"""Probe: where do qwen3-moe decode_32k memory bytes go?

Compares per-layer cost (2-layer minus 1-layer compiles) against napkin
terms: expert weights, attention weights, KV-cache reads.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses

from repro.launch.dryrun import _compile_combo
from repro.launch.train import TrainHyper
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as roofline_lib
from repro.configs.base import get_config, INPUT_SHAPES

cfg0 = get_config("qwen3_moe_30b_a3b")
mesh = mesh_lib.make_production_mesh()
shape = INPUT_SHAPES["decode_32k"]

res = {}
for L in (1, 2):
    cfg = dataclasses.replace(cfg0, num_layers=L)
    compiled, _, _ = _compile_combo(cfg, shape, mesh, TrainHyper(), unroll=L)
    r = roofline_lib.analyse(compiled, chips=256)
    res[L] = r
    print(f"L={L}: flops={r.flops:.3e} bytes={r.bytes_accessed:.3e} "
          f"coll={r.coll_bytes:.3e}")

per_layer_bytes = res[2].bytes_accessed - res[1].bytes_accessed
per_layer_flops = res[2].flops - res[1].flops
print(f"\nper-layer bytes: {per_layer_bytes/1e9:.2f} GB   "
      f"per-layer flops: {per_layer_flops/1e9:.2f} GF")

d, ff, e = cfg0.d_model, cfg0.d_ff, cfg0.moe_num_experts
e_local = e // 16
w_expert = 3 * d * ff * e_local * 4
hd = cfg0.resolved_head_dim
w_attn = (d * cfg0.num_heads * hd + 2 * d * cfg0.num_kv_heads * hd
          + cfg0.num_heads * hd * d) * 4 / 16
kv = 8 * 32768 * 2 * cfg0.num_kv_heads * hd * 4 / 16  # b_local x S, seq/model
print(f"napkin/layer: expert weights {w_expert/1e9:.3f} GB, "
      f"attn weights {w_attn/1e9:.4f} GB, kv reads {kv/1e9:.3f} GB")
