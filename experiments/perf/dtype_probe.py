import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses, re, collections, sys
from repro.launch.dryrun import _compile_combo
from repro.launch.train import TrainHyper
from repro.launch import mesh as mesh_lib
from repro.configs.base import get_config, INPUT_SHAPES

dtype = sys.argv[1] if len(sys.argv) > 1 else "bfloat16"
cfg = dataclasses.replace(get_config("llama3_8b"), num_layers=1, dtype=dtype)
mesh = mesh_lib.make_production_mesh()
compiled, _, _ = _compile_combo(cfg, INPUT_SHAPES["train_4k"], mesh,
                                TrainHyper(remat=False), unroll=1)
text = compiled.as_text()
agg = collections.Counter()
for line in text.splitlines():
    if "=" not in line:
        continue
    rhs = line.split("=", 1)[1]
    m = re.search(r"\b(all-reduce|all-gather|reduce-scatter|all-to-all)"
                  r"(?:-start)?\(", rhs)
    if not m or "-done(" in rhs:
        continue
    head = rhs.split("(", 1)[0]   # "f32[16,4096,4096]{1,0} all-reduce"
    for dt, dims in re.findall(r"(\w+)\[([\d,]*)\]", head):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * {"f32": 4, "bf16": 2, "u32": 4, "s32": 4, "pred": 1,
                 "f16": 2, "u8": 1}.get(dt, 4)
        agg[(m.group(1), dt)] += b
for k, v in agg.most_common(12):
    print(k, f"{v/1e9:.3f} GB")

shapes = collections.Counter()
for line in text.splitlines():
    if "=" not in line:
        continue
    rhs = line.split("=", 1)[1]
    if not re.search(r"\ball-reduce(?:-start)?\(", rhs) or "-done(" in rhs:
        continue
    head = rhs.split("(", 1)[0].strip()
    shapes[head.split("{")[0]] += 1
for k, v in shapes.most_common(15):
    print(v, "x", k)
