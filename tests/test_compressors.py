import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import matrixize
from repro.core.compressors import (ExactRankK, IdentityCompressor, RandomBlock,
                                    RandomK, SignNorm, SpectralAtomo, TopK,
                                    UnbiasedRankK, make_compressor)

KEY = jax.random.key(0)


def _problem(shape=(40, 30), seed=0):
    m = jax.random.normal(jax.random.key(seed), shape)
    grads = {"w": m, "b": jnp.ones((7,))}
    specs = {"w": matrixize.default_spec(m),
             "b": matrixize.default_spec(grads["b"])}
    shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads)
    return grads, specs, shapes


ALL = ["identity", "powersgd", "powersgd_cold", "powersgd_best_approx",
       "unbiased_rank_k", "random_block", "random_k", "sign_norm", "top_k",
       "spectral_atomo", "exact_rank_k"]


@pytest.mark.parametrize("name", ALL)
def test_shapes_and_finiteness(name):
    grads, specs, shapes = _problem()
    comp = make_compressor(name, rank=2)
    state = comp.init(shapes, specs, KEY)
    out = comp.step(grads, state, specs, key=KEY)
    for k in grads:
        assert out.agg[k].shape == grads[k].shape
        assert out.recon[k].shape == grads[k].shape
        assert bool(jnp.all(jnp.isfinite(out.agg[k])))
    # bias passes through exactly for every scheme
    np.testing.assert_array_equal(np.asarray(out.agg["b"]), np.ones(7))


def test_identity_lossless():
    grads, specs, shapes = _problem()
    out = IdentityCompressor().step(grads, None, specs, key=KEY)
    np.testing.assert_array_equal(np.asarray(out.agg["w"]), np.asarray(grads["w"]))


def test_unbiased_rank_k_is_unbiased():
    """E[(MU)Uᵀ] = M (§4.1) — check the sample mean converges."""
    grads, specs, shapes = _problem(shape=(12, 10))
    comp = UnbiasedRankK(rank=2)
    acc = np.zeros((12, 10))
    trials = 3000
    for i in range(trials):
        out = comp.step(grads, None, specs, key=jax.random.key(i))
        acc += np.asarray(out.recon["w"])
    acc /= trials
    err = np.abs(acc - np.asarray(grads["w"])).mean()
    scale = np.abs(np.asarray(grads["w"])).mean()
    assert err < 0.15 * scale


def test_atomo_is_unbiased():
    grads, specs, shapes = _problem(shape=(8, 6))
    comp = SpectralAtomo(rank=2, attempts=16)
    acc = np.zeros((8, 6))
    trials = 1500
    for i in range(trials):
        out = comp.step(grads, None, specs, key=jax.random.key(i))
        acc += np.asarray(out.recon["w"])
    acc /= trials
    err = np.abs(acc - np.asarray(grads["w"])).mean()
    scale = np.abs(np.asarray(grads["w"])).mean()
    assert err < 0.2 * scale


def test_top_k_keeps_largest():
    grads, specs, shapes = _problem()
    comp = TopK(rank=1)
    out = comp.step(grads, specs=specs, state=None, key=KEY)
    recon = np.asarray(out.recon["w"]).ravel()
    orig = np.asarray(grads["w"]).ravel()
    kept = recon != 0
    b = kept.sum()
    assert b == min((40 + 30) * 1, orig.size)
    thresh = np.sort(np.abs(orig))[-b]
    assert np.all(np.abs(orig[kept]) >= thresh - 1e-6)


def test_random_block_is_contiguous():
    grads, specs, shapes = _problem()
    comp = RandomBlock(rank=1)
    out = comp.step(grads, None, specs, key=KEY)
    nz = np.nonzero(np.asarray(out.recon["w"]).ravel())[0]
    assert len(nz) > 0
    assert nz[-1] - nz[0] + 1 == len(nz)  # one contiguous slice


def test_sign_norm_magnitude():
    grads, specs, shapes = _problem()
    out = SignNorm(rank=1).step(grads, None, specs, key=KEY)
    recon = np.asarray(out.recon["w"])
    l1 = np.abs(np.asarray(grads["w"])).mean()
    vals = np.unique(np.round(np.abs(recon), 6))
    assert len(vals) == 1
    np.testing.assert_allclose(vals[0], l1, rtol=1e-5)


def test_exact_rank_k_is_optimal():
    grads, specs, shapes = _problem()
    exact = ExactRankK(rank=2).step(grads, None, specs, key=KEY)
    # any other rank-2 reconstruction must be at least as far from M
    psgd = make_compressor("powersgd_best_approx", rank=2)
    st = psgd.init(shapes, specs, KEY)
    out = psgd.step(grads, st, specs, key=KEY)
    e_exact = float(jnp.linalg.norm(grads["w"] - exact.agg["w"]))
    e_psgd = float(jnp.linalg.norm(grads["w"] - out.agg["w"]))
    assert e_exact <= e_psgd + 1e-4


def test_sparsifier_budgets_match_powersgd():
    """Appendix G: sparsifier budget b = (n+m)·r coordinates."""
    grads, specs, shapes = _problem()
    for cls in (RandomK, TopK):
        out = cls(rank=2).step(grads, None, specs, key=KEY)
        nz = int((np.asarray(out.recon["w"]) != 0).sum())
        assert nz == (40 + 30) * 2


def test_allreduce_flags():
    """§5.1: linear schemes support all-reduce, sign/top-k/atomo do not."""
    assert make_compressor("powersgd").allreduce
    assert make_compressor("random_block").allreduce
    assert make_compressor("random_k").allreduce
    assert make_compressor("unbiased_rank_k").allreduce
    assert not make_compressor("sign_norm").allreduce
    assert not make_compressor("top_k").allreduce
    assert not make_compressor("spectral_atomo").allreduce
