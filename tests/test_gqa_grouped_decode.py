"""gqa_grouped_decode perf variant (§Perf #4): numerically identical to the
expand-and-take decode attention path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.dist import SINGLE
from repro.models import model as model_lib

KEY = jax.random.key(0)


def _decode_tokens(cfg, steps=6):
    params = model_lib.init(KEY, cfg, model_shards=1)
    b = 2
    cache = model_lib.init_cache(cfg, 1, b, 32)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits_all = []
    for pos in range(steps):
        tok, logits, cache = model_lib.decode_step(
            params, cache, tok, jnp.int32(pos), cfg, SINGLE)
        logits_all.append(np.asarray(logits))
    return np.stack(logits_all)


def test_grouped_decode_matches_expand_path():
    # llama3 reduced has GQA (heads divisible by kv heads)
    base = get_config("llama3-8b", reduced=True)
    assert base.num_heads % base.num_kv_heads == 0
    a = _decode_tokens(base)
    b = _decode_tokens(dataclasses.replace(base, gqa_grouped_decode=True))
    np.testing.assert_allclose(a, b, atol=2e-5)
