"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward/train step and one decode step on CPU,
asserting output shapes and the absence of NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.core.dist import SINGLE
from repro.models import model as model_lib

KEY = jax.random.key(0)


def _batch(cfg, b=2, s=64):
    batch = {
        "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
    }
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(KEY, (b, 16, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.moe_num_experts <= 4
    params = model_lib.init(KEY, cfg, model_shards=1)
    loss, metrics = model_lib.loss_fn(params, _batch(cfg), cfg, SINGLE, q_chunk=32)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    assert float(metrics["lm_loss"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    """One EF-PowerSGD train step on the (1,1) mesh: params move, stay finite."""
    from repro.launch.train import TrainHyper, make_train_step

    cfg = get_config(arch, reduced=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    hyper = TrainHyper(q_chunk=32, warmup_steps=2, remat=False, lr=0.05)
    step_fn, _, init_state = make_train_step(cfg, mesh, hyper)
    with jax.set_mesh(mesh):
        params, ef = init_state(KEY)
        batch = _batch(cfg, b=2, s=32)
        if cfg.frontend == "vision":
            batch["patches"] = jax.random.normal(KEY, (2, 8, cfg.frontend_dim))
        new_params, new_ef, metrics = step_fn(params, ef, batch, KEY)
    assert bool(jnp.isfinite(metrics["lm_loss"]))
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree_util.tree_leaves(new_params),
                        jax.tree_util.tree_leaves(
                            model_lib.init(KEY, cfg, model_shards=1))))
    assert moved
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    params = model_lib.init(KEY, cfg, model_shards=1)
    b = 2
    cache = model_lib.init_cache(cfg, 1, b, 32)
    tok = jnp.zeros((b, 1), jnp.int32)
    for pos in range(4):
        tok, logits, cache = model_lib.decode_step(
            params, cache, tok, jnp.int32(pos), cfg, SINGLE)
    assert tok.shape == (b, 1)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.all((tok >= 0) & (tok < cfg.vocab_size)))


@pytest.mark.parametrize("arch", ["llama3_8b", "mamba2_1p3b", "jamba_v01_52b"])
def test_prefill_matches_decode(arch):
    """prefill(prompt) then decode must equal token-by-token decode."""
    cfg = get_config(arch, reduced=True)
    cfg = dataclasses.replace(cfg, decode_window=0)
    params = model_lib.init(KEY, cfg, model_shards=1)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
    logits_pf, cache_pf = model_lib.prefill_step(
        params, {"tokens": toks}, cfg, SINGLE, q_chunk=8)
    cache = model_lib.init_cache(cfg, 1, b, s)
    for pos in range(s):
        _, logits, cache = model_lib.decode_step(
            params, cache, toks[:, pos:pos + 1], jnp.int32(pos), cfg, SINGLE)
    np.testing.assert_allclose(np.asarray(logits_pf[:, 0]),
                               np.asarray(logits[:, 0]), atol=2e-4)


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the exact assigned hyperparams."""
    import math

    expect = {
        "llama3_8b": dict(num_layers=32, d_model=4096, num_heads=32,
                          num_kv_heads=8, d_ff=14336, vocab_size=128256),
        "mamba2_1p3b": dict(num_layers=48, d_model=2048, d_ff=0,
                            vocab_size=50280, ssm_state=128),
        "jamba_v01_52b": dict(num_layers=32, d_model=4096, num_heads=32,
                              num_kv_heads=8, d_ff=14336, vocab_size=65536,
                              moe_num_experts=16, moe_top_k=2),
        "musicgen_medium": dict(num_layers=48, d_model=1536, num_heads=24,
                                num_kv_heads=24, d_ff=6144, vocab_size=2048),
        "llava_next_34b": dict(num_layers=60, d_model=7168, num_heads=56,
                               num_kv_heads=8, d_ff=20480, vocab_size=64000),
        "qwen3_moe_30b_a3b": dict(num_layers=48, d_model=2048, num_heads=32,
                                  num_kv_heads=4, d_ff=768, vocab_size=151936,
                                  moe_num_experts=128, moe_top_k=8,
                                  qk_norm=True),
        "codeqwen15_7b": dict(num_layers=32, d_model=4096, num_heads=32,
                              num_kv_heads=32, d_ff=13440, vocab_size=92416),
        "olmoe_1b_7b": dict(num_layers=16, d_model=2048, num_heads=16,
                            num_kv_heads=16, d_ff=1024, vocab_size=50304,
                            moe_num_experts=64, moe_top_k=8),
        "qwen3_4b": dict(num_layers=36, d_model=2560, num_heads=32,
                         num_kv_heads=8, d_ff=9728, vocab_size=151936,
                         qk_norm=True),
        "yi_6b": dict(num_layers=32, d_model=4096, num_heads=32,
                      num_kv_heads=4, d_ff=11008, vocab_size=64000),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for f, v in fields.items():
            assert getattr(cfg, f) == v, (arch, f, getattr(cfg, f), v)
    # jamba interleave: 1 attention per 8 layers, MoE every other layer
    cfg = get_config("jamba_v01_52b")
    mixers = [s.mixer for s in cfg.slots]
    assert mixers.count("attn") == 1 and len(mixers) == 8
    assert [s.ffn for s in cfg.slots].count("moe") == 4
