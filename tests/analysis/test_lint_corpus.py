"""gradlint corpus conformance: every known-bad program under
``tests/analysis/corpus/`` is flagged by exactly its pass (its declared
rule, no cross-pass false positives), and the clean control trace produces
nothing.

Corpus modules declare ``RULE`` (the one rule they violate) and ``PASS``
(the pass that owns it).  Jaxpr-pass programs expose ``build() ->
(TraceArtifact, budget)``; the partition program exposes ``build() ->
(state, partition)``; AST programs are linted as source text at their
declared ``REL_PATH`` and never imported.
"""

import ast
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import astlint, partition as partition_pass, passes
from repro.analysis import tracing
from repro.core.compressors import make_compressor
from repro.core import matrixize
from repro.core.dist import CollectiveStats, MeshCtx

CORPUS = pathlib.Path(__file__).parent / "corpus"

JAXPR_CORPUS = ["bad_upcast", "bad_int_reduce", "bad_budget",
                "bad_unkeyed_prng", "bad_reduce_order"]
AST_CORPUS = ["bad_host_transfer", "bad_prng_in_step", "bad_implicit_reduce"]


def _load(name):
    spec = importlib.util.spec_from_file_location(
        f"gradlint_corpus_{name}", CORPUS / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _module_consts(name):
    """Module-level string constants, read without importing (AST corpus
    must stay usable from the jax-free test as well)."""
    tree = ast.parse((CORPUS / f"{name}.py").read_text())
    out = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and \
                isinstance(node.value, ast.Constant):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out[tgt.id] = node.value.value
    return out


@pytest.mark.parametrize("name", JAXPR_CORPUS)
def test_jaxpr_corpus_flagged_by_exactly_its_pass(name):
    mod = _load(name)
    art, budget = mod.build()
    findings = passes.run_jaxpr_passes(art, budget=budget, scheme=name)
    assert findings, f"{name}: corpus program produced no findings"
    assert {f.rule for f in findings} == {mod.RULE}, \
        [(f.rule, f.message) for f in findings]
    assert {f.pass_name for f in findings} == {mod.PASS}


def test_partition_corpus_flagged():
    mod = _load("bad_partition")
    state, partition = mod.build()
    findings = partition_pass.check_partition(
        state, partition, mesh_axes=("data", "model"))
    assert findings
    assert {f.rule for f in findings} == {mod.RULE}
    # the jaxpr passes have nothing to say about a partition-only program,
    # and vice versa the partition pass stays quiet on a clean tree
    from repro.core.engine import MODEL_SHARDED, StatePartition
    from jax.sharding import PartitionSpec as P
    ok = {"w": StatePartition(spec=P(None, "model"), model=MODEL_SHARDED)}
    assert partition_pass.check_partition(
        state, ok, mesh_axes=("data", "model")) == []


@pytest.mark.parametrize("name", AST_CORPUS)
def test_ast_corpus_flagged_by_exactly_its_rule(name):
    consts = _module_consts(name)
    findings = astlint.lint_source(
        (CORPUS / f"{name}.py").read_text(), consts["REL_PATH"])
    assert findings, f"{name}: corpus program produced no findings"
    assert {f.rule for f in findings} == {consts["RULE"]}, \
        [(f.rule, f.message) for f in findings]


def test_clean_control_trace_produces_no_findings():
    """The clean control: a real zoo compress step (the same trace the
    budget matrix runs) yields zero findings across every jaxpr pass —
    corpus programs fire because they are bad, not because the passes
    are trigger-happy."""
    comp = make_compressor("powersgd", rank=2)
    grads = {"w": jnp.zeros((24, 16)), "b": jnp.zeros((7,))}
    specs = {"w": matrixize.MatrixSpec("matrix", 0), "b": matrixize.NONE}
    art = tracing.trace_compress_step(comp, grads, specs, label="control")
    assert passes.run_jaxpr_passes(
        art, budget=comp.declared_budget(), scheme="control") == []


def test_unattributed_collective_is_gl103():
    """A hand-rolled lax.psum that never passes through the dist entry
    points escapes both ledgers — the budget pass calls it out."""
    stats = CollectiveStats()

    def compress(g):
        return jax.lax.psum(g, "data")

    art = tracing.trace_fn(compress, (jnp.zeros((8,)),), stats=stats,
                           label="handrolled")
    findings = passes.check_budget(art, budget=(1, 1, 0))
    assert any(f.rule == "GL103" for f in findings)


def test_static_stats_mismatch_is_gl102():
    """A collective that bypasses CollectiveStats (here: a dist-attributed
    trace whose stats object was swapped for an empty one) trips the
    cross-check."""
    ctx = MeshCtx(data_axes=("data",), stats=CollectiveStats())

    def compress(g):
        return ctx.pmean_flat([g])[0]

    art = tracing.trace_fn(compress, (jnp.zeros((8,)),),
                           stats=CollectiveStats(),  # NOT the ctx's stats
                           label="stats_bypass")
    findings = passes.check_budget(art, budget=(1, 1, 0))
    assert any(f.rule == "GL102" for f in findings)
