"""gradlint corpus: GL101 collective-budget-exceeded.

A compress step that reduces twice against a documented budget of one
fused collective — the O(1)-collectives property of the paper's Section 3
scalability argument has silently regressed.
"""

import jax
import jax.numpy as jnp

from repro.analysis import tracing
from repro.core.dist import CollectiveStats, MeshCtx

RULE = "GL101"
PASS = "budget"


def build():
    stats = CollectiveStats()
    ctx = MeshCtx(data_axes=("data",), stats=stats)

    def compress(g):
        # BUG: a second fused reduce sneaks in (e.g. a stats/debug path
        # that went to the wire) against a declared budget of 1
        agg = ctx.pmean_flat([g])[0]
        return ctx.pmean_flat([agg * agg])[0]

    g = jax.ShapeDtypeStruct((64,), jnp.float32)
    art = tracing.trace_fn(compress, (g,), stats=stats, label="bad_budget")
    return art, (1, 1, 0)
