"""gradlint corpus: GLA03 implicit-dtype-reduction.

A ``jnp.sum`` without an explicit ``dtype=`` in a wire-path module: the
accumulator width — and with it the bytes that cross the wire — becomes
an implicit-promotion accident (the PR 3 bug class).  Linted as if it
lived at ``REL_PATH`` (a wire-path module); never imported by the tests.
"""

import jax.numpy as jnp

RULE = "GLA03"
PASS = "ast"
REL_PATH = "core/dist.py"


def chunk_bytes(payload):
    # BUG: accumulator dtype left to promotion rules on a wire path
    return jnp.sum(payload) * payload.dtype.itemsize
