"""gradlint corpus: GL201 wire-upcast-before-collective.

A bfloat16 gradient is widened to float32 *before* the fused reduce — one
straggler cast and the whole payload rides a 4-byte wire (the PR 3 bug
class the wire-dtype pass exists to catch).
"""

import jax
import jax.numpy as jnp

from repro.analysis import tracing
from repro.core.dist import CollectiveStats, MeshCtx

RULE = "GL201"
PASS = "wire-dtype"


def build():
    stats = CollectiveStats()
    ctx = MeshCtx(data_axes=("data",), stats=stats)

    def compress(g):
        # BUG: widens the bf16 payload to f32 on the pack path
        return ctx.pmean_flat([g.astype(jnp.float32)])[0]

    g = jax.ShapeDtypeStruct((64,), jnp.bfloat16)
    art = tracing.trace_fn(compress, (g,), stats=stats, label="bad_upcast")
    return art, (1, 1, 0)
