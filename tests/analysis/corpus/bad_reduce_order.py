"""gradlint corpus: GL302 uncertified-reduce-order.

Inside a certified sync_mode="broadcast" step, a helper builds its own
allreduce MeshCtx and issues a raw psum.  The result is correct in exact
arithmetic, but the psum's reduction order is substrate-defined — the
replicas (and SimMesh-vs-shard_map reruns) may disagree in the last ULP,
which is exactly the drift class the PR 6 certified pattern (canonical
all_gather + pairwise tree replay, or the masked broadcast0 delivery)
removes.
"""

import jax
import jax.numpy as jnp

from repro.analysis import tracing
from repro.core.dist import CollectiveStats, MeshCtx

RULE = "GL302"
PASS = "determinism"


def build():
    stats = CollectiveStats()
    synced = MeshCtx(data_axes=("data",), stats=stats,
                     sync_mode="broadcast")
    # BUG: a "utility" ctx that forgot the certified sync mode
    rogue = MeshCtx(data_axes=("data",), stats=stats)

    def compress(g):
        agg = synced.pmean_flat([g])[0]
        scale = rogue.psum_data(jnp.sum(agg, dtype=jnp.float32))
        return agg * scale

    g = jax.ShapeDtypeStruct((64,), jnp.float32)
    art = tracing.trace_fn(compress, (g,), stats=stats,
                           sync_mode="broadcast", label="bad_reduce_order")
    return art, None  # budget not the point; broadcast budgets unchecked
