"""gradlint corpus: GLA01 host-transfer.

``np.asarray`` on (possibly sharded) device values outside ``checkpoint/``
reads device 0's shard and silently drops every other rank's content.
Linted as if it lived at ``REL_PATH``; never imported by the tests.
"""

import numpy as np

RULE = "GLA01"
PASS = "ast"
REL_PATH = "launch/metrics.py"


def summarize(tree_leaf):
    # BUG: host transfer outside the checkpoint canonicalize path
    host = np.asarray(tree_leaf)
    return float(host.mean())
