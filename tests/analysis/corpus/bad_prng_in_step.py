"""gradlint corpus: GLA02 prng-key-in-step.

A PRNG key constructed from a constant inside a step function: every
invocation (and every retracing rank) reuses the same stream.  Linted as
source text only; never imported by the tests.
"""

import jax

RULE = "GLA02"
PASS = "ast"
REL_PATH = "core/sampler.py"


def sample_step(params, batch):
    # BUG: constant key built inside the step body
    key = jax.random.PRNGKey(0)
    return jax.random.uniform(key, (4,)), params, batch
