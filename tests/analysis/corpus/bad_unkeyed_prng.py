"""gradlint corpus: GL301 in-trace-prng-seed.

A PRNG key seeded from a constant *inside* the traced step: every step
draws the same stream, and any rank-dependent retrace desynchronizes the
replicas.  Keys must enter as arguments and derive via fold_in.
"""

import jax
import jax.numpy as jnp

from repro.analysis import tracing
from repro.core.dist import CollectiveStats, MeshCtx

RULE = "GL301"
PASS = "determinism"


def build():
    stats = CollectiveStats()
    ctx = MeshCtx(data_axes=("data",), stats=stats)

    def compress(g):
        # BUG: constant seed inside the trace
        noise = jax.random.normal(jax.random.key(0), g.shape, g.dtype)
        return ctx.pmean_flat([g + 0.01 * noise])[0]

    g = jax.ShapeDtypeStruct((64,), jnp.float32)
    art = tracing.trace_fn(compress, (g,), stats=stats,
                           label="bad_unkeyed_prng")
    return art, (1, 1, 0)
