"""gradlint corpus: GL403 invalid-partition-spec.

A state leaf classified MODEL_REPLICATED whose dims-spec nonetheless
shards over the model axis — the two halves of its StatePartition
contradict each other, so the checkpoint canonicalize path and the
shard_map specs disagree about what bytes each rank owns (the PR 7
corruption class).
"""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.engine import MODEL_REPLICATED, StatePartition

RULE = "GL403"
PASS = "partition"


def build():
    state = {"w": jax.ShapeDtypeStruct((4, 8), jnp.float32)}
    # BUG: spec says model-sharded, classification says replicated
    partition = {"w": StatePartition(spec=P(None, "model"),
                                     model=MODEL_REPLICATED)}
    return state, partition
