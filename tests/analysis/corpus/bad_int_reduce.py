"""gradlint corpus: GL202 unwidened-int-reduce.

int8 sign bytes are summed over the data axis directly — at W >= 2 the
accumulator wraps at +-127 and the aggregate is garbage.  Quantized
payloads must dequantize into a float accumulator before any reduce (or
ship over an all-gather, as sign_norm actually does).
"""

import jax
import jax.numpy as jnp

from repro.analysis import tracing
from repro.core.dist import CollectiveStats, MeshCtx

RULE = "GL202"
PASS = "wire-dtype"


def build():
    stats = CollectiveStats()
    ctx = MeshCtx(data_axes=("data",), stats=stats)

    def compress(signs):
        # BUG: integer payload straight into a psum
        return ctx.psum_data(signs)

    signs = jax.ShapeDtypeStruct((64,), jnp.int8)
    art = tracing.trace_fn(compress, (signs,), stats=stats,
                           label="bad_int_reduce")
    return art, (1, 1, 0)
