"""The static collective-budget matrix: every zoo scheme × wire dtype ×
staleness mode, traced device-free, must agree three ways —

  declared (``Compressor.declared_budget``)
    == static (jaxpr collective primitives, sidecars folded)
    == runtime accounting (``CollectiveStats``, recorded at trace time)

— plus retrace-stability across the PowerSGD rank staircase.  This is the
paper's Section 3 O(1)-collectives claim as a machine-checked property
rather than a documented observation.
"""

import pytest

from repro.analysis.findings import Report
from repro.analysis import lint as L
from repro.analysis import partition as partition_pass
from repro.analysis import tracing


@pytest.mark.parametrize("scheme", L.ZOO_SCHEMES)
def test_budget_matrix_triple_agreement(scheme):
    """All 4 wire dtypes × both staleness modes for one scheme, plus the
    broadcast-mode determinism trace: zero findings means the declared
    budget, the jaxpr ledger, and the CollectiveStats ledger all agree
    (GL101/GL102/GL104 police the three pairwise comparisons) and no
    wire-dtype or determinism rule fired along the way."""
    rep = Report()
    n = L.run_matrix(rep, schemes=(scheme,))
    assert n == len(L.WIRE_DTYPES) * len(L.STALENESS_MODES) + 1
    assert rep.findings == [], [str(f) for f in rep.findings]


@pytest.mark.parametrize("wire_dtype,staleness",
                         [("auto", "none"), ("int4", "one_step")])
def test_declared_budget_matches_observed_counts(wire_dtype, staleness):
    """Spot-check the agreement *numbers* (not just the absence of
    findings): the traced logical ledger equals the declared budget
    exactly, for a reduce scheme and a gather scheme with an integer
    side channel."""
    grads, specs = L._mixed_tree()
    for scheme in ("powersgd", "sign_norm"):
        comp = L.make_zoo_compressor(scheme, wire_dtype, staleness)
        art = tracing.trace_compress_step(comp, grads, specs,
                                          staleness=staleness)
        total, n_reduce, n_gather = comp.declared_budget()
        logical = art.logical()
        assert len(logical) == total, (scheme, [s.provenance() for s in logical])
        assert sum(1 for s in logical if s.kind == "reduce") == n_reduce
        assert sum(1 for s in logical if s.kind == "gather") == n_gather
        # the runtime accounting path recorded the same trace
        assert art.stats.data_collectives == total, (scheme, art.stats.kinds)


def test_one_step_pipeline_traces_identical_collectives():
    """PR 8's trace-identity contract, statically: the one-step-stale
    pipeline must issue byte-for-byte the same collective schedule as the
    serial step (same primitives, kinds, dtypes, sizes, in order)."""
    grads, specs = L._mixed_tree()

    def ledger(staleness):
        comp = L.make_zoo_compressor("powersgd", "auto", staleness)
        art = tracing.trace_compress_step(comp, grads, specs,
                                          staleness=staleness)
        return [(s.primitive, s.kind, s.dtype, s.size)
                for s in art.logical()]

    assert ledger("none") == ledger("one_step")


def test_retrace_stable_and_rank_boundaries_distinct():
    """GL5xx on the real thing: tracing the same (scheme, rank) twice is
    hash-stable, and each declared RankController boundary (rank 1→2→4)
    actually changes the program."""
    grads, specs = L._mixed_tree()

    def build(rank):
        comp = L.make_zoo_compressor("powersgd", "auto", "none", rank=rank)
        return tracing.trace_compress_step(comp, grads, specs,
                                           label=f"rank{rank}")

    findings = partition_pass.check_retrace(build, [(1,), (2,), (4,)])
    assert findings == [], [str(f) for f in findings]


def test_collapsed_rank_boundary_is_gl502():
    """Negative control: a rank 'boundary' that never reaches the
    compressor hashes identically and is called out as a rotted
    declaration."""
    grads, specs = L._mixed_tree()

    def build(rank):  # BUG: drops rank on the floor
        comp = L.make_zoo_compressor("powersgd", "auto", "none", rank=2)
        return tracing.trace_compress_step(comp, grads, specs)

    findings = partition_pass.check_retrace(build, [(2,), (4,)])
    assert [f.rule for f in findings] == ["GL502"]
