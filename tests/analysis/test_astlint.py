"""AST-rule conformance — deliberately jax-free.

This module must import cleanly (and pass) in the docs CI job, which has
no jax installed: it exercises ``repro.analysis.astlint`` on source text
only, pins the live ``src/repro`` tree clean, and proves the
``--ast-only`` CLI path never imports jax.
"""

import pathlib
import subprocess
import sys

from repro.analysis import astlint
from repro.analysis.findings import RULES

SRC = pathlib.Path(__file__).resolve().parents[2] / "src" / "repro"


def _rules(src, rel="launch/somewhere.py"):
    return [f.rule for f in astlint.lint_source(src, rel)]


def test_host_transfer_flagged_outside_checkpoint():
    assert _rules("import numpy as np\nx = np.asarray(y)\n") == ["GLA01"]
    assert _rules("import jax\nx = jax.device_get(y)\n") == ["GLA01"]


def test_host_transfer_sanctioned_in_checkpoint():
    src = "import numpy as np\nx = np.asarray(y)\n"
    assert _rules(src, rel="checkpoint/train_state.py") == []


def test_escape_hatch_by_name_and_id():
    by_name = "x = np.asarray(y)  # gradlint: disable=host-transfer\n"
    by_id = "x = np.asarray(y)  # gradlint: disable=GLA01\n"
    both = "x = np.asarray(y)  # gradlint: disable=GLA01, prng-key-in-step\n"
    assert _rules(by_name) == []
    assert _rules(by_id) == []
    assert _rules(both) == []
    # a disable for a *different* rule does not suppress
    wrong = "x = np.asarray(y)  # gradlint: disable=GLA02\n"
    assert _rules(wrong) == ["GLA01"]


def test_prng_key_flagged_in_step_not_in_factory():
    in_step = ("import jax\n"
               "def train_step(s):\n"
               "    return jax.random.PRNGKey(0)\n")
    in_factory = ("import jax\n"
                  "def make_train_step(cfg):\n"
                  "    key = jax.random.key(0)\n"
                  "    def step(s):\n"
                  "        return s\n"
                  "    return step\n")
    assert _rules(in_step) == ["GLA02"]
    assert _rules(in_factory) == []


def test_implicit_reduction_only_on_wire_paths():
    src = "import jax.numpy as jnp\ndef f(x):\n    return jnp.sum(x)\n"
    ok = ("import jax.numpy as jnp\ndef f(x):\n"
          "    return jnp.sum(x, dtype=jnp.float32)\n")
    assert _rules(src, rel="core/dist.py") == ["GLA03"]
    assert _rules(ok, rel="core/dist.py") == []
    assert _rules(src, rel="models/model.py") == []  # not a wire path


def test_live_source_tree_is_clean():
    """The repo's own ``src/repro`` carries no AST findings — every
    deliberate host-transfer site is annotated with the escape hatch, no
    step builds constant keys, no wire-path reduction leaves its
    accumulator dtype to promotion."""
    findings = astlint.lint_tree(SRC)
    assert findings == [], [str(f) for f in findings]


def test_ast_only_cli_runs_without_jax():
    """``python -m repro.analysis.lint --ast-only`` must work on a machine
    with no jax at all (the docs CI job): run it with an import hook that
    refuses jax and assert a clean exit."""
    blocker = (
        "import sys\n"
        "class NoJax:\n"
        "    def find_spec(self, name, path=None, target=None):\n"
        "        if name == 'jax' or name.startswith('jax.') or \\\n"
        "                name == 'jaxlib' or name.startswith('jaxlib.'):\n"
        "            raise ImportError('jax is unavailable in this job')\n"
        "        return None\n"
        "sys.meta_path.insert(0, NoJax())\n"
        "from repro.analysis.lint import main\n"
        "sys.exit(main(['--ast-only']))\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", blocker],
        env={"PYTHONPATH": str(SRC.parent), "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_rule_catalog_is_consistent():
    """Every rule id is unique, every name is unique, and findings render
    with both (the machine-readable contract the CI annotations parse)."""
    ids = [r.id for r in RULES]
    names = [r.name for r in RULES]
    assert len(set(ids)) == len(ids)
    assert len(set(names)) == len(names)
    f = astlint.lint_source("x = np.asarray(y)\n", "launch/x.py")[0]
    d = f.to_dict()
    assert d["rule"] == "GLA01" and d["name"] == "host-transfer"
    assert d["file"] == "launch/x.py" and d["line"] == 1
