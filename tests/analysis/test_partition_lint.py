"""Partition-consistency pass (GL4xx) against the real spec-derivation
stack, plus the regression pin for the finding gradlint surfaced:
``EFState.inflight`` used to be classified only by a hand-patch inside
``make_train_step``, leaving every other partition consumer (notably the
checkpoint classification path) with unclassified in-flight leaves.
"""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import partition as partition_pass
from repro.core.engine import MODEL_LOCAL, StatePartition
from repro.core.error_feedback import EFState
from repro.core import matrixize, powersgd
from repro.launch import specs as specs_lib

PSPECS = {
    "w_row": P("model", None),    # row-parallel matrix -> Q is MODEL_LOCAL
    "w_col": P(None, "model"),    # col-parallel matrix -> Q is MODEL_SHARDED
    "bias": P(),                  # uncompressed vector
}
MSPECS = {
    "w_row": matrixize.MatrixSpec("matrix", 0),
    "w_col": matrixize.MatrixSpec("matrix", 0),
    "bias": matrixize.NONE,
}
SHAPES = {
    "w_row": jax.ShapeDtypeStruct((8, 6), jnp.float32),
    "w_col": jax.ShapeDtypeStruct((6, 8), jnp.float32),
    "bias": jax.ShapeDtypeStruct((5,), jnp.float32),
}


def _ef_state(staleness):
    comp = jax.eval_shape(lambda: powersgd.init_state(
        powersgd.PowerSGDConfig(rank=2), SHAPES, MSPECS,
        jax.random.key(0)))
    return EFState(
        error=jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((2,) + tuple(s.shape), s.dtype),
            SHAPES),
        momentum=SHAPES, comp=comp,
        step=jax.ShapeDtypeStruct((), jnp.int32),
        inflight=SHAPES if staleness == "one_step" else None)


@pytest.mark.parametrize("staleness", ["none", "one_step"])
def test_ef_partition_classifies_every_leaf(staleness):
    """The single-source-of-truth derivation covers the whole EF state —
    including the one-step-stale in-flight buffer (the fixed finding:
    before, ``staleness`` never reached ``ef_partition`` and inflight
    leaves had no StatePartition record)."""
    parts = specs_lib.ef_partition(PSPECS, MSPECS, ("data",),
                                   staleness=staleness)
    findings = partition_pass.check_partition(
        _ef_state(staleness), parts, mesh_axes=("data", "model"))
    assert findings == [], [str(f) for f in findings]


def test_omitting_staleness_regresses_to_gl401():
    """Negative control for the fixed finding: derive the partition
    without the staleness mode (the pre-fix call shape) against a
    one-step state and the inflight leaves come back unclassified."""
    parts = specs_lib.ef_partition(PSPECS, MSPECS, ("data",))
    findings = partition_pass.check_partition(
        _ef_state("one_step"), parts, mesh_axes=("data", "model"))
    assert findings and {f.rule for f in findings} == {"GL401"}
    assert all(".inflight" in f.message for f in findings)


def test_factor_partition_cross_check_clean_and_detects_drift():
    """GL402: the compressor's own state_partition agrees with the
    canonical factor_partition derivation — and a leaf mutated to the
    wrong model classification is caught."""
    comp_parts = powersgd.state_partition(PSPECS, MSPECS)
    assert partition_pass.check_factor_partition(
        PSPECS, MSPECS, comp_parts) == []

    # corrupt one leaf: pretend the col-parallel Q factor (whose m dim is
    # model-sharded, spec P('model', None)) is model-local
    bad = jax.tree_util.tree_map(
        lambda p: StatePartition(spec=p.spec, model=MODEL_LOCAL)
        if p is not None and p.spec == P("model", None) else p,
        comp_parts,
        is_leaf=lambda x: x is None or isinstance(x, StatePartition))
    findings = partition_pass.check_factor_partition(PSPECS, MSPECS, bad)
    assert findings and {f.rule for f in findings} == {"GL402"}


@pytest.mark.slow
def test_real_config_end_to_end_clean():
    """The full per-config pipeline (partition + jaxpr passes + rank
    staircase) on a real reduced architecture produces zero findings —
    the same invocation the CI static-analysis job runs for all ten."""
    from repro.analysis.findings import Report
    from repro.analysis import lint as L

    for staleness in ("none", "one_step"):
        rep = Report()
        L.run_config(rep, "qwen3_4b", staleness=staleness)
        assert rep.findings == [], [str(f) for f in rep.findings]
