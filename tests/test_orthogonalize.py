import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.orthogonalize import cholesky_qr, gram_schmidt

jax.config.update("jax_enable_x64", False)


@settings(deadline=None, max_examples=25)
@given(
    n=st.integers(4, 96),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_schmidt_orthonormal(n, r, seed):
    r = min(r, n)
    p = jax.random.normal(jax.random.key(seed), (n, r))
    q = gram_schmidt(p)
    gram = np.asarray(q.T @ q)
    np.testing.assert_allclose(gram, np.eye(r), atol=2e-3)


@settings(deadline=None, max_examples=25)
@given(
    n=st.integers(4, 96),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_cholesky_qr_orthonormal(n, r, seed):
    r = min(r, n)
    p = jax.random.normal(jax.random.key(seed), (n, r))
    q = cholesky_qr(p)
    gram = np.asarray(q.T @ q)
    np.testing.assert_allclose(gram, np.eye(r), atol=2e-3)


@pytest.mark.parametrize("orth", [gram_schmidt, cholesky_qr])
def test_span_preserved(orth):
    """orthogonalize(P) must span the same subspace as P (Remark 2:
    orthogonalization is right-multiplication by an invertible R⁻¹)."""
    key = jax.random.key(0)
    p = jax.random.normal(key, (40, 4))
    q = orth(p)
    # project p onto span(q): should reconstruct p exactly
    coeff = q.T @ p
    np.testing.assert_allclose(np.asarray(q @ coeff), np.asarray(p), atol=1e-4)


def test_batched_shapes():
    key = jax.random.key(1)
    p = jax.random.normal(key, (3, 5, 32, 2))
    for orth in (gram_schmidt, cholesky_qr):
        q = orth(p)
        assert q.shape == p.shape
        gram = jnp.einsum("...nr,...ns->...rs", q, q)
        np.testing.assert_allclose(
            np.asarray(gram), np.broadcast_to(np.eye(2), (3, 5, 2, 2)), atol=2e-3)


def test_gs_cholqr_agree_up_to_sign():
    """Both produce orthonormal bases of the same span; columns may differ
    only by an orthogonal transform — check the projection operators match."""
    key = jax.random.key(2)
    p = jax.random.normal(key, (64, 4))
    q1, q2 = gram_schmidt(p), cholesky_qr(p)
    proj1 = np.asarray(q1 @ q1.T)
    proj2 = np.asarray(q2 @ q2.T)
    np.testing.assert_allclose(proj1, proj2, atol=1e-3)


def test_tiny_values_stable():
    """Gradients can be ~1e-20 early in training; no NaNs allowed."""
    key = jax.random.key(3)
    p = jax.random.normal(key, (32, 2)) * 1e-20
    for orth in (gram_schmidt, cholesky_qr):
        q = orth(p)
        assert bool(jnp.all(jnp.isfinite(q)))
