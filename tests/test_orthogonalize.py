import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.orthogonalize import cholesky_qr, gram_schmidt, gs_cholqr

jax.config.update("jax_enable_x64", False)

ULP = float(jnp.finfo(jnp.float32).eps)


@settings(deadline=None, max_examples=25)
@given(
    n=st.integers(4, 96),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_schmidt_orthonormal(n, r, seed):
    r = min(r, n)
    p = jax.random.normal(jax.random.key(seed), (n, r))
    q = gram_schmidt(p)
    gram = np.asarray(q.T @ q)
    np.testing.assert_allclose(gram, np.eye(r), atol=2e-3)


@settings(deadline=None, max_examples=25)
@given(
    n=st.integers(4, 96),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_cholesky_qr_orthonormal(n, r, seed):
    r = min(r, n)
    p = jax.random.normal(jax.random.key(seed), (n, r))
    q = cholesky_qr(p)
    gram = np.asarray(q.T @ q)
    np.testing.assert_allclose(gram, np.eye(r), atol=2e-3)


@pytest.mark.parametrize("orth", [gram_schmidt, cholesky_qr])
def test_span_preserved(orth):
    """orthogonalize(P) must span the same subspace as P (Remark 2:
    orthogonalization is right-multiplication by an invertible R⁻¹)."""
    key = jax.random.key(0)
    p = jax.random.normal(key, (40, 4))
    q = orth(p)
    # project p onto span(q): should reconstruct p exactly
    coeff = q.T @ p
    np.testing.assert_allclose(np.asarray(q @ coeff), np.asarray(p), atol=1e-4)


def test_batched_shapes():
    key = jax.random.key(1)
    p = jax.random.normal(key, (3, 5, 32, 2))
    for orth in (gram_schmidt, cholesky_qr):
        q = orth(p)
        assert q.shape == p.shape
        gram = jnp.einsum("...nr,...ns->...rs", q, q)
        np.testing.assert_allclose(
            np.asarray(gram), np.broadcast_to(np.eye(2), (3, 5, 2, 2)), atol=2e-3)


def test_gs_cholqr_agree_up_to_sign():
    """Both produce orthonormal bases of the same span; columns may differ
    only by an orthogonal transform — check the projection operators match."""
    key = jax.random.key(2)
    p = jax.random.normal(key, (64, 4))
    q1, q2 = gram_schmidt(p), cholesky_qr(p)
    proj1 = np.asarray(q1 @ q1.T)
    proj2 = np.asarray(q2 @ q2.T)
    np.testing.assert_allclose(proj1, proj2, atol=1e-3)


def test_tiny_values_stable():
    """Gradients can be ~1e-20 early in training; no NaNs allowed."""
    key = jax.random.key(3)
    p = jax.random.normal(key, (32, 2)) * 1e-20
    for orth in (gram_schmidt, cholesky_qr, gs_cholqr):
        q = orth(p)
        assert bool(jnp.all(jnp.isfinite(q)))


# ---------------------------------------------------------------------------
# determinism / stability properties of the hardened Gram-Schmidt (ISSUE 6):
# orthonormality at dtype-ULP tolerance, idempotence, bounded response to
# ULP-perturbed inputs, exact zeros (never NaN) on rank-deficient input,
# and exact scale invariance.
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=25)
@given(
    n=st.integers(8, 96),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_schmidt_orthonormal_ulp_tolerance(n, r, seed):
    """Well-conditioned gaussian input: ‖QᵀQ − I‖_max within a dtype-ULP
    budget, far tighter than the legacy 2e-3 check above."""
    r = min(r, n)
    p = jax.random.normal(jax.random.key(seed), (n, r))
    q = gram_schmidt(p)
    gram = np.asarray(q.T @ q)
    assert np.abs(gram - np.eye(r)).max() <= 64 * r * ULP


@settings(deadline=None, max_examples=25)
@given(
    n=st.integers(8, 96),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_schmidt_idempotent(n, r, seed):
    """orth(orth(P)) ≈ orth(P): an already-orthonormal basis passes through
    with at most ULP-level renormalization touch-up per column."""
    r = min(r, n)
    p = jax.random.normal(jax.random.key(seed), (n, r))
    q1 = gram_schmidt(p)
    q2 = gram_schmidt(q1)
    assert np.abs(np.asarray(q2 - q1)).max() <= 64 * r * ULP


@settings(deadline=None, max_examples=25)
@given(
    n=st.integers(8, 96),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_schmidt_ulp_perturbation_not_amplified(n, r, seed):
    """The drift bug (docs/checkpoint.md): rank-dependent all-reduce seeds
    ULP-level input differences which the legacy orthogonalizer amplified to
    5e-1 factor divergence.  On well-conditioned input, a 1-ULP relative
    perturbation must stay O(√ULP) in the output, not O(1)."""
    r = min(r, n)
    p = jax.random.normal(jax.random.key(seed), (n, r))
    bump = 1.0 + jnp.where(
        jax.random.bernoulli(jax.random.key(seed + 1), 0.5, p.shape),
        ULP, 0.0)
    q1 = gram_schmidt(p)
    q2 = gram_schmidt(p * bump)
    assert np.abs(np.asarray(q2 - q1)).max() <= 1e-3


def test_gram_schmidt_scale_invariant_bitexact():
    """Power-of-two rescaling (including deep-underflow scales the old
    absolute-epsilon guard mangled) leaves the output bit-identical."""
    p = jax.random.normal(jax.random.key(7), (48, 4))
    q = np.asarray(gram_schmidt(p))
    for c in (2.0**-40, 2.0**-10, 2.0**20):
        np.testing.assert_array_equal(np.asarray(gram_schmidt(p * c)), q)


def test_gram_schmidt_zero_columns_exact_zero():
    """All-zero columns come back as exact zeros — not NaN, not noise."""
    p = jax.random.normal(jax.random.key(8), (32, 4))
    p = p.at[:, 1].set(0.0).at[:, 3].set(0.0)
    q = np.asarray(gram_schmidt(p))
    assert np.isfinite(q).all()
    np.testing.assert_array_equal(q[:, 1], np.zeros(32))
    np.testing.assert_array_equal(q[:, 3], np.zeros(32))
    # the surviving columns are still orthonormal
    live = q[:, [0, 2]]
    np.testing.assert_allclose(live.T @ live, np.eye(2), atol=64 * ULP)


def test_gram_schmidt_rank_deficient_no_nan():
    """Numerically dependent columns (the warm-started converged case) are
    zeroed, never normalized noise: output is finite and QᵀQ is a projector."""
    key = jax.random.key(9)
    base = jax.random.normal(key, (64, 2))
    coeff = jax.random.normal(jax.random.key(10), (2, 6))
    p = base @ coeff                     # rank 2 embedded in 6 columns
    q = gram_schmidt(p)
    assert bool(jnp.all(jnp.isfinite(q)))
    gram = np.asarray(q.T @ q)
    np.testing.assert_allclose(gram @ gram, gram, atol=1e-4)
    # exactly rank-2 output: 2 unit columns, 4 exact-zero columns
    norms = np.sort(np.diag(gram))
    np.testing.assert_allclose(norms[:4], np.zeros(4), atol=0)
    np.testing.assert_allclose(norms[4:], np.ones(2), atol=64 * ULP)


def test_gs_cholqr_matches_gs_when_well_conditioned():
    """The fallback orthogonalizer passes Gram-Schmidt output through
    bit-exactly whenever GS already met its ULP budget."""
    p = jax.random.normal(jax.random.key(11), (64, 4))
    np.testing.assert_array_equal(np.asarray(gs_cholqr(p)),
                                  np.asarray(gram_schmidt(p)))


def test_gs_cholqr_selects_cholqr_on_ill_conditioned():
    """When GS exceeds its ULP orthogonality budget (κ ~ 1e4: sequential
    MGS loses orthogonality as κ·ulp) the fallback must actually switch to
    the CholeskyQR2 result — bit-equal to calling cholesky_qr directly —
    and stay finite."""
    key = jax.random.key(12)
    u = jax.random.normal(key, (64, 4))
    p = u @ jnp.diag(jnp.array([1.0, 1.0, 1.0, 1e-4]))
    p = p.at[:, 3].add(p[:, 0])          # col3 ≈ col0 + 1e-4·noise
    q_gs = gram_schmidt(p)
    gram = np.asarray(q_gs.T @ q_gs)
    err = np.abs(gram @ gram - gram).max()
    assert err > 1024 * ULP, "fixture no longer ill-conditioned enough"
    q = gs_cholqr(p)
    assert bool(jnp.all(jnp.isfinite(q)))
    np.testing.assert_array_equal(np.asarray(q), np.asarray(cholesky_qr(p)))
