"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.key(0)


@settings(deadline=None, max_examples=20)
@given(
    n=st.integers(1, 300),
    k=st.integers(1, 300),
    r=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
def test_project_matches_ref(n, k, r, seed):
    key = jax.random.key(seed)
    m = jax.random.normal(key, (n, k))
    q = jax.random.normal(jax.random.fold_in(key, 1), (k, r))
    got = ops.lowrank_project(m, q, block_n=64, block_k=64)
    want = ref.lowrank_project(m, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


@settings(deadline=None, max_examples=20)
@given(
    n=st.integers(1, 300),
    k=st.integers(1, 300),
    r=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
def test_backproject_matches_ref(n, k, r, seed):
    key = jax.random.key(seed)
    m = jax.random.normal(key, (n, k))
    p = jax.random.normal(jax.random.fold_in(key, 1), (n, r))
    got = ops.lowrank_backproject(m, p, block_n=64, block_k=64)
    want = ref.lowrank_backproject(m, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,r", [((128, 128), 2), ((257, 511), 4),
                                     ((64, 1024), 1)])
def test_project_dtypes(shape, r, dtype):
    m = jax.random.normal(KEY, shape).astype(dtype)
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (shape[1], r)).astype(dtype)
    got = ops.lowrank_project(m, q)
    want = ref.lowrank_project(m, q)
    atol = 1e-3 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol, rtol=0.05)


@pytest.mark.parametrize("batch", [(), (3,), (2, 4)])
def test_batched(batch):
    shape = batch + (96, 80)
    m = jax.random.normal(KEY, shape)
    q = jax.random.normal(jax.random.fold_in(KEY, 1), batch + (80, 2))
    got = ops.lowrank_project(m, q, block_n=32, block_k=32)
    want = ref.lowrank_project(m, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@settings(deadline=None, max_examples=12)
@given(
    n=st.integers(2, 200),
    m=st.integers(2, 200),
    r=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_ef_apply_matches_ref(n, m, r, seed):
    key = jax.random.key(seed)
    x = jax.random.normal(key, (n, m))
    mom = jax.random.normal(jax.random.fold_in(key, 1), (n, m))
    p = jax.random.normal(jax.random.fold_in(key, 2), (n, r))
    q = jax.random.normal(jax.random.fold_in(key, 3), (m, r))
    got_x, got_m = ops.ef_apply(x, mom, p, q, 0.05, 0.9, block_n=64, block_m=64)
    want_x, want_m = ref.ef_apply(x, mom, p, q, 0.05, 0.9)
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(want_x),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m),
                               atol=1e-4, rtol=1e-4)


def test_powersgd_pallas_path_matches_jnp_path():
    from repro.core import matrixize
    from repro.core.compressors import PowerSGDCompressor

    grads = {"w": jax.random.normal(KEY, (257, 130))}
    specs = {"w": matrixize.default_spec(grads["w"])}
    shapes = {"w": jax.ShapeDtypeStruct((257, 130), jnp.float32)}
    a = PowerSGDCompressor(rank=2)
    b = PowerSGDCompressor(rank=2, use_pallas=True)
    oa = a.step(grads, a.init(shapes, specs, KEY), specs, key=KEY)
    ob = b.step(grads, b.init(shapes, specs, KEY), specs, key=KEY)
    np.testing.assert_allclose(np.asarray(oa.agg["w"]), np.asarray(ob.agg["w"]),
                               atol=1e-4)
