"""The bucketed batched-compression engine (docs/paper_map.md, design note).

Covers the ISSUE acceptance criteria:
  * bucket-planner unit tests (grouping, padding tolerance, determinism),
  * batched-vs-per-leaf numerical equivalence on a mixed-shape tree
    (1-D, conv, layer-stacked and non-compressible leaves),
  * exactly 2 data-axis collectives per step regardless of matrix count,
  * batched (B, n, m) Pallas kernels vs the ref.py oracle in interpret mode.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import matrixize
from repro.core.compressors import PowerSGDCompressor
from repro.core.dist import CollectiveStats, MeshCtx

KEY = jax.random.key(0)


# ---------------------------------------------------------------------------
# bucket planner
# ---------------------------------------------------------------------------

def test_planner_groups_equal_shapes():
    plan = matrixize.plan_buckets([(1, 64, 32), (1, 64, 32), (4, 64, 32)])
    assert len(plan.buckets) == 1
    b = plan.buckets[0]
    assert (b.n, b.m) == (64, 32)
    assert b.count == 6
    assert [e.offset for e in b.entries] == [0, 1, 2]


def test_planner_pads_within_tolerance():
    # (60, 30) padded into the (64, 32) bucket: waste 2048/1800 - 1 ≈ 13.8%
    plan = matrixize.plan_buckets([(1, 64, 32), (1, 60, 30)], tolerance=0.25)
    assert len(plan.buckets) == 1
    # with zero tolerance they split
    plan0 = matrixize.plan_buckets([(1, 64, 32), (1, 60, 30)], tolerance=0.0)
    assert len(plan0.buckets) == 2


def test_planner_separates_distant_shapes():
    plan = matrixize.plan_buckets([(1, 64, 32), (1, 8, 8)], tolerance=0.25)
    assert len(plan.buckets) == 2


def test_planner_skips_none_and_keeps_indices():
    plan = matrixize.plan_buckets([None, (2, 16, 8), None, (1, 16, 8)])
    assert len(plan.buckets) == 1
    b = plan.buckets[0]
    assert [e.index for e in b.entries] == [1, 3]
    assert [e.offset for e in b.entries] == [0, 2]
    b_id, e = plan.entry_for(3)
    assert b_id == 0 and e.offset == 2 and e.count == 1


def test_planner_never_crops():
    # a taller-but-narrower shape must not be forced into a wider bucket
    plan = matrixize.plan_buckets([(1, 40, 40), (1, 100, 10)], tolerance=10.0)
    for b in plan.buckets:
        for e in b.entries:
            assert e.n <= b.n and e.m <= b.m


def test_pack_unpack_roundtrip():
    arrays = {0: jax.random.normal(KEY, (2, 10, 6)),
              1: jax.random.normal(jax.random.fold_in(KEY, 1), (1, 8, 5))}
    plan = matrixize.plan_buckets([(2, 10, 6), (1, 8, 5)], tolerance=1.0)
    assert len(plan.buckets) == 1
    b = plan.buckets[0]
    slab = matrixize.pack_matrices(b, arrays)
    assert slab.shape == (3, b.n, b.m)
    for e in b.entries:
        got = matrixize.unpack_entry(slab, e, e.n, e.m)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(arrays[e.index]))


# ---------------------------------------------------------------------------
# engine equivalence on a mixed-shape tree
# ---------------------------------------------------------------------------

def _mixed_tree():
    """Matrices in two nearby shape clusters, a conv kernel, a layer-stacked
    leaf, and non-compressible 1-D leaves."""
    k = KEY
    grads = {
        "w1": jax.random.normal(k, (64, 32)),
        "w2": jax.random.normal(jax.random.fold_in(k, 1), (60, 30)),
        "wide": jax.random.normal(jax.random.fold_in(k, 2), (16, 256)),
        "conv": jax.random.normal(jax.random.fold_in(k, 3), (16, 8, 3, 3)),
        "stack": jax.random.normal(jax.random.fold_in(k, 4), (3, 20, 10)),
        "bias": jnp.linspace(-1.0, 1.0, 7),
        "scale": jnp.ones((5,)),
    }
    specs = {
        "w1": matrixize.default_spec(grads["w1"]),
        "w2": matrixize.default_spec(grads["w2"]),
        "wide": matrixize.default_spec(grads["wide"]),
        "conv": matrixize.default_spec(grads["conv"]),
        "stack": matrixize.MatrixSpec("matrix", 1),
        "bias": matrixize.default_spec(grads["bias"]),
        "scale": matrixize.default_spec(grads["scale"]),
    }
    shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads)
    return grads, specs, shapes


@pytest.mark.parametrize("kw", [
    {},
    {"warm_start": False},
    {"num_iters": 2},
    {"error_mode": "local"},
    {"orthogonalizer": "cholesky_qr"},
    {"use_pallas": True},
])
def test_bucketed_matches_per_leaf(kw):
    grads, specs, shapes = _mixed_tree()
    a = PowerSGDCompressor(rank=2, bucketing="off", **kw)
    b = PowerSGDCompressor(rank=2, bucketing="auto", **kw)
    oa = a.step(grads, a.init(shapes, specs, KEY), specs, key=KEY)
    ob = b.step(grads, b.init(shapes, specs, KEY), specs, key=KEY)
    for name in grads:
        np.testing.assert_allclose(np.asarray(oa.agg[name]),
                                   np.asarray(ob.agg[name]),
                                   atol=1e-5, err_msg=f"agg[{name}] {kw}")
        np.testing.assert_allclose(np.asarray(oa.recon[name]),
                                   np.asarray(ob.recon[name]),
                                   atol=1e-5, err_msg=f"recon[{name}] {kw}")
    for name in ("w1", "w2", "wide", "conv", "stack"):
        np.testing.assert_allclose(np.asarray(oa.state[name]),
                                   np.asarray(ob.state[name]),
                                   atol=1e-5, err_msg=f"state[{name}] {kw}")
    assert oa.state["bias"] is None and ob.state["bias"] is None
    assert oa.bits_per_worker == ob.bits_per_worker


def test_bucketed_warm_start_improves_over_steps():
    grads, specs, shapes = _mixed_tree()
    comp = PowerSGDCompressor(rank=2)
    state = comp.init(shapes, specs, KEY)
    errs = []
    for _ in range(6):
        out = comp.step(grads, state, specs, key=KEY)
        state = out.state
        errs.append(float(jnp.linalg.norm(grads["w1"] - out.agg["w1"])))
    assert errs[-1] < errs[0]


def test_bucketed_multiworker_matches_per_leaf():
    """pmean_flat under a mapped data axis == per-leaf pmeans (linearity)."""
    W = 4
    grads, specs, shapes = _mixed_tree()
    stacks = jax.tree_util.tree_map(
        lambda g: jnp.stack([g + 0.1 * jax.random.normal(
            jax.random.key(i), g.shape) for i in range(W)]), grads)
    ctx = MeshCtx(data_axes=("dp",))
    outs = {}
    for mode in ("off", "auto"):
        comp = PowerSGDCompressor(rank=2, bucketing=mode)
        state = comp.init(shapes, specs, KEY)

        def one(tree):
            out = comp.step(tree, state, specs, ctx=ctx, key=KEY)
            return out.agg

        outs[mode] = jax.vmap(one, axis_name="dp")(stacks)
    for name in grads:
        np.testing.assert_allclose(np.asarray(outs["off"][name]),
                                   np.asarray(outs["auto"][name]),
                                   atol=1e-5, err_msg=name)


# ---------------------------------------------------------------------------
# the ISSUE acceptance criterion: exactly 2 data-axis collectives per step
# ---------------------------------------------------------------------------

def _quickstart_model():
    """Mirror of the multi-layer model in examples/quickstart.py §5."""
    key = jax.random.key(7)
    dims = [(64, 32), (32, 32), (32, 16), (30, 16), (16, 4)]
    grads, specs = {}, {}
    for i, (n, m) in enumerate(dims):
        w = jax.random.normal(jax.random.fold_in(key, i), (n, m))
        grads[f"layer{i}/w"] = w
        specs[f"layer{i}/w"] = matrixize.default_spec(w)
        b = jax.random.normal(jax.random.fold_in(key, 100 + i), (m,))
        grads[f"layer{i}/b"] = b
        specs[f"layer{i}/b"] = matrixize.default_spec(b)
    shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads)
    return grads, specs, shapes


def test_bucketed_step_issues_exactly_two_collectives():
    grads, specs, shapes = _quickstart_model()
    stats = CollectiveStats()
    comp = PowerSGDCompressor(rank=2, bucketing="auto")
    state = comp.init(shapes, specs, KEY)
    out_b = comp.step(grads, state, specs, ctx=MeshCtx(stats=stats), key=KEY)
    # one flat P (+ vector leaves), one flat Q — independent of matrix count
    assert stats.data_collectives == 2, stats.sizes

    per_leaf_stats = CollectiveStats()
    per_leaf = PowerSGDCompressor(rank=2, bucketing="off")
    out_l = per_leaf.step(grads, per_leaf.init(shapes, specs, KEY), specs,
                          ctx=MeshCtx(stats=per_leaf_stats), key=KEY)
    # per-leaf: 2 per weight matrix + 1 per vector leaf
    assert per_leaf_stats.data_collectives == 2 * 5 + 5

    # ...and the aggregated update matches the per-leaf path (float32)
    for name in grads:
        np.testing.assert_allclose(np.asarray(out_l.agg[name]),
                                   np.asarray(out_b.agg[name]),
                                   atol=1e-5, err_msg=name)


def test_collective_count_independent_of_matrix_count():
    for n_layers in (1, 3, 8):
        key = jax.random.key(n_layers)
        grads = {f"w{i}": jax.random.normal(jax.random.fold_in(key, i),
                                            (32 + i, 16))
                 for i in range(n_layers)}
        specs = {k: matrixize.default_spec(v) for k, v in grads.items()}
        shapes = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads)
        stats = CollectiveStats()
        comp = PowerSGDCompressor(rank=2)
        comp.step(grads, comp.init(shapes, specs, KEY), specs,
                  ctx=MeshCtx(stats=stats), key=KEY)
        assert stats.data_collectives == 2


def test_num_iters_collective_count():
    grads, specs, shapes = _quickstart_model()
    stats = CollectiveStats()
    comp = PowerSGDCompressor(rank=2, warm_start=False, num_iters=3)
    comp.step(grads, comp.init(shapes, specs, KEY), specs,
              ctx=MeshCtx(stats=stats), key=KEY)
    assert stats.data_collectives == 6  # 2 per power iteration


# ---------------------------------------------------------------------------
# batched Pallas kernels (interpret mode) vs ref oracle
# ---------------------------------------------------------------------------

def test_batched_kernel_project_matches_ref():
    from repro.kernels import ops, ref

    for b, n, k, r in [(1, 96, 80, 2), (5, 96, 80, 2), (3, 257, 130, 4),
                       (2, 33, 500, 1)]:
        m = jax.random.normal(jax.random.fold_in(KEY, b * n), (b, n, k))
        q = jax.random.normal(jax.random.fold_in(KEY, b * n + 1), (b, k, r))
        got = ops.lowrank_project(m, q, block_n=64, block_k=64)
        want = ref.lowrank_project(m, q)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-3, rtol=1e-3)


def test_batched_kernel_backproject_matches_ref():
    from repro.kernels import ops, ref

    for b, n, k, r in [(1, 96, 80, 2), (5, 96, 80, 2), (3, 257, 130, 4),
                       (2, 33, 500, 1)]:
        m = jax.random.normal(jax.random.fold_in(KEY, b * k), (b, n, k))
        p = jax.random.normal(jax.random.fold_in(KEY, b * k + 1), (b, n, r))
        got = ops.lowrank_backproject(m, p, block_n=64, block_k=64)
        want = ref.lowrank_backproject(m, p)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-3, rtol=1e-3)


def test_batched_kernel_higher_rank_batch_dims():
    from repro.kernels import ops, ref

    m = jax.random.normal(KEY, (2, 3, 40, 24))
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 3, 24, 2))
    got = ops.lowrank_project(m, q, block_n=32, block_k=32)
    want = ref.lowrank_project(m, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


# ---------------------------------------------------------------------------
# pmean_flat unit behaviour
# ---------------------------------------------------------------------------

def test_pmean_flat_identity_roundtrip():
    parts = [jax.random.normal(KEY, (3, 4)),
             jnp.arange(5.0),
             jax.random.normal(jax.random.fold_in(KEY, 1), (2, 2, 2))]
    stats = CollectiveStats()
    out = MeshCtx(stats=stats).pmean_flat(parts)
    assert stats.data_collectives == 1
    assert stats.sizes == [12 + 5 + 8]
    for a, b in zip(parts, out):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert MeshCtx().pmean_flat([]) == []


def test_pmean_flat_means_over_mapped_axis():
    W = 4
    xs = jnp.stack([jnp.full((3,), float(i)) for i in range(W)])
    ys = jnp.stack([jnp.full((2, 2), float(10 * i)) for i in range(W)])
    ctx = MeshCtx(data_axes=("dp",))

    def one(x, y):
        a, b = ctx.pmean_flat([x, y])
        return a, b

    a, b = jax.vmap(one, axis_name="dp")(xs, ys)
    np.testing.assert_allclose(np.asarray(a[0]), np.full((3,), 1.5))
    np.testing.assert_allclose(np.asarray(b[0]), np.full((2, 2), 15.0))
