"""Docs health (ISSUE 4 CI satellite): every relative link in README and
docs/ resolves, every fenced python snippet at least compiles, the README
autotuner snippet stays mirrored in quickstart §7, and the committed
adaptive_rank_profile.json artifact actually shows the acceptance claim
(an adaptive schedule ≥25% fewer compressed floats than fixed rank-4 at
equal-or-better final loss)."""

import ast
import json
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = sorted([ROOT / "README.md", *(ROOT / "docs").glob("*.md")])

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)[^)]*\)")
FENCE_RE = re.compile(r"```(\w*)\n(.*?)```", re.S)
# `path/to/file.py`-style inline-code references
PATH_RE = re.compile(
    r"`((?:[\w.-]+/)+[\w.-]+\.(?:py|md|json|yml|yaml))(?:::[\w.]+)?`")


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_relative_links_resolve(doc):
    bad = []
    for target in LINK_RE.findall(doc.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if not (doc.parent / target).exists():
            bad.append(target)
    assert not bad, f"{doc.name}: dead relative links {bad}"


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_referenced_repo_paths_exist(doc):
    """`src/...`-style inline-code path mentions must not go stale."""
    bad = []
    for target in PATH_RE.findall(doc.read_text()):
        roots = (doc.parent, ROOT, ROOT / "src" / "repro")  # `core/...` style
        if not any((r / target).exists() for r in roots):
            bad.append(target)
    assert not bad, f"{doc.name}: stale path references {bad}"


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_python_fences_compile(doc):
    """Code snippets in the docs must stay syntactically valid python (the
    cheap half of doctesting; quickstart §7 executes the real thing)."""
    for lang, body in FENCE_RE.findall(doc.read_text()):
        if lang != "python":
            continue
        try:
            ast.parse(body)
        except SyntaxError as e:  # pragma: no cover - failure path
            pytest.fail(f"{doc.name}: python fence does not parse: {e}\n"
                        f"{body[:300]}")


def test_readme_snippet_mirrored_in_quickstart():
    """The README 'Adaptive rank' snippet and quickstart §7 must stay in
    sync on the load-bearing calls."""
    readme = (ROOT / "README.md").read_text()
    quickstart = (ROOT / "examples" / "quickstart.py").read_text()
    for needle in ("autotune.autotune(", "autotune.make_tuned_compressor(",
                   "autotune.apply_plan(", "rank_schedule=",
                   ".controller()", "HardwareModel.from_backend("):
        assert needle in readme, f"README snippet lost {needle!r}"
        assert needle in quickstart, f"quickstart §7 lost {needle!r}"


def test_adaptive_rank_profile_acceptance():
    """The committed artifact must demonstrate the ISSUE 4 claim."""
    path = ROOT / "experiments" / "benchmarks" / "adaptive_rank_profile.json"
    rows = {r["schedule"]: r for r in json.loads(path.read_text())}
    fixed4 = rows["fixed_rank4"]
    up = rows["staircase_up_1_2_4"]
    assert up["eval_loss"] <= fixed4["eval_loss"], (
        "adaptive schedule must reach equal-or-better final loss", rows)
    savings = 1 - (up["compressed_mfloats_total"]
                   / fixed4["compressed_mfloats_total"])
    assert savings >= 0.25, (
        "adaptive schedule must send >=25% fewer compressed floats", savings)
    # and the recorded switch log shows it actually adapted
    assert up["rank_history"].count("@") >= 3


def test_resume_overhead_artifact_and_docs():
    """ISSUE 5 acceptance: the committed resume_overhead.json must show the
    bit-exact full-state resume, and the numbers docs/tuning.md +
    docs/paper_map.md quote must match it."""
    rows = {r["mode"]: r for r in json.loads(
        (ROOT / "experiments" / "benchmarks"
         / "resume_overhead.json").read_text())}
    assert rows["resume_full"]["bitexact_vs_uninterrupted"] is True
    assert (rows["resume_full"]["final_loss_hex"]
            == rows["uninterrupted"]["final_loss_hex"])
    # the degraded restores pay a real (positive) re-absorption transient
    assert rows["resume_drop_ef"]["post_resume_loss_spike"] > 0
    assert rows["resume_drop_warm_start"]["post_resume_loss_spike"] > 0

    tuning = (ROOT / "docs" / "tuning.md").read_text()
    cost = rows["checkpoint_cost"]
    for needle in (f"{cost['ckpt_mb']} MB", f"{cost['save_ms_mean']} ms",
                   f"{cost['restore_ms']} ms",
                   f"{cost['save_overhead_pct_of_train']} %"):
        assert needle in tuning, f"tuning.md stale: {needle!r} not found"
    paper = (ROOT / "docs" / "paper_map.md").read_text()
    for row in ("resume_drop_ef", "resume_drop_warm_start"):
        needle = f"+{rows[row]['post_resume_loss_spike']}"
        assert needle in paper, f"paper_map.md stale: {needle!r} not found"
        assert f"+{rows[row]['post_resume_loss_spike']}" in tuning


def test_overlap_profile_acceptance():
    """ISSUE 8 acceptance: the committed overlap_profile.json must show the
    pipeline hiding ≥80% of modeled comm at the paper's ethernet α-β
    operating points, and the measured stale arms landing within the pinned
    final-loss tolerance of the synchronous baseline (and converging)."""
    rows = json.loads((ROOT / "experiments" / "benchmarks"
                       / "overlap_profile.json").read_text())
    modeled = [r for r in rows if r["arm"] == "modeled" and r["workers"] > 1]
    assert modeled, rows
    for r in modeled:
        assert r["hidden_comm_pct"] >= 80.0, r
        assert r["stale_step_ms"] <= r["sync_step_ms"], r
    measured = [r for r in rows if r["arm"] == "measured_simmesh"]
    by_scenario = {}
    for r in measured:
        by_scenario.setdefault(r["scenario"], {})[r["staleness"]] = r
    assert set(by_scenario) == {"clean", "dropout", "straggler"}
    for scenario, arms in by_scenario.items():
        stale, sync = arms["one_step"], arms["none"]
        gap = stale["final5_loss"] - sync["final5_loss"]
        assert abs(gap) < 0.75, (scenario, gap)
        # and the stale arm genuinely trained
        assert stale["final5_loss"] < stale["first5_loss"] - 0.5, stale


def test_tuning_md_staleness_table_matches_artifact():
    """The staleness section of docs/tuning.md quotes overlap_profile.json —
    modeled comm/hidden percentages and measured final losses must match."""
    doc = (ROOT / "docs" / "tuning.md").read_text()
    rows = json.loads((ROOT / "experiments" / "benchmarks"
                       / "overlap_profile.json").read_text())
    for r in rows:
        if r["arm"] == "modeled" and r["workers"] > 1:
            assert f"{r['modeled_comm_ms']} ms" in doc, r
            assert f"{r['step_speedup_pct']}%" in doc, r
        elif r["arm"] == "measured_simmesh":
            assert str(r["final5_loss"]) in doc, r


def test_quantized_wire_artifact_and_docs():
    """ISSUE 9 acceptance: the committed zoo_transport_profile.json must
    show the powersgd int8/int4 rows moving ≥4x fewer wire bytes than the
    float32 baseline at a final loss within the pinned tolerance, and the
    numbers docs/tuning.md quotes must match the artifact."""
    rows = json.loads((ROOT / "experiments" / "benchmarks"
                       / "zoo_transport_profile.json").read_text())
    psgd = {r["wire_dtype"]: r for r in rows
            if r["algorithm"] == "powersgd" and "wire_dtype" in r}
    assert {"float32", "int8", "int4"} <= set(psgd), sorted(psgd)
    # >=4x fewer wire bytes (int8 is allowed the toy-tree scale sidecar)
    assert psgd["int4"]["wire_bytes_ratio_vs_float32"] >= 4.0, psgd["int4"]
    assert psgd["int8"]["wire_bytes_ratio_vs_float32"] >= 3.9, psgd["int8"]
    # ... at a final loss within the pinned tolerance of the float32 wire
    # (same tolerance family as tests/sim/test_zoo_conformance.py)
    base = psgd["float32"]["final5_loss"]
    assert abs(psgd["int8"]["final5_loss"] - base) < 0.5, psgd["int8"]
    assert abs(psgd["int4"]["final5_loss"] - base) < 0.5, psgd["int4"]
    # every quantized arm genuinely trained (MarkovLM starts near ln(V)≈5.6)
    for wd in ("float32", "int8", "int4"):
        assert psgd[wd]["final5_loss"] < 4.5, psgd[wd]

    doc = (ROOT / "docs" / "tuning.md").read_text()
    for wd in ("float32", "int8", "int4"):
        r = psgd[wd]
        assert str(r["reduce_kb_per_step"]) in doc, r
        assert f"{r['modeled_comm_ms_w16']} ms" in doc, r
        assert str(r["final5_loss"]) in doc, r
    gather = {(r["algorithm"], r.get("wire_dtype")): r for r in rows}
    for key in (("sign_norm", "int8"), ("top_k", "int4")):
        r = gather[key]
        assert str(r["gather_kb_per_step_w16"]) in doc, r
        assert f"{r['wire_bytes_ratio_vs_float32']}" in doc, r
    paper = (ROOT / "docs" / "paper_map.md").read_text()
    assert "quantize-before-reduce" in paper
    assert "quantize-before-gather" in paper


def test_tuning_md_tables_match_artifacts():
    """docs/tuning.md quotes measured numbers — they must match the JSONs
    they claim to come from (the doc names its sources)."""
    doc = (ROOT / "docs" / "tuning.md").read_text()
    rows = {r["schedule"]: r for r in json.loads(
        (ROOT / "experiments" / "benchmarks"
         / "adaptive_rank_profile.json").read_text())}
    for sched in ("fixed_rank1", "fixed_rank2", "fixed_rank4",
                  "staircase_up_1_2_4", "staircase_down_4_2_1"):
        loss = f"{rows[sched]['eval_loss']:.4f}"
        assert loss in doc, (
            f"tuning.md stale: {sched} eval_loss {loss} not found")
    comm = json.loads((ROOT / "experiments" / "benchmarks"
                       / "comm_profile.json").read_text())
    by_engine = {r["engine"]: r for r in comm}
    assert str(by_engine["per_leaf"]["collectives_per_step"]) in doc
    assert str(by_engine["bucketed"]["collectives_per_step"]) in doc
