"""End-to-end behaviour: the whole stack (data → model → EF-PowerSGD →
update) actually learns, and serving actually serves."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.dist import SINGLE
from repro.data.synthetic import MarkovLM
from repro.launch.train import TrainHyper, make_train_step
from repro.models import model as model_lib

KEY = jax.random.key(0)


def _train(arch, steps, compressor=None, lr=0.1, seq=64, batch=8):
    cfg = get_config(arch, reduced=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    hyper = TrainHyper(lr=lr, q_chunk=32, warmup_steps=5, remat=False,
                       weight_decay=0.0)
    step_fn, _, init_state = make_train_step(cfg, mesh, hyper,
                                             compressor=compressor)
    # order-1 with 8 token clusters: learnable in tens of steps AND the
    # transition table has ~8 distinct rows, so gradients are low-rank —
    # the regime the paper targets (decaying gradient spectrum, §2)
    data = MarkovLM(vocab=cfg.vocab_size, seed=0, order=1, clusters=8)
    it = data.batches(batch, seq)
    losses = []
    with jax.set_mesh(mesh):
        params, ef = init_state(KEY)
        for _ in range(steps):
            b = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, ef, met = step_fn(params, ef, b, KEY)
            losses.append(float(met["lm_loss"]))
    return losses, params, cfg


def test_powersgd_training_learns():
    losses, _, _ = _train("llama3-8b", steps=40)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.5, (first, last)


def test_powersgd_tracks_identity_baseline():
    """The paper's central claim at small scale: rank-2 PowerSGD reaches
    quality close to uncompressed SGD in the same number of steps.

    Calibration (measured on this exact setup, deterministic seed): the
    PowerSGD-vs-SGD loss gap is a warm-start transient, not a regression —
    window-of-5 mean gap is 0.52 at step 60, 0.12 at step 100, 0.09 at
    step 140 (and shrinks with rank: 0.08 at step 60 for rank 4).  The
    original 60-step/0.5 threshold sat exactly on that transient's edge
    and failed by 0.016.  We assert where the claim actually lives: after
    the low-rank subspace has locked on (100 steps), with a 0.4 threshold
    ≈ 3.5× the measured gap."""
    from repro.core.compressors import IdentityCompressor

    losses_psgd, _, _ = _train("llama3-8b", steps=100)
    losses_sgd, _, _ = _train("llama3-8b", steps=100,
                              compressor=IdentityCompressor())
    assert np.mean(losses_psgd[-5:]) < np.mean(losses_sgd[-5:]) + 0.4


def test_train_then_serve_roundtrip():
    losses, params, cfg = _train("llama3-8b", steps=10)
    b = 2
    cache = model_lib.init_cache(cfg, 1, b, 32)
    tok = jnp.zeros((b, 1), jnp.int32)
    outs = []
    for pos in range(8):
        tok, logits, cache = model_lib.decode_step(
            params, cache, tok, jnp.int32(pos), cfg, SINGLE)
        outs.append(np.asarray(tok))
    assert all(o.shape == (b, 1) for o in outs)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_checkpoint_resume_bitexact(tmp_path):
    """Stop/restore mid-training: the resumed run must continue bit-exactly
    (params, EF error, momentum, Q factors are all checkpointed)."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    cfg = get_config("yi-6b", reduced=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    hyper = TrainHyper(lr=0.1, q_chunk=32, warmup_steps=5, remat=False)
    step_fn, _, init_state = make_train_step(cfg, mesh, hyper)
    data = MarkovLM(vocab=cfg.vocab_size, seed=0)
    it = data.batches(4, 32)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()} for _ in range(6)]

    with jax.set_mesh(mesh):
        params, ef = init_state(KEY)
        for b in batches[:3]:
            params, ef, _ = step_fn(params, ef, b, KEY)
        save_checkpoint(str(tmp_path), 3, {"params": params, "ef": ef})
        for b in batches[3:]:
            params, ef, _ = step_fn(params, ef, b, KEY)
        final_direct = params

        restored, _ = restore_checkpoint(
            str(tmp_path), {"params": params, "ef": ef})
        params2, ef2 = restored["params"], restored["ef"]
        for b in batches[3:]:
            params2, ef2, _ = step_fn(params2, ef2, b, KEY)

    for a, b in zip(jax.tree_util.tree_leaves(final_direct),
                    jax.tree_util.tree_leaves(params2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resnet_and_lstm_train():
    """The paper's own benchmark models learn under EF-PowerSGD."""
    from repro.core import error_feedback as ef_lib
    from repro.core.compressors import PowerSGDCompressor
    from repro.data.synthetic import GaussianClusters
    from repro.models import lstm, resnet

    # ResNet (scaled down) on Gaussian clusters
    rcfg = resnet.ResNetConfig(width=8, blocks=(1, 1), num_classes=4)
    params, bn_state = resnet.init(KEY, rcfg)
    specs = resnet.mspecs(params)
    comp = PowerSGDCompressor(rank=2)
    state = ef_lib.init_state(comp, params, specs, KEY)
    data = GaussianClusters(num_classes=4, image_size=8, noise=0.5)
    accs = []

    @jax.jit
    def grad_fn(p, bs, batch):
        return jax.grad(resnet.loss_fn, has_aux=True)(p, bs, batch, rcfg)

    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in data.sample(64, i).items()}
        grads, (bn_state, met) = grad_fn(params, bn_state, batch)
        params, state, _ = ef_lib.apply_updates(
            comp, params, grads, state, specs, lr=0.05, momentum=0.9, key=KEY)
        accs.append(float(met["acc"]))
    assert np.mean(accs[-5:]) > np.mean(accs[:5]) + 0.2, accs

    # LSTM LM on the (order-1) Markov stream.  tied embeddings require
    # embed == hidden; order-1 keeps the task learnable within ~100 steps.
    lcfg = lstm.LSTMConfig(vocab=32, embed=64, hidden=64, layers=2,
                           init_scale=0.15)
    lp = lstm.init(KEY, lcfg)
    lspecs = lstm.mspecs(lp)
    lstate = ef_lib.init_state(comp, lp, lspecs, KEY)
    mdata = MarkovLM(vocab=32, seed=1, order=1)
    it = mdata.batches(16, 32)

    @jax.jit
    def lgrad(p, batch):
        return jax.grad(lstm.loss_fn, has_aux=True)(p, batch, lcfg)

    losses = []
    for i in range(100):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        grads, met = lgrad(lp, batch)
        lp, lstate, _ = ef_lib.apply_updates(
            comp, lp, grads, lstate, lspecs, lr=0.8, momentum=0.9, key=KEY)
        losses.append(float(met["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses
