import os
import signal
import sys
import threading

import pytest

# make `import repro` work regardless of how pytest is invoked
SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.


# ---------------------------------------------------------------------------
# per-test timeout (no pytest-timeout dependency — the container is minimal)
# ---------------------------------------------------------------------------
#
# Default comes from the `test_timeout` ini option (pyproject.toml); override
# per test with `@pytest.mark.timeout(seconds)`.  0 disables.  Implemented
# with SIGALRM (main thread, POSIX only).  Scope caveat: a Python signal
# handler runs between bytecodes, so this interrupts Python-level hangs
# (stuck loops, subprocess waits, step-by-step jax dispatch) but NOT a call
# blocked inside C++ that never returns to the interpreter — those still
# need the CI job-level timeout as the backstop.

def pytest_addoption(parser):
    parser.addini("test_timeout",
                  "per-test timeout in seconds (0 disables)", default="300")


class _TestTimeout(Exception):
    pass


def _timeout_for(item) -> int:
    marker = item.get_closest_marker("timeout")
    if marker is not None and marker.args:
        return int(marker.args[0])
    return int(item.config.getini("test_timeout"))


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_protocol(item, nextitem):
    # wrap the whole protocol (setup + call + teardown): module-scoped
    # fixtures do the suite's heaviest work (jit compiles, sim training),
    # and a hang there must trip the alarm just like one in the test body
    seconds = _timeout_for(item)
    if (seconds <= 0 or not hasattr(signal, "SIGALRM")
            or threading.current_thread() is not threading.main_thread()):
        yield
        return

    def on_alarm(signum, frame):
        raise _TestTimeout(
            f"{item.nodeid} exceeded the per-test timeout of {seconds}s "
            f"(test_timeout ini / @pytest.mark.timeout)")

    previous = signal.signal(signal.SIGALRM, on_alarm)
    signal.alarm(seconds)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, previous)
