import os
import sys

# make `import repro` work regardless of how pytest is invoked
SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.
