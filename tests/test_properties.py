"""Property-based tests for the bucket planner and the orthogonalizers.

Runs under hypothesis when installed, otherwise under the deterministic
fallback sampler (tests/_hypothesis_fallback.py) — the strategies stick to
the ``st.integers`` subset both implement.  Properties:

* planner: per-entry padding-waste bound, never-crop, exact coverage,
  offset contiguity, pack/unpack roundtrip exactness;
* planner: permutation invariance of the plan (distinct-area inputs);
* orthogonalizers: orthonormality on near-rank-deficient inputs, and
  invariance under the bucket engine's zero-row padding.
"""

import math
import random

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal container: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import matrixize
from repro.core.orthogonalize import cholesky_qr, gram_schmidt


# ---------------------------------------------------------------------------
# planner generators (seeded — both hypothesis and the fallback drive them
# through integer draws only)
# ---------------------------------------------------------------------------

def _random_shapes(seed: int, n_shapes: int, distinct_areas: bool = False):
    """A plan_buckets input: (count, n, m) tuples interleaved with Nones."""
    rng = random.Random(seed)
    shapes, seen = [], set()
    while len(shapes) < n_shapes:
        if not distinct_areas and rng.random() < 0.2:
            shapes.append(None)  # uncompressed leaf
            continue
        c = rng.randint(1, 4)
        n = rng.randint(1, 96)
        m = rng.randint(1, 96)
        if distinct_areas:
            if n * m in seen:
                continue
            seen.add(n * m)
        shapes.append((c, n, m))
    return shapes


def _check_plan_invariants(shapes, plan, tolerance):
    seen = {}
    for b in plan.buckets:
        off = 0
        for e in b.entries:
            c, n, m = shapes[e.index]
            # never crops, never splits
            assert (e.count, e.n, e.m) == (c, n, m)
            assert e.n <= b.n and e.m <= b.m
            # padding-waste bound: the bucket's padded area exceeds the
            # entry's own by at most `tolerance` (relative)
            assert b.n * b.m <= (1.0 + tolerance) * n * m + 1e-9, (
                (b.n, b.m), (n, m), tolerance)
            # contiguous slot layout
            assert e.offset == off
            off += e.count
            seen[e.index] = seen.get(e.index, 0) + 1
        assert b.count == off
    # exact coverage: every compressed leaf exactly once, Nones never
    expect = {i for i, s in enumerate(shapes) if s is not None}
    assert set(seen) == expect and all(v == 1 for v in seen.values())


@settings(deadline=None, max_examples=30)
@given(seed=st.integers(min_value=0, max_value=10**6),
       n_shapes=st.integers(min_value=1, max_value=24),
       tol_pct=st.integers(min_value=0, max_value=100))
def test_planner_waste_bound_and_coverage(seed, n_shapes, tol_pct):
    tolerance = tol_pct / 100.0
    shapes = _random_shapes(seed, n_shapes)
    plan = matrixize.plan_buckets(shapes, tolerance=tolerance)
    _check_plan_invariants(shapes, plan, tolerance)


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(min_value=0, max_value=10**6),
       n_shapes=st.integers(min_value=1, max_value=16),
       tol_pct=st.integers(min_value=0, max_value=60))
def test_planner_permutation_invariant(seed, n_shapes, tol_pct):
    """With distinct areas the largest-area-first greedy order is fully
    determined, so permuting the input leaves must not change which bucket
    shape hosts each leaf."""
    tolerance = tol_pct / 100.0
    shapes = _random_shapes(seed, n_shapes, distinct_areas=True)
    plan = matrixize.plan_buckets(shapes, tolerance=tolerance)

    rng = random.Random(seed ^ 0x5EED)
    perm = list(range(len(shapes)))
    rng.shuffle(perm)
    shuffled = [shapes[p] for p in perm]
    plan_p = matrixize.plan_buckets(shuffled, tolerance=tolerance)
    _check_plan_invariants(shuffled, plan_p, tolerance)

    def host(plan, idx):
        b_id, _ = plan.entry_for(idx)
        b = plan.buckets[b_id]
        return (b.n, b.m)

    for new_idx, old_idx in enumerate(perm):
        assert host(plan_p, new_idx) == host(plan, old_idx)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(min_value=0, max_value=10**6),
       n_shapes=st.integers(min_value=1, max_value=10))
def test_pack_unpack_roundtrip(seed, n_shapes):
    """Zero-padding into bucket slabs and cropping back is exact."""
    shapes = _random_shapes(seed, n_shapes)
    plan = matrixize.plan_buckets(shapes, tolerance=0.5)
    rng = np.random.RandomState(seed % 2**31)
    arrays = {i: jnp.asarray(rng.randn(c, n, m).astype(np.float32))
              for i, s in enumerate(shapes) if s is not None
              for c, n, m in [s]}
    for b in plan.buckets:
        slab = matrixize.pack_matrices(b, arrays)
        assert slab.shape == (b.count, b.n, b.m)
        for e in b.entries:
            got = matrixize.unpack_entry(slab, e, e.n, e.m)
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(arrays[e.index]))


# ---------------------------------------------------------------------------
# orthogonalizers
# ---------------------------------------------------------------------------

def _near_deficient(seed: int, n: int, r: int, rank: int, noise: float):
    """(n, r) matrix whose columns span only `rank` directions + noise —
    the hard case for orthogonalization (κ(P) → 1/noise)."""
    rng = np.random.RandomState(seed % 2**31)
    base = rng.randn(n, rank).astype(np.float32)
    mix = rng.randn(rank, r).astype(np.float32)
    p = base @ mix + noise * rng.randn(n, r).astype(np.float32)
    return jnp.asarray(p)


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(min_value=0, max_value=10**6),
       r=st.integers(min_value=2, max_value=8),
       deficiency=st.integers(min_value=1, max_value=8))
def test_orthogonalizers_near_rank_deficient(seed, r, deficiency):
    """Both orthogonalizers must return finite, near-orthonormal factors
    even when the input columns are nearly linearly dependent (warm-started
    P collapses toward the top singular directions — this is the *common*
    case after convergence, not a corner)."""
    rank = max(1, r - deficiency)  # true column rank before noise
    p = _near_deficient(seed, n=64, r=r, rank=rank, noise=1e-3)
    for orth in (gram_schmidt, cholesky_qr):
        q = orth(p)
        assert bool(jnp.all(jnp.isfinite(q))), orth.__name__
        gram = np.asarray(q.T @ q)
        # columns with survivable mass must be orthonormal; the tolerance
        # is loose for gram_schmidt whose eps-regularised near-zero
        # residual columns are *small* rather than unit (by design: they
        # contribute ~nothing to P̂ Qᵀ instead of amplifying noise)
        off = gram - np.diag(np.diag(gram))
        assert np.max(np.abs(off)) < 5e-2, (orth.__name__, gram)
        assert np.all(np.diag(gram) < 1.0 + 1e-4), (orth.__name__, gram)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(min_value=0, max_value=10**6),
       r=st.integers(min_value=1, max_value=6),
       pad=st.integers(min_value=1, max_value=32))
def test_orthogonalization_ignores_zero_row_padding(seed, r, pad):
    """Bucket-engine exactness: zero-padded rows contribute nothing to any
    column inner product, so orthogonalizing a padded stack equals
    orthogonalizing the unpadded matrix."""
    rng = np.random.RandomState(seed % 2**31)
    p = jnp.asarray(rng.randn(40, r).astype(np.float32))
    padded = jnp.concatenate([p, jnp.zeros((pad, r), jnp.float32)])
    for orth in (gram_schmidt, cholesky_qr):
        q = np.asarray(orth(p))
        qp = np.asarray(orth(padded))
        np.testing.assert_allclose(qp[:40], q, atol=1e-6)
        np.testing.assert_allclose(qp[40:], 0.0, atol=1e-6)
