"""Deterministic stand-in for `hypothesis` when it is not installed.

The tier-1 suite uses a small slice of the hypothesis API (`@settings`,
`@given`, `st.integers`).  This module re-implements exactly that slice with
a seeded PRNG so the property tests still *run* (with fixed, reproducible
examples) in minimal environments instead of failing at collection.  When
hypothesis is available the real library is used — see the try/except import
in the test modules.

Not a shrinker, not a database, no `@example` — install hypothesis
(`pip install -e .[test]`) for the real search.
"""

from __future__ import annotations

import functools
import random


class _Integers:
    def __init__(self, min_value: int, max_value: int):
        self.min_value = min_value
        self.max_value = max_value

    def example(self, rng: random.Random) -> int:
        return rng.randint(self.min_value, self.max_value)


class strategies:  # mirrors `hypothesis.strategies` module surface
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Integers:
        return _Integers(min_value, max_value)


def settings(deadline=None, max_examples: int = 20, **_kw):
    """Records max_examples on the decorated (given-wrapped) test."""

    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strats):
    """Runs the test over `max_examples` deterministic draws."""

    def deco(fn):
        def wrapper():
            rng = random.Random(0xC0FFEE)
            for _ in range(getattr(wrapper, "_max_examples", 20)):
                draws = {k: s.example(rng) for k, s in strats.items()}
                fn(**draws)

        # NOT functools.wraps: copying __wrapped__ would make pytest read the
        # inner signature and demand fixtures named after the draw params.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
