"""Data pipeline, schedules, checkpointing, optimizers."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.synthetic import GaussianClusters, MarkovLM, shard_batch
from repro.optim import schedules, sgd_apply, sgd_init, signum_apply, signum_init


def test_markov_deterministic():
    d1 = MarkovLM(vocab=100, seed=7).sample(4, 32, step=3)
    d2 = MarkovLM(vocab=100, seed=7).sample(4, 32, step=3)
    np.testing.assert_array_equal(d1, d2)
    d3 = MarkovLM(vocab=100, seed=8).sample(4, 32, step=3)
    assert not np.array_equal(d1, d3)


def test_markov_has_learnable_structure():
    """Next token is one of `branching` candidates 95% of the time — the
    bigram-conditional entropy must be far below uniform."""
    data = MarkovLM(vocab=50, seed=0, branching=4)
    toks = data.sample(64, 128, step=0)
    hits = 0
    total = 0
    for row in toks:
        for t in range(2, len(row)):
            cands = data._nexts(int(row[t - 2]), int(row[t - 1]))
            hits += int(row[t]) in cands
            total += 1
    assert hits / total > 0.9


def test_shard_batch():
    b = {"tokens": np.arange(32).reshape(8, 4)}
    s = shard_batch(b, worker=1, num_workers=4)
    np.testing.assert_array_equal(s["tokens"], np.arange(8, 16).reshape(2, 4))


def test_clusters_separable():
    data = GaussianClusters(num_classes=4, image_size=8, seed=0, noise=0.3)
    batch = data.sample(256, step=0)
    x = batch["images"].reshape(256, -1)
    c = data._centers[batch["labels"]]
    d_own = np.linalg.norm(x - c, axis=1).mean()
    d_other = np.linalg.norm(x - data._centers[(batch["labels"] + 1) % 4], axis=1).mean()
    assert d_own < d_other


def test_schedule_paper_recipe():
    lr0 = schedules.paper_cifar_schedule(0, 0.1, 16, steps_per_epoch=10)
    lr_peak = schedules.paper_cifar_schedule(50, 0.1, 16, steps_per_epoch=10)
    lr_late = schedules.paper_cifar_schedule(2600, 0.1, 16, steps_per_epoch=10)
    assert abs(float(lr0) - 0.1) < 1e-6          # starts at 1-worker LR
    assert abs(float(lr_peak) - 1.6) < 1e-6      # 16× after warmup
    assert abs(float(lr_late) - 0.016) < 1e-6    # /10 /10 after both decays


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4), "d": None},
            "step": jnp.int32(7)}
    save_checkpoint(str(tmp_path), 7, tree)
    save_checkpoint(str(tmp_path), 9, tree)
    assert latest_step(str(tmp_path)) == 9
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 9
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["d"] is None


def test_checkpoint_retention(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in range(6):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    files = sorted(os.listdir(tmp_path))
    assert len(files) == 2 and files[-1].endswith("0000000005.msgpack")


def test_signum_majority_vote_sign():
    params = {"w": jnp.zeros((4,))}
    st = signum_init(params)
    g = {"w": jnp.array([1.0, -2.0, 3.0, -4.0])}
    p2, st2 = signum_apply(params, g, st, lr=0.1, momentum=0.0)
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               -0.1 * np.sign(np.asarray(g["w"])), atol=1e-7)


def test_sgd_momentum():
    params = {"w": jnp.zeros(2)}
    st = sgd_init(params)
    g = {"w": jnp.array([1.0, 1.0])}
    p, st = sgd_apply(params, g, st, lr=0.1, momentum=0.9)
    p, st = sgd_apply(p, g, st, lr=0.1, momentum=0.9)
    np.testing.assert_allclose(np.asarray(p["w"]), -0.1 - 0.19, atol=1e-6)
