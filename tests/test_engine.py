"""The unified transport engine (core/engine.py + the flat-payload planner
in core/matrixize.py + the fused collectives in core/dist.py).

Covers the ISSUE acceptance criteria:
  * flat-payload planning: per-dtype chunking (the mixed-dtype upcast
    footgun fix), explicit wire-dtype casts, max_chunk_bytes splitting,
  * pmean_flat / allgather_flat semantics and CollectiveStats recording
    (actual wire itemsize per chunk; gather bytes scaled by fanout),
  * the CI regression guard: collectives-per-step budgets for the
    documented engines (powersgd ≤ 2, identity ≤ 1 fused data collectives).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import engine, matrixize, powersgd
from repro.core.compressors import IdentityCompressor, PowerSGDCompressor
from repro.core.dist import CollectiveStats, MeshCtx, SimBackend
from repro.core.engine import MODEL_LOCAL, MODEL_REPLICATED, MODEL_SHARDED
from repro.core.simmesh import SimMesh

KEY = jax.random.key(0)


# ---------------------------------------------------------------------------
# flat-payload planner
# ---------------------------------------------------------------------------

def test_plan_flat_single_dtype_single_chunk():
    parts = [jnp.zeros((3, 4)), jnp.zeros((5,)), jnp.zeros(())]
    plan = matrixize.plan_flat(parts)
    assert len(plan.chunks) == 1
    chunk = plan.chunks[0]
    assert chunk.size == 12 + 5 + 1
    assert [s.offset for s in chunk.slots] == [0, 12, 17]
    assert plan.total_wire_bytes == 18 * 4


def test_plan_flat_groups_by_dtype_no_upcast():
    """The mixed-dtype footgun fix: one float32 straggler must NOT promote a
    bfloat16 payload to a 4-byte wire — each dtype gets its own chunk with
    its own itemsize."""
    parts = [jnp.zeros((100,), jnp.bfloat16), jnp.zeros((3,), jnp.float32),
             jnp.zeros((50,), jnp.bfloat16)]
    plan = matrixize.plan_flat(parts, wire_dtype="auto")
    assert len(plan.chunks) == 2
    by_dtype = {jnp.dtype(c.wire_dtype): c for c in plan.chunks}
    assert by_dtype[jnp.dtype(jnp.bfloat16)].size == 150
    assert by_dtype[jnp.dtype(jnp.float32)].size == 3
    assert plan.total_wire_bytes == 150 * 2 + 3 * 4  # not 153 * 4


def test_plan_flat_explicit_wire_dtype_shares_chunk():
    parts = [jnp.zeros((100,), jnp.bfloat16), jnp.zeros((3,), jnp.float32)]
    plan = matrixize.plan_flat(parts, wire_dtype="bfloat16")
    assert len(plan.chunks) == 1
    assert plan.total_wire_bytes == 103 * 2


def test_plan_flat_max_chunk_bytes_splits():
    parts = [jnp.zeros((100,)), jnp.zeros((100,)), jnp.zeros((100,))]
    plan = matrixize.plan_flat(parts, max_chunk_bytes=800)  # 200 floats
    assert len(plan.chunks) == 2
    assert [c.size for c in plan.chunks] == [200, 100]
    # a part never spans two chunks
    for c in plan.chunks:
        for s in c.slots:
            assert s.size == 100


def test_plan_flat_rejects_unknown_wire_dtype():
    with pytest.raises(ValueError):
        matrixize.plan_flat([jnp.zeros((3,))], wire_dtype="float16")


def test_pack_unpack_flat_roundtrip():
    parts = [jax.random.normal(KEY, (3, 4)),
             jax.random.normal(jax.random.fold_in(KEY, 1), (5,))]
    plan = matrixize.plan_flat(parts)
    (chunk,) = plan.chunks
    buf = matrixize.pack_flat(chunk, parts)
    out = matrixize.unpack_flat(chunk, buf)
    for i, p in enumerate(parts):
        np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(p))


# ---------------------------------------------------------------------------
# pmean_flat wire policy + stats
# ---------------------------------------------------------------------------

def test_pmean_flat_mixed_dtype_two_collectives_two_itemsizes():
    stats = CollectiveStats()
    parts = [jnp.ones((100,), jnp.bfloat16), jnp.ones((3,), jnp.float32)]
    out = MeshCtx(stats=stats).pmean_flat(parts)
    assert stats.data_collectives == 2
    assert sorted(zip(stats.sizes, stats.itemsizes)) == [(3, 4), (100, 2)]
    for a, b in zip(parts, out):
        assert b.dtype == a.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pmean_flat_explicit_bfloat16_wire():
    stats = CollectiveStats()
    parts = [jnp.full((8,), 1.5, jnp.float32)]
    out = MeshCtx(stats=stats).pmean_flat(parts, wire_dtype="bfloat16")
    assert stats.itemsizes == [2]
    assert out[0].dtype == jnp.float32  # cast back after transport
    np.testing.assert_array_equal(np.asarray(out[0]), np.full(8, 1.5))


def test_pmean_flat_max_chunk_bytes_counts():
    stats = CollectiveStats()
    parts = [jnp.ones((100,)), jnp.ones((100,))]
    MeshCtx(stats=stats).pmean_flat(parts, max_chunk_bytes=400)
    assert stats.data_collectives == 2


# ---------------------------------------------------------------------------
# allgather_flat: the W-scaled gather path
# ---------------------------------------------------------------------------

def test_allgather_flat_single_device_leading_one():
    stats = CollectiveStats()
    parts = [jax.random.normal(KEY, (3, 4)), jnp.arange(5.0)]
    out = MeshCtx(stats=stats).allgather_flat(parts)
    assert stats.data_collectives == 1
    assert stats.kinds == ["gather"] and stats.fanouts == [1]
    for a, b in zip(parts, out):
        assert b.shape == (1,) + a.shape
        np.testing.assert_array_equal(np.asarray(b[0]), np.asarray(a))


def test_allgather_flat_gathers_over_mapped_axis():
    W = 4
    xs = jnp.stack([jnp.full((3,), float(i)) for i in range(W)])
    ys = jnp.stack([jnp.full((2, 2), float(10 * i)) for i in range(W)])
    ctx = MeshCtx(data_axes=("dp",))

    def one(x, y):
        a, b = ctx.allgather_flat([x, y])
        return a, b

    a, b = jax.vmap(one, axis_name="dp")(xs, ys)
    # every worker sees every worker's payload, in worker order
    assert a.shape == (W, W, 3)
    np.testing.assert_allclose(np.asarray(a[0]),
                               np.arange(W)[:, None] * np.ones(3))
    np.testing.assert_allclose(np.asarray(b[2]),
                               10 * np.arange(W)[:, None, None] * np.ones((2, 2)))


def test_gather_bytes_scaled_by_fanout():
    """CollectiveStats.bytes_per_collective must report gather traffic
    W-scaled (a worker receives every other worker's payload)."""
    W = 4
    stats = CollectiveStats()
    ctx = MeshCtx(data_axes=("dp",), stats=stats)

    def one(x):
        (g,) = ctx.allgather_flat([x])
        (r,) = ctx.pmean_flat([x])
        return g, r

    jax.vmap(one, axis_name="dp")(jnp.ones((W, 10)))
    assert stats.kinds == ["gather", "reduce"]
    assert stats.fanouts == [W, 1]
    assert stats.bytes_per_collective() == [10 * 4 * W, 10 * 4]


def test_transport_combine_mean_matches_weighted_pmean():
    """Transport.combine_mean must reproduce SimBackend's weighted-pmean
    semantics, including the all-dropped round degenerating to exact zero."""
    t = engine.Transport()
    x = jax.random.normal(KEY, (4, 3))
    np.testing.assert_allclose(np.asarray(t.combine_mean(x, None)),
                               np.asarray(x).mean(0), rtol=1e-6)
    w = jnp.asarray([1.0, 0.0, 2.0, 1.0])
    want = (np.asarray(x) * np.asarray(w)[:, None]).sum(0) / 4.0
    np.testing.assert_allclose(np.asarray(t.combine_mean(x, w)), want,
                               rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(t.combine_mean(x, jnp.zeros(4))), np.zeros(3))


# ---------------------------------------------------------------------------
# CI regression guard: documented collective budgets (ISSUE satellite)
# ---------------------------------------------------------------------------

def _model_tree(n_layers=6):
    key = jax.random.key(7)
    grads, specs = {}, {}
    for i in range(n_layers):
        w = jax.random.normal(jax.random.fold_in(key, i), (24 + i, 16))
        b = jnp.ones((16,))
        grads[f"l{i}/w"], specs[f"l{i}/w"] = w, matrixize.default_spec(w)
        grads[f"l{i}/b"], specs[f"l{i}/b"] = b, matrixize.default_spec(b)
    shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads)
    return grads, specs, shapes


@pytest.mark.parametrize("name,comp,budget", [
    ("powersgd", lambda: PowerSGDCompressor(rank=2), 2),
    ("identity", lambda: IdentityCompressor(), 1),
])
def test_collective_budget_never_exceeded(name, comp, budget):
    """Regression guard: the documented per-step fused-collective budget for
    the default engines (README table) — 2 data collectives for powersgd,
    1 for identity — must never regress, at any model size.

    The budgets assume a dtype-homogeneous gradient tree (float32, as all
    our model trees are): under ``wire_dtype="auto"`` every extra payload
    dtype deliberately adds one chunk per phase instead of upcasting (see
    README); an explicit ``wire_dtype`` restores a single shared chunk."""
    from repro.analysis import tracing

    for n_layers in (1, 6, 17):
        grads, specs, shapes = _model_tree(n_layers)
        c = comp()
        stats = CollectiveStats()
        c.step(grads, c.init(shapes, specs, KEY), specs,
               ctx=MeshCtx(stats=stats), key=KEY)
        assert stats.data_collectives <= budget, (
            name, n_layers, stats.data_collectives, stats.sizes)
        assert stats.gather_collectives == 0, name

        # static cross-check (gradlint): the jaxpr of the same step holds
        # exactly the collectives the runtime accounting recorded — if the
        # CollectiveStats path ever under-records, the compiled program
        # itself is the witness
        art = tracing.trace_compress_step(c, grads, specs,
                                          with_error_feedback=False)
        assert len(art.logical()) == stats.data_collectives, (
            name, n_layers, [s.provenance() for s in art.logical()])
        assert all(s.kind == "reduce" for s in art.logical()), name


def test_quantized_wire_bytes_ratio_pinned():
    """Regression guard for honest fractional byte accounting (ISSUE 9):
    the same powersgd step under ``wire_dtype="int4"`` must record ~0.5
    bytes/element plus the scale sidecar — an 8× wire-byte reduction over
    float32 (int8: 4×), NOT a silently-rounded 1 byte/element — while the
    2-collective budget stays untouched."""
    grads, specs, shapes = _model_tree(6)

    def run(wd):
        c = PowerSGDCompressor(rank=2, wire_dtype=wd)
        stats = CollectiveStats()
        c.step(grads, c.init(shapes, specs, KEY), specs,
               ctx=MeshCtx(stats=stats), key=KEY)
        assert stats.data_collectives == 2, (wd, stats.kinds)
        return stats

    f32, i8, i4 = run("float32"), run("int8"), run("int4")
    assert f32.sizes == i8.sizes == i4.sizes  # same payload elements
    f32_b, i8_b, i4_b = (sum(s.bytes_per_collective())
                         for s in (f32, i8, i4))
    n = sum(f32.sizes)
    assert f32_b == 4 * n and f32.overheads == [0, 0]
    # exact: payload at the fractional itemsize + one f32 scale per slot
    assert i8_b == n + sum(i8.overheads)
    assert i4_b == 0.5 * n + sum(i4.overheads)
    assert all(o > 0 for o in i4.overheads)
    # ratio bounds: the ideal 4×/8× shaved by the scale sidecar (this tiny
    # tree has ~5% sidecar overhead; real models amortize it to <1%)
    assert 3.5 <= f32_b / i8_b <= 4.0
    assert 6.5 <= f32_b / i4_b <= 8.0


# ---------------------------------------------------------------------------
# PipelinedTransport: double-buffered chunk schedule (ISSUE 8)
# ---------------------------------------------------------------------------

def test_pmean_flat_interleave_bit_identical_same_trace():
    """interleave=True only reorders the issue/unpack interleaving of the
    chunk loop — values bit-equal to the serial path, and the
    CollectiveStats trace (recorded at issue time) identical, so the
    collective-budget guard cannot silently pass on a reordered schedule."""
    parts = [jax.random.normal(jax.random.fold_in(KEY, i), (64,))
             for i in range(5)]
    s_serial, s_inter = CollectiveStats(), CollectiveStats()
    # 64 floats = 256 bytes/part; cap forces a multi-chunk schedule
    out_a = MeshCtx(stats=s_serial).pmean_flat(parts, max_chunk_bytes=512)
    out_b = MeshCtx(stats=s_inter).pmean_flat(parts, max_chunk_bytes=512,
                                              interleave=True)
    assert s_serial.data_collectives >= 2  # the cap actually split
    for a, b in zip(out_a, out_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (s_serial.kinds, s_serial.sizes, s_serial.itemsizes) == \
           (s_inter.kinds, s_inter.sizes, s_inter.itemsizes)


def test_pipelined_transport_bit_identical_and_budget():
    """The pipeline=True engine must produce bit-identical compression
    output AND the identical fused-collective trace as the synchronous
    transport (same ≤2 budget, same kinds/sizes/itemsizes) — the wire
    schedule becomes overlappable, the math and the accounting do not
    change."""
    for n_layers in (1, 6, 17):
        grads, specs, shapes = _model_tree(n_layers)
        sync_c = PowerSGDCompressor(rank=2)
        pipe_c = PowerSGDCompressor(rank=2, pipeline=True)
        s_sync, s_pipe = CollectiveStats(), CollectiveStats()
        out_sync = sync_c.step(grads, sync_c.init(shapes, specs, KEY), specs,
                               ctx=MeshCtx(stats=s_sync), key=KEY)
        out_pipe = pipe_c.step(grads, pipe_c.init(shapes, specs, KEY), specs,
                               ctx=MeshCtx(stats=s_pipe), key=KEY)
        for a, b in zip(jax.tree_util.tree_leaves(out_sync.agg),
                        jax.tree_util.tree_leaves(out_pipe.agg)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(out_sync.state),
                        jax.tree_util.tree_leaves(out_pipe.state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert s_pipe.data_collectives <= 2, (n_layers, s_pipe.kinds)
        assert (s_sync.kinds, s_sync.sizes, s_sync.itemsizes) == \
               (s_pipe.kinds, s_pipe.sizes, s_pipe.itemsizes), n_layers


def test_pipelined_transport_chunked_schedule_stays_on_budget_per_chunk():
    """With a max_chunk_bytes cap the pipelined engine splits each phase into
    several in-flight buffers; the per-chunk records must stay identical to
    the synchronous engine's so comm models price both schedules the same."""
    grads, specs, shapes = _model_tree(6)
    kw = dict(rank=2, max_chunk_bytes=1024)
    sync_c = PowerSGDCompressor(**kw)
    pipe_c = PowerSGDCompressor(pipeline=True, **kw)
    s_sync, s_pipe = CollectiveStats(), CollectiveStats()
    a = sync_c.step(grads, sync_c.init(shapes, specs, KEY), specs,
                    ctx=MeshCtx(stats=s_sync), key=KEY)
    b = pipe_c.step(grads, pipe_c.init(shapes, specs, KEY), specs,
                    ctx=MeshCtx(stats=s_pipe), key=KEY)
    assert s_sync.data_collectives > 2  # cap split the fused phases
    assert (s_sync.kinds, s_sync.sizes, s_sync.itemsizes) == \
           (s_pipe.kinds, s_pipe.sizes, s_pipe.itemsizes)
    for x, y in zip(jax.tree_util.tree_leaves(a.agg),
                    jax.tree_util.tree_leaves(b.agg)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_pipelined_transport_shift_rotation():
    """PipelinedTransport.shift is the cross-step double-buffer rotation:
    returns (to_apply, new_inflight) = (inflight, fresh); init_inflight
    seeds the zero bubble."""
    fresh = {"a": jnp.ones((3,)), "b": jnp.full((2,), 2.0)}
    inflight = engine.PipelinedTransport.init_inflight(fresh)
    for leaf in jax.tree_util.tree_leaves(inflight):
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.zeros_like(np.asarray(leaf)))
    applied, parked = engine.PipelinedTransport.shift(fresh, inflight)
    assert applied is inflight and parked is fresh


# ---------------------------------------------------------------------------
# sync_mode="broadcast": semantics, byte accounting and collective budgets
# ---------------------------------------------------------------------------

def test_broadcast_mode_aggregates_bit_identical_across_ranks():
    """Under sync_mode="broadcast" every data-axis aggregate must come back
    bit-identical on all ranks, broadcast_flat must deliver rank 0's copy,
    and CollectiveStats must record the reduce+broadcast legs honestly."""
    W = 4
    stats = CollectiveStats()

    def one(x):
        ctx = MeshCtx(data_axes=("dp",), sync_mode="broadcast", stats=stats)
        (m,) = ctx.pmean_flat([x])
        s = ctx.psum_data(x)
        (b,) = ctx.broadcast_flat([x])
        return m, s, b

    x = np.asarray(jax.random.normal(KEY, (W, 13)))
    m, s, b = (np.asarray(v) for v in
               jax.vmap(one, axis_name="dp")(jnp.asarray(x)))
    np.testing.assert_array_equal(m, np.broadcast_to(m[:1], m.shape))
    np.testing.assert_array_equal(s, np.broadcast_to(s[:1], s.shape))
    np.testing.assert_array_equal(b, np.broadcast_to(x[:1], b.shape))
    np.testing.assert_allclose(m[0], x.mean(0), rtol=1e-6)
    np.testing.assert_allclose(s[0], x.sum(0), rtol=1e-6)
    assert stats.kinds == ["reduce", "broadcast",    # pmean_flat
                           "reduce", "broadcast",    # psum_data
                           "broadcast"]              # broadcast_flat
    # broadcast bytes are flat in W — never fanout-scaled
    assert stats.fanouts == [1] * 5
    assert stats.bytes_per_collective() == [13 * 4] * 5


def test_broadcast_mode_sync_false_skips_broadcast_record():
    """sync=False marks an internal phase reduce: canonical order, but only
    the reduce leg is recorded (the scheme broadcasts once at the end)."""
    W = 2
    stats = CollectiveStats()

    def one(x):
        ctx = MeshCtx(data_axes=("dp",), sync_mode="broadcast", stats=stats)
        (m,) = ctx.pmean_flat([x], sync=False)
        return m

    m = np.asarray(jax.vmap(one, axis_name="dp")(jnp.ones((W, 7))))
    np.testing.assert_array_equal(m, np.ones((W, 7)))
    assert stats.kinds == ["reduce"]


def test_broadcast_mode_weighted_matches_allreduce_semantics():
    """The canonical deterministic reduction must preserve the weighted-pmean
    contract (Σw·x/Σw, guarded denominator): same values as allreduce mode
    up to reassociation, and the all-dropped round stays exactly zero."""
    W = 4
    x = jax.random.normal(KEY, (W, 5))

    def run(mode, w):
        def one(xi, wi):
            ctx = MeshCtx(data_axes=("dp",), sync_mode=mode,
                          backend=SimBackend(axis="dp", size=W, weight=wi))
            return ctx.pmean_data(xi)
        return np.asarray(jax.vmap(one, axis_name="dp")(x, w))

    w = jnp.asarray([1.0, 0.0, 2.0, 1.0])
    np.testing.assert_allclose(run("broadcast", w), run("allreduce", w),
                               rtol=1e-6)
    np.testing.assert_array_equal(run("broadcast", jnp.zeros(W)),
                                  np.zeros((W, 5)))


# ---------------------------------------------------------------------------
# per-leaf state partition: factor classification + bucket flags (ISSUE 7)
# ---------------------------------------------------------------------------

def _w(shape):
    return jax.random.normal(KEY, shape)


def test_factor_partition_classification():
    """The three-way model relation of a PowerSGD Q factor, from the owning
    parameter's PartitionSpec: column-parallel (m-sharded) weights have
    honestly model-sharded factors, row-parallel (n-sharded) weights have
    model-LOCAL ones (per-rank content behind a replicated-shaped spec),
    unsharded weights replicate, uncompressed leaves have no factor."""
    spec2d = matrixize.default_spec(_w((8, 16)))
    # column-parallel: m dim carries "model" → factor is m-sharded, honest
    part = powersgd.factor_partition(P(None, "model"), spec2d)
    assert part.model == MODEL_SHARDED and part.spec == P("model", None)
    # row-parallel: n dim carries "model" → per-rank Q = M_localᵀP̂ content
    # behind a dims-replicated spec: model-LOCAL
    part = powersgd.factor_partition(P("model", None), spec2d)
    assert part.model == MODEL_LOCAL and part.spec == P(None, None)
    part = powersgd.factor_partition(P(None, None), spec2d)
    assert part.model == MODEL_REPLICATED and part.spec == P(None, None)
    # uncompressed (1-D) leaves carry no factor at all
    bias_spec = matrixize.default_spec(_w((16,)))
    assert powersgd.factor_partition(P(None), bias_spec) is None


def test_bucket_model_sharded_flags():
    """MatrixPayloads.build learns which buckets hold non-whole-mesh-
    replicated factors from the partition tree — the signal the checkpoint
    layer keys its mesh-aware gather on."""
    grads = {"loc": _w((8, 16)), "rep": _w((12, 20)), "bias": jnp.ones((16,))}
    specs = {k: matrixize.default_spec(v) for k, v in grads.items()}
    pspecs = {"loc": P("model", None), "rep": P(None, None), "bias": P(None)}
    partition = powersgd.state_partition(pspecs, specs)
    assert partition["loc"].model == MODEL_LOCAL
    assert partition["rep"].model == MODEL_REPLICATED
    assert partition["bias"] is None

    state = {"loc": _w((16, 2)), "rep": _w((20, 2)), "bias": None}
    mp = engine.MatrixPayloads.build(grads, state, specs, dtype=jnp.float32,
                                     partition=partition)
    flags = {}
    for bucket, flag in zip(mp.plan.buckets, mp.bucket_model_sharded):
        for e in bucket.entries:
            flags[jax.tree_util.keystr(mp.leaves[e.index][0])] = flag
    assert flags == {"['loc']": True, "['rep']": False}, flags

    # without a partition tree the information is declared unknown, not False
    mp2 = engine.MatrixPayloads.build(grads, state, specs, dtype=jnp.float32)
    assert mp2.bucket_model_sharded is None


@pytest.mark.parametrize("name,comp,reduces,broadcasts", [
    ("powersgd", lambda: PowerSGDCompressor(rank=2), 2, 1),
    ("identity", lambda: IdentityCompressor(), 1, 1),
])
def test_collective_budget_broadcast_mode(name, comp, reduces, broadcasts):
    """ISSUE 6 satellite: under sync_mode="broadcast" the documented budgets
    become `reduces` fused reduces plus at most ONE fused rank-0 broadcast
    per step (powersgd ≤2+1, identity ≤1+1) — the per-phase reduces defer
    their sync leg to the single end-of-step broadcast."""
    W = 2
    sim = SimMesh(workers=W, axis="dp")
    for n_layers in (1, 6, 17):
        grads, specs, shapes = _model_tree(n_layers)
        c = comp()
        stats = CollectiveStats()
        state = c.init(shapes, specs, KEY)

        def step(g, s):
            ctx = sim.ctx(stats=stats, sync_mode="broadcast")
            return c.step(g, s, specs, ctx=ctx, key=KEY).agg

        agg = sim.run(step, in_axes=(0, 0))(
            sim.replicate(grads), sim.replicate(state))
        sim.assert_replicated(agg, f"{name} agg")
        assert stats.reduce_collectives <= reduces, (
            name, n_layers, stats.kinds, stats.sizes)
        assert stats.broadcast_collectives <= broadcasts, (
            name, n_layers, stats.kinds)
        assert stats.gather_collectives == 0, name
        for k, s_, i_, b_ in zip(stats.kinds, stats.sizes, stats.itemsizes,
                                 stats.bytes_per_collective()):
            if k == "broadcast":
                assert b_ == s_ * i_  # flat in W
