"""Property suite for the quantized wire formats (ISSUE 9).

Round-trip laws for the int4 nibble pack/unpack pair (identity on
representable codes, odd-length tail padding, Pallas interpret-mode kernel
bit-exact against the pure-jnp reference), per-slot symmetric scale
correctness, and the elementwise quantization error bound
|x − dequant(quant(x))| ≤ scale/2 that error feedback relies on.

Runs real hypothesis when installed, else the bundled fallback sampler.
"""

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on minimal images
    from _hypothesis_fallback import given, settings, strategies as st

import jax.numpy as jnp
import pytest

from repro.core import matrixize
from repro.kernels import ops, quant, ref


def _codes(n, seed, qmax=7):
    rng = np.random.default_rng(seed)
    return rng.integers(-qmax, qmax + 1, size=n).astype(np.int8)


# ---------------------------------------------------------------------------
# nibble pack/unpack round-trip laws
# ---------------------------------------------------------------------------


@settings(max_examples=30)
@given(n=st.integers(min_value=1, max_value=700),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_nibble_roundtrip_identity(n, seed):
    """unpack ∘ pack == identity on representable int4 codes, any length."""
    codes = _codes(n, seed)
    packed = ref.nibble_pack(jnp.asarray(codes))
    assert packed.dtype == jnp.uint8
    assert packed.shape == ((n + 1) // 2,)
    back = ref.nibble_unpack(packed, n)
    np.testing.assert_array_equal(np.asarray(back), codes)


@settings(max_examples=20)
@given(n=st.integers(min_value=1, max_value=301),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_nibble_odd_tail_padding(n, seed):
    """An odd-length vector's last byte carries a zero high nibble, and the
    padding code never leaks back out of unpack."""
    n = 2 * (n // 2) + 1  # force odd
    codes = _codes(n, seed)
    packed = np.asarray(ref.nibble_pack(jnp.asarray(codes)))
    assert packed[-1] >> 4 == 0
    assert np.asarray(ref.nibble_unpack(jnp.asarray(packed), n)).shape == (n,)


@settings(max_examples=20)
@given(n=st.integers(min_value=1, max_value=1000),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_pallas_matches_reference_bitexact(n, seed):
    """Pallas interpret-mode kernels ≡ the pure-jnp reference, both ways."""
    codes = jnp.asarray(_codes(n, seed))
    ref_packed = ref.nibble_pack(codes)
    pl_packed = quant.nibble_pack(codes, interpret=True)
    np.testing.assert_array_equal(np.asarray(pl_packed),
                                  np.asarray(ref_packed))
    ref_back = ref.nibble_unpack(ref_packed, n)
    pl_back = quant.nibble_unpack(ref_packed, n, interpret=True)
    np.testing.assert_array_equal(np.asarray(pl_back), np.asarray(ref_back))


def test_pallas_multiblock_grid():
    """A payload larger than one (BLOCK_ROWS, LANE) block still round-trips
    bit-exactly through the gridded Pallas kernels."""
    n = 2 * quant.BLOCK_ROWS * quant.LANE + 77
    codes = jnp.asarray(_codes(n, seed=3))
    packed = quant.nibble_pack(codes, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(packed), np.asarray(ref.nibble_pack(codes)))
    np.testing.assert_array_equal(
        np.asarray(quant.nibble_unpack(packed, n, interpret=True)),
        np.asarray(codes))


def test_ops_dispatch_cpu_routes_to_reference():
    """On the CPU test substrate the ops dispatcher uses the reference path
    (vmap-safe) and agrees with an explicit Pallas interpret call."""
    codes = jnp.asarray(_codes(513, seed=11))
    packed = ops.nibble_pack(codes)  # default routing
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(ref.nibble_pack(codes)))
    np.testing.assert_array_equal(
        np.asarray(ops.nibble_unpack(packed, 513, use_pallas=True,
                                     interpret=True)),
        np.asarray(codes))


# ---------------------------------------------------------------------------
# symmetric scales + quantization error bound
# ---------------------------------------------------------------------------


@settings(max_examples=25)
@given(n=st.integers(min_value=1, max_value=400),
       seed=st.integers(min_value=0, max_value=2**31 - 1),
       log_mag=st.integers(min_value=-8, max_value=8))
def test_scale_and_error_bound(n, seed, log_mag):
    """scale = max|x|/qmax, codes stay in [-qmax, qmax], and the round-trip
    error is ≤ scale/2 elementwise across 16 orders of magnitude — for both
    the int8 (qmax 127) and int4 (qmax 7) grids."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(n) * 10.0 ** log_mag).astype(np.float32)
    xs = jnp.asarray(x)
    for qmax in (127, 7):
        sc = ref.quant_scale(xs, qmax)
        np.testing.assert_allclose(float(sc), np.abs(x).max() / qmax
                                   if np.abs(x).max() > 0 else 1.0, rtol=1e-6)
        q = ref.quantize(xs, sc, qmax)
        qn = np.asarray(q)
        assert qn.min() >= -qmax and qn.max() <= qmax
        err = np.abs(np.asarray(ref.dequantize(q, sc)) - x)
        assert err.max() <= float(sc) / 2 * (1 + 1e-6), (err.max(), float(sc))


def test_zero_array_scale_guard():
    """All-zero inputs quantize to all-zero codes with the guarded scale 1.0
    (no NaN/inf anywhere in the round trip)."""
    x = jnp.zeros(33, jnp.float32)
    sc = ref.quant_scale(x, 7)
    assert float(sc) == 1.0
    out = ref.dequantize(ref.quantize(x, sc, 7), sc)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(33, np.float32))


# ---------------------------------------------------------------------------
# flat-plan integration: per-slot scales, packed offsets, honest bytes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wd", ["int8", "int4"])
def test_flat_plan_per_slot_scale_correctness(wd):
    """Each slot in a quantized chunk is scaled by ITS OWN absmax — a huge
    neighbor slot must not crush a small slot's resolution — and the
    gather-path pack/unpack agrees with the reduce-path dequantized buffer
    exactly."""
    rng = np.random.default_rng(0)
    parts = [jnp.asarray(rng.standard_normal((5, 7)).astype(np.float32)),
             jnp.asarray(1e4 * rng.standard_normal(9).astype(np.float32)),
             jnp.asarray(1e-4 * rng.standard_normal(11).astype(np.float32))]
    plan = matrixize.plan_flat(parts, wire_dtype=wd)
    (chunk,) = plan.chunks
    assert chunk.quant == wd
    qmax = matrixize.QUANT_QMAX[wd]
    payload, scales = matrixize.quant_pack_flat(chunk, parts)
    for k, (s, p) in enumerate(zip(chunk.slots, parts)):
        x = np.asarray(p, np.float32).ravel()
        np.testing.assert_allclose(float(scales[k]), np.abs(x).max() / qmax,
                                   rtol=1e-6)
    out = matrixize.quant_unpack_flat(chunk, payload, scales)
    buf = np.asarray(matrixize.quant_dequant_flat(chunk, parts))
    ref_out = matrixize.unpack_flat(chunk, jnp.asarray(buf))
    for s in chunk.slots:
        x = np.asarray(parts[s.index], np.float32)
        got = np.asarray(out[s.index])
        np.testing.assert_array_equal(got, np.asarray(ref_out[s.index]))
        sc = float(scales[[i for i, t in enumerate(chunk.slots)
                           if t.index == s.index][0]])
        assert np.abs(got - x).max() <= sc / 2 * (1 + 1e-6)


def test_flat_plan_int4_packed_offsets_odd_slots():
    """Odd-size slots are each padded to their own even code count, so slot
    boundaries in the packed buffer stay byte-aligned and decodable."""
    rng = np.random.default_rng(7)
    parts = [jnp.asarray(rng.standard_normal(n).astype(np.float32))
             for n in (3, 5, 8, 1)]
    plan = matrixize.plan_flat(parts, wire_dtype="int4")
    (chunk,) = plan.chunks
    payload, scales = matrixize.quant_pack_flat(chunk, parts)
    assert payload.shape == (sum((n + 1) // 2 for n in (3, 5, 8, 1)),)
    assert matrixize.quant_slot_sizes(chunk) == [2, 3, 4, 1]
    out = matrixize.quant_unpack_flat(chunk, payload, scales)
    for i, p in enumerate(parts):
        assert out[i].shape == p.shape
        sc = float(scales[i])
        assert np.abs(np.asarray(out[i]) - np.asarray(p)).max() <= sc / 2 * (
            1 + 1e-6)


def test_flat_plan_ints_never_quantized_and_honest_bytes():
    """Integer parts keep their own exact chunks under a quantized wire, and
    the plan's byte accounting is 0.5 B/elem + 4 B/slot for int4."""
    parts = [jnp.ones((4, 4), jnp.float32), jnp.arange(6, dtype=jnp.int32),
             jnp.ones(5, jnp.float32)]
    plan = matrixize.plan_flat(parts, wire_dtype="int4")
    quant_chunks = [c for c in plan.chunks if c.quant]
    int_chunks = [c for c in plan.chunks if not c.quant]
    assert len(quant_chunks) == 1 and len(int_chunks) == 1
    qc, ic = quant_chunks[0], int_chunks[0]
    assert ic.wire_dtype == jnp.int32 and ic.overhead_bytes == 0
    assert qc.wire_itemsize == 0.5
    assert qc.wire_bytes == 21 * 0.5 + 2 * matrixize.SCALE_BYTES
    assert matrixize.plan_flat(parts, "int8").chunks[0].wire_bytes == 21 + 8
