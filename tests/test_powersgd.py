import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import matrixize, powersgd
from repro.core.compressors import ExactRankK, PowerSGDCompressor
from repro.core.powersgd import PowerSGDConfig


def _setup(shape=(50, 40), rank=2, seed=0, **kw):
    key = jax.random.key(seed)
    m = jax.random.normal(key, shape)
    grads = {"w": m}
    specs = {"w": matrixize.default_spec(m, batch_dims=max(0, m.ndim - 2))}
    shapes = {"w": jax.ShapeDtypeStruct(m.shape, m.dtype)}
    comp = PowerSGDCompressor(rank=rank, **kw)
    state = comp.init(shapes, specs, key)
    return comp, grads, state, specs, key


def test_warm_start_converges_to_best_rank_r():
    """Theorem I: repeated warm-started subspace iteration on a FIXED matrix
    recovers the best rank-r approximation."""
    comp, grads, state, specs, key = _setup(rank=2)
    for _ in range(80):
        out = comp.step(grads, state, specs, key=key)
        state = out.state
    exact = ExactRankK(rank=2).step(grads, None, specs, key=key)
    err_psgd = float(jnp.linalg.norm(grads["w"] - out.agg["w"]))
    err_best = float(jnp.linalg.norm(grads["w"] - exact.agg["w"]))
    assert err_psgd <= err_best * 1.001


def test_single_iteration_worse_than_converged():
    comp, grads, state, specs, key = _setup(rank=2)
    out1 = comp.step(grads, state, specs, key=key)
    state2 = out1.state
    for _ in range(40):
        out = comp.step(grads, state2, specs, key=key)
        state2 = out.state
    e1 = float(jnp.linalg.norm(grads["w"] - out1.agg["w"]))
    e2 = float(jnp.linalg.norm(grads["w"] - out.agg["w"]))
    assert e2 <= e1 + 1e-5


def test_best_approx_variant_matches_svd():
    """Appendix G.7: 4 cold-start subspace iterations ≈ best approximation."""
    comp, grads, state, specs, key = _setup(rank=2, warm_start=False, num_iters=4)
    out = comp.step(grads, state, specs, key=key)
    exact = ExactRankK(rank=2).step(grads, None, specs, key=key)
    err = float(jnp.linalg.norm(grads["w"] - out.agg["w"]))
    err_best = float(jnp.linalg.norm(grads["w"] - exact.agg["w"]))
    assert err <= err_best * 1.05


@settings(deadline=None, max_examples=15)
@given(
    n=st.integers(4, 64),
    m=st.integers(4, 64),
    r=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_rank_budget_and_shape(n, m, r, seed):
    comp, grads, state, specs, key = _setup(shape=(n, m), rank=r, seed=seed)
    out = comp.step(grads, state, specs, key=key)
    assert out.agg["w"].shape == (n, m)
    # reconstruction has rank ≤ r (vacuous when r ≥ min(n, m): the
    # factorisation P̂Qᵀ may then be full rank, which is correct)
    if r < min(n, m):
        s = jnp.linalg.svd(out.agg["w"], compute_uv=False)
        assert float(s[r:].sum()) < 1e-3 * max(1.0, float(s[0]))
    # message size: r·(n+m) floats
    assert out.bits_per_worker == r * (n + m) * 32


def test_higher_rank_better_approximation():
    errs = []
    for r in (1, 2, 4, 8):
        comp, grads, state, specs, key = _setup(rank=r, seed=3)
        for _ in range(10):
            out = comp.step(grads, state, specs, key=key)
            state = out.state
        errs.append(float(jnp.linalg.norm(grads["w"] - out.agg["w"])))
    assert errs == sorted(errs, reverse=True)


def test_vector_params_uncompressed():
    key = jax.random.key(0)
    grads = {"b": jnp.arange(8.0)}
    specs = {"b": matrixize.default_spec(grads["b"])}
    comp = PowerSGDCompressor(rank=2)
    state = comp.init({"b": jax.ShapeDtypeStruct((8,), jnp.float32)}, specs, key)
    assert state["b"] is None
    out = comp.step(grads, state, specs, key=key)
    np.testing.assert_array_equal(np.asarray(out.agg["b"]), np.arange(8.0))
    np.testing.assert_array_equal(np.asarray(out.recon["b"]), np.arange(8.0))


def test_stacked_batch_dims():
    key = jax.random.key(0)
    m = jax.random.normal(key, (3, 4, 20, 10))  # (layers, experts, n, m)
    grads = {"w": m}
    specs = {"w": matrixize.MatrixSpec("matrix", 2)}
    comp = PowerSGDCompressor(rank=2)
    state = comp.init({"w": jax.ShapeDtypeStruct(m.shape, m.dtype)}, specs, key)
    assert state["w"].shape == (3, 4, 10, 2)
    out = comp.step(grads, state, specs, key=key)
    assert out.agg["w"].shape == m.shape
    # each (layer, expert) matrix is compressed independently to rank ≤ 2
    s = jnp.linalg.svd(out.agg["w"], compute_uv=False)
    assert float(s[..., 2:].max()) < 1e-4 * float(s.max())


def test_orthogonalizer_variants_equivalent():
    """Gram-Schmidt (paper) vs CholeskyQR (TPU opt) give the same
    reconstruction: P̂Qᵀ only depends on span(P̂)."""
    outs = {}
    for orth in ("gram_schmidt", "cholesky_qr"):
        comp, grads, state, specs, key = _setup(rank=3, orthogonalizer=orth)
        out = comp.step(grads, state, specs, key=key)
        outs[orth] = np.asarray(out.agg["w"])
    np.testing.assert_allclose(outs["gram_schmidt"], outs["cholesky_qr"],
                               atol=5e-4)


def test_resnet18_total_compression_matches_paper():
    """Paper Table 10: whole ResNet18 compresses 243/r× (43 MB total)."""
    from repro.models import resnet

    params, _ = resnet.init(jax.random.key(0), resnet.paper_resnet18())
    specs = resnet.mspecs(params)
    total = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    sent = powersgd.compressed_floats_total(
        jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params),
        specs, rank=1)
    ratio = total / sent
    assert 11.1e6 < total < 11.2e6          # 11,173,962 params ≈ 43 MB fp32
    assert 220 < ratio < 260                 # paper: 243/1×


def test_lstm_total_compression_matches_paper():
    """Paper Table 11: whole LSTM compresses 310/r× (110 MB total)."""
    from repro.models import lstm

    params = lstm.init(jax.random.key(0), lstm.paper_lstm())
    specs = lstm.mspecs(params)
    total = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    sent = powersgd.compressed_floats_total(
        jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params),
        specs, rank=1)
    ratio = total / sent
    assert 280 < ratio < 340                 # paper: 310/1×
