"""Checkpoint-layer unit tests (ISSUE 5): v2 envelope integrity, durability
mechanics (fsync, tmp sweep, retention races), dtype/shape/structure checks
with leaf-path errors, legacy-v1 restore, PRNG/controller serialization and
the elastic error-buffer rescale semantics.

SimMesh end-to-end resume coverage (bit-exactness, elastic W=1→4) lives in
``tests/sim/test_resume.py``."""

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

from repro.checkpoint import (MODEL_AXIS_KEY, CheckpointError, TrainState,
                              all_steps, checkpoint_meta, latest_step,
                              restore_checkpoint, restore_train_state,
                              save_checkpoint, save_train_state)
from repro.checkpoint import msgpack_ckpt
from repro.core.error_feedback import (EFState, rescale_error_buffers,
                                       rescale_path)
from repro.core.powersgd import RankController


def _tree():
    return {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16), "d": None},
            "step": jnp.int32(7)}


# ---------------------------------------------------------------------------
# envelope roundtrip + integrity
# ---------------------------------------------------------------------------

def test_v2_roundtrip_with_meta(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 7, tree, meta={"workers": 4, "note": "x"})
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype
    assert restored["b"]["d"] is None
    assert checkpoint_meta(str(tmp_path)) == {"workers": 4, "note": "x"}


def test_bfloat16_roundtrips_exactly(tmp_path):
    """The legacy encoder stored numpy's ``.str`` token, which is '<V2'
    (void) for bfloat16 — decoding produced raw structs.  v2 must
    round-trip extension dtypes bit-exactly."""
    tree = {"w": (jnp.arange(7, dtype=jnp.bfloat16) * 0.3)}
    save_checkpoint(str(tmp_path), 0, tree)
    restored, _ = restore_checkpoint(str(tmp_path), tree)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(restored["w"]).view(np.uint16),
        np.asarray(tree["w"]).view(np.uint16))


def test_dtype_mismatch_names_leaf(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"m": {"w": jnp.zeros(3, jnp.float32)}})
    with pytest.raises(CheckpointError, match=r"\['m'\]\['w'\].*dtype.*"
                                              r"float32.*bfloat16"):
        restore_checkpoint(str(tmp_path),
                           {"m": {"w": jnp.zeros(3, jnp.bfloat16)}})


def test_shape_mismatch_names_leaf(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"m": {"w": jnp.zeros((3, 2))}})
    with pytest.raises(CheckpointError, match=r"\['m'\]\['w'\].*shape"):
        restore_checkpoint(str(tmp_path), {"m": {"w": jnp.zeros((3, 4))}})


def test_structure_drift_caught_by_paths(tmp_path):
    """Same leaf count and shapes but different tree keys must not restore
    silently into the wrong slots (v2 stores per-leaf paths)."""
    save_checkpoint(str(tmp_path), 1, {"p": jnp.zeros(3), "q": jnp.ones(3)})
    with pytest.raises(CheckpointError, match="structure mismatch"):
        restore_checkpoint(str(tmp_path),
                           {"p": jnp.zeros(3), "r": jnp.ones(3)})


def test_truncated_checkpoint_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 3, _tree())
    path = os.path.join(str(tmp_path), "ckpt_0000000003.msgpack")
    data = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(data[:len(data) // 2])
    with pytest.raises(CheckpointError, match="truncated or corrupted"):
        restore_checkpoint(str(tmp_path), _tree())


def test_bitflip_in_buffers_rejected_by_crc(tmp_path):
    """A flipped bit inside the raw leaf bytes still parses as valid
    msgpack — only the checksum catches it."""
    tree = {"w": jnp.ones(1024)}
    save_checkpoint(str(tmp_path), 3, tree)
    path = os.path.join(str(tmp_path), "ckpt_0000000003.msgpack")
    raw = bytearray(open(path, "rb").read())
    # flip a bit in the middle of the (large, contiguous) float payload
    raw[len(raw) // 2] ^= 0x10
    with open(path, "wb") as f:
        f.write(bytes(raw))
    with pytest.raises(CheckpointError, match="checksum"):
        restore_checkpoint(str(tmp_path), tree)


def test_legacy_v1_envelope_still_restores(tmp_path):
    """Pre-versioning checkpoints (no version/meta/paths/crc) must load."""
    tree = {"w": jnp.arange(4.0)}
    arr = np.asarray(tree["w"])
    payload = {"step": 5, "treedef": "ignored",
               "leaves": [{"kind": "array", "dtype": arr.dtype.str,
                           "shape": list(arr.shape), "data": arr.tobytes()}]}
    with open(os.path.join(str(tmp_path), "ckpt_0000000005.msgpack"),
              "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), arr)
    assert checkpoint_meta(str(tmp_path)) == {}


# ---------------------------------------------------------------------------
# durability mechanics
# ---------------------------------------------------------------------------

def test_save_fsyncs_before_replace(tmp_path, monkeypatch):
    synced = []
    real_fsync = os.fsync
    real_replace = os.replace

    def spy_fsync(fd):
        synced.append("fsync")
        return real_fsync(fd)

    def spy_replace(src, dst):
        assert "fsync" in synced, "os.replace before any fsync: a crash " \
            "could publish a checkpoint whose data never hit disk"
        synced.append("replace")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "fsync", spy_fsync)
    monkeypatch.setattr(os, "replace", spy_replace)
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(3)})
    assert "replace" in synced
    # and the directory entry is fsync'd after the rename
    assert synced.index("replace") < len(synced) - 1


def test_orphaned_tmp_files_swept(tmp_path):
    """mkstemp leaks *.tmp forever if the writer crashes between write and
    rename — the next save must sweep them."""
    orphan = tmp_path / "abcdef.tmp"
    orphan.write_bytes(b"half-written checkpoint")
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(3)})
    names = sorted(os.listdir(tmp_path))
    assert names == ["ckpt_0000000001.msgpack"], names


def test_failed_save_leaves_no_tmp(tmp_path, monkeypatch):
    def boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(msgpack_ckpt.msgpack, "packb", boom)
    with pytest.raises(OSError):
        save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(3)})
    assert [n for n in os.listdir(tmp_path) if n.endswith(".tmp")] == []


def test_retain_tolerates_vanishing_files(tmp_path, monkeypatch):
    """A concurrent cleaner removing an old checkpoint between listdir and
    os.remove must not crash the save."""
    tree = {"w": jnp.zeros(3)}
    for s in range(3):
        save_checkpoint(str(tmp_path), s, tree, keep=10)

    real_remove = os.remove

    def racy_remove(path):
        real_remove(path)          # the file vanishes...
        raise FileNotFoundError(path)  # ...and the racer sees ENOENT

    monkeypatch.setattr(msgpack_ckpt.os, "remove", racy_remove)
    save_checkpoint(str(tmp_path), 3, tree, keep=1)  # must not raise
    monkeypatch.undo()
    assert all_steps(str(tmp_path)) == [3]


def test_retention_and_latest(tmp_path):
    tree = {"w": jnp.zeros(3)}
    for s in range(6):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    assert all_steps(str(tmp_path)) == [4, 5]
    assert latest_step(str(tmp_path)) == 5


# ---------------------------------------------------------------------------
# TrainState envelope: PRNG keys, controller, elastic rescale
# ---------------------------------------------------------------------------

def _train_state(workers=1, rank=2):
    key = jax.random.key(11)
    ef = EFState(
        error={"w": jnp.arange(float(workers * 6)).reshape(workers, 6)},
        momentum={"w": jnp.ones(6)},
        comp={"w": jax.random.normal(key, (6, rank)), "b": None},
        step=jnp.int32(4))
    return TrainState(params={"w": jnp.full((6,), 2.0)}, ef=ef, key=key,
                      data_step=jnp.int32(4))


def test_train_state_roundtrip_continues_prng_stream(tmp_path):
    st = _train_state()
    save_train_state(str(tmp_path), st, extra_meta={"last_residual": 0.5})
    restored, meta = restore_train_state(str(tmp_path), _train_state())
    assert meta["workers"] == 1 and meta["last_residual"] == 0.5
    # the restored key reproduces the same per-step stream
    a = jax.random.normal(jax.random.fold_in(st.key, 9))
    b = jax.random.normal(jax.random.fold_in(restored.key, 9))
    assert float(a) == float(b)
    assert int(restored.ef.step) == 4 and int(restored.data_step) == 4


def test_train_state_rejects_plain_checkpoint(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"params": {"w": jnp.zeros(3)}})
    with pytest.raises(CheckpointError, match="train_state_version"):
        restore_train_state(str(tmp_path), _train_state())


def test_restore_keeps_checkpoint_rank(tmp_path):
    """Template built at the configured rank, checkpoint mid-staircase at a
    different one: the checkpoint's factors win (the jitted step retraces);
    every non-factor leaf still shape-checks strictly."""
    save_train_state(str(tmp_path), _train_state(rank=2))
    restored, _ = restore_train_state(str(tmp_path), _train_state(rank=4))
    assert restored.ef.comp["w"].shape == (6, 2)


def test_restore_rescales_error_buffers(tmp_path):
    st = _train_state(workers=1)
    save_train_state(str(tmp_path), st)
    restored, meta = restore_train_state(str(tmp_path),
                                         _train_state(workers=4))
    assert meta["workers"] == 1
    err = np.asarray(restored.ef.error["w"])
    assert err.shape == (4, 6)
    for w in range(4):  # grow = bit-exact duplication
        np.testing.assert_array_equal(err[w], np.asarray(st.ef.error["w"][0]))


def test_controller_state_dict_roundtrip():
    c = RankController("1@0,2@3,4@6")
    c.update(None, 0)
    comp = {"w": jnp.zeros((8, 1))}
    comp, changed = c.update(comp, 3)
    assert changed and c.rank == 2
    c.observe(0.4)

    d = c.state_dict()
    c2 = RankController("1@0,2@3,4@6").load_state_dict(d)
    assert c2.rank == 2 and c2.history == c.history
    assert c2._ema == pytest.approx(c._ema)
    # the transition PRNG stream continues identically: the *next* growth
    # draws the same fresh columns in both controllers
    n1, _ = c.update({"w": jnp.zeros((8, 2))}, 6)
    n2, _ = c2.update({"w": jnp.zeros((8, 2))}, 6)
    np.testing.assert_array_equal(np.asarray(n1["w"]), np.asarray(n2["w"]))


def test_restore_records_rescale_provenance(tmp_path):
    """``meta["ef_rescale"]`` names the path that actually ran, and the
    coprime fallback warns (per-worker identity is silently lost otherwise)."""
    save_train_state(str(tmp_path), _train_state(workers=4))
    _, meta = restore_train_state(str(tmp_path), _train_state(workers=4))
    assert meta["ef_rescale"] == {"from": 4, "to": 4, "path": "identity"}
    _, meta = restore_train_state(str(tmp_path), _train_state(workers=8))
    assert meta["ef_rescale"] == {"from": 4, "to": 8, "path": "grow"}
    with pytest.warns(UserWarning, match="coprime EF rescale 4 -> 3"):
        _, meta = restore_train_state(str(tmp_path), _train_state(workers=3))
    assert meta["ef_rescale"]["path"] == "coprime-mean"
    # the saved meta itself is not polluted: provenance is restore-side only
    assert "ef_rescale" not in checkpoint_meta(str(tmp_path))


def test_rescale_path_values():
    assert rescale_path(4, 4) == "identity"
    assert rescale_path(1, 4) == "grow"
    assert rescale_path(4, 2) == "shrink"
    assert rescale_path(4, 3) == "coprime-mean"
    assert rescale_path(3, 7) == "coprime-mean"


def test_model_axis_mismatch_names_both_sizes(tmp_path):
    """A degree-2 envelope restored while claiming degree 4 must fail with a
    CheckpointError naming both sizes — model-local stacks cannot be
    re-sliced across model degrees."""
    save_train_state(str(tmp_path), _train_state(), model_axis_size=2,
                     mesh_shape={"data": 2, "model": 2})
    meta = checkpoint_meta(str(tmp_path))
    assert meta[MODEL_AXIS_KEY] == 2
    assert meta["mesh_shape"] == {"data": 2, "model": 2}
    with pytest.raises(CheckpointError,
                       match="model_axis_size=2.*model_axis_size=4"):
        restore_train_state(str(tmp_path), _train_state(), model_axis_size=4)
    # matching degree passes the guard
    restore_train_state(str(tmp_path), _train_state(), model_axis_size=2)


def test_legacy_envelope_treated_as_model_degree_1(tmp_path):
    """Envelopes saved before the stacked layout (no model_axis_size in
    meta) restore onto degree-1 meshes and are refused elsewhere."""
    save_train_state(str(tmp_path), _train_state())  # default degree 1
    restore_train_state(str(tmp_path), _train_state(), model_axis_size=1)
    with pytest.raises(CheckpointError, match="model_axis_size=1.*=2"):
        restore_train_state(str(tmp_path), _train_state(), model_axis_size=2)


def test_rescale_error_buffers_semantics():
    e = {"w": jnp.asarray(np.random.RandomState(0).randn(4, 5), jnp.float32)}
    # identity
    assert rescale_error_buffers(e, 4)["w"] is e["w"]
    # grow 4→8: duplication, worker-mean preserved exactly as a multiset
    g = np.asarray(rescale_error_buffers(e, 8)["w"])
    assert g.shape == (8, 5)
    np.testing.assert_array_equal(g[0], g[1])
    np.testing.assert_array_equal(g[::2], np.asarray(e["w"]))
    # shrink 4→2: pairwise means
    s = np.asarray(rescale_error_buffers(e, 2)["w"])
    np.testing.assert_allclose(
        s, np.asarray(e["w"]).reshape(2, 2, 5).mean(1), rtol=1e-6)
    # coprime 4→3: every buffer is the global mean (and the fallback warns)
    with pytest.warns(UserWarning, match="coprime"):
        c = np.asarray(rescale_error_buffers(e, 3)["w"])
    np.testing.assert_allclose(
        c, np.broadcast_to(np.asarray(e["w"]).mean(0), (3, 5)), rtol=1e-6)
    # the invariant all three branches share
    for scaled in (g, s, c):
        np.testing.assert_allclose(scaled.mean(0), np.asarray(e["w"]).mean(0),
                                   rtol=1e-5)
