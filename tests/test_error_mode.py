"""PowerSGD error_mode ablation: "global" (reference-impl style — error
measured against the aggregated reconstruction) vs "local" (Algorithm 2
literal — against the worker's own back-projection).

On a single worker the two are identical (Q_local == Q_aggregated); under
simulated multi-worker vmap they differ per worker but aggregate to the
same decompressed update."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import matrixize
from repro.core.dist import MeshCtx
from repro.core.powersgd import PowerSGDConfig, compress_aggregate, init_state

KEY = jax.random.key(0)
SPECS = {"w": matrixize.MatrixSpec("matrix", 0)}


def _state(cfg, shape):
    shapes = {"w": jax.ShapeDtypeStruct(shape, jnp.float32)}
    return init_state(cfg, shapes, SPECS, KEY)


def test_single_worker_modes_identical():
    g = {"w": jax.random.normal(KEY, (24, 16))}
    outs = {}
    for mode in ("global", "local"):
        cfg = PowerSGDConfig(rank=2, error_mode=mode)
        out = compress_aggregate(cfg, g, _state(cfg, (24, 16)), SPECS)
        outs[mode] = out
    np.testing.assert_allclose(np.asarray(outs["global"].recon["w"]),
                               np.asarray(outs["local"].recon["w"]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(outs["global"].agg["w"]),
                               np.asarray(outs["local"].agg["w"]), atol=1e-6)


def test_multi_worker_agg_matches_but_recon_is_local():
    """agg is identical across modes; local recon differs per worker and
    averages to the global one (linearity of the back-projection)."""
    W = 4
    ctx = MeshCtx(data_axes=("w",))
    gs = jnp.stack([jax.random.normal(jax.random.key(i), (24, 16))
                    for i in range(W)])

    results = {}
    for mode in ("global", "local"):
        cfg = PowerSGDConfig(rank=2, error_mode=mode)
        state = _state(cfg, (24, 16))

        def one(g):
            out = compress_aggregate(cfg, {"w": g}, state, SPECS, ctx)
            return out.agg["w"], out.recon["w"]

        agg, recon = jax.vmap(one, axis_name="w")(gs)
        results[mode] = (np.asarray(agg), np.asarray(recon))

    agg_g, recon_g = results["global"]
    agg_l, recon_l = results["local"]
    # aggregated update identical in both modes, and identical across workers
    np.testing.assert_allclose(agg_g, agg_l, atol=1e-5)
    np.testing.assert_allclose(agg_g[0], agg_g[-1], atol=1e-6)
    # global recon == agg (replicated); local recons differ per worker ...
    np.testing.assert_allclose(recon_g, agg_g, atol=1e-6)
    assert np.abs(recon_l[0] - recon_l[1]).max() > 1e-4
    # ... but their mean equals the aggregate (linearity)
    np.testing.assert_allclose(recon_l.mean(0), agg_l[0], atol=1e-5)
