import jax
import jax.numpy as jnp
import numpy as np

from repro.core import error_feedback as ef
from repro.core import matrixize
from repro.core.compressors import IdentityCompressor, PowerSGDCompressor
from repro.optim import sgd_apply, sgd_init

KEY = jax.random.key(0)


def _problem(seed=0):
    k = jax.random.key(seed)
    params = {"w": jax.random.normal(k, (20, 16)) * 0.1, "b": jnp.zeros((5,))}
    specs = {n: matrixize.default_spec(p) for n, p in params.items()}
    return params, specs


def test_identity_compressor_matches_alg2_recurrence():
    """EF-SGD with the identity compressor must equal Algorithm 2 / appendix
    recurrence (2) with Δ' = g exactly:

        m_{t+1} = λ m_t + Δ'_t ;  x_{t+1} = x_t − γ (Δ'_t + m_{t+1})

    and the error buffer must stay identically zero."""
    params, specs = _problem()
    comp = IdentityCompressor()
    state = ef.init_state(comp, params, specs, KEY)
    p_ef = params
    p_ref = params
    m_ref = jax.tree_util.tree_map(jnp.zeros_like, params)
    lr, lam = 0.01, 0.9
    for i in range(5):
        g = {"w": jax.random.normal(jax.random.key(i), (20, 16)),
             "b": jnp.ones((5,)) * 0.1}
        p_ef, state, _ = ef.apply_updates(
            comp, p_ef, g, state, specs, lr=lr, momentum=lam,
            weight_decay=0.0, key=KEY)
        m_ref = jax.tree_util.tree_map(lambda m, d: lam * m + d, m_ref, g)
        p_ref = jax.tree_util.tree_map(
            lambda x, d, m: x - lr * (d + m), p_ref, g, m_ref)
    for k in params:
        np.testing.assert_allclose(
            np.asarray(p_ef[k]), np.asarray(p_ref[k]), atol=1e-6)
    # error buffer stays identically zero
    assert float(jnp.abs(state.error["w"]).max()) == 0.0


def test_error_accumulates_the_residual():
    params, specs = _problem()
    comp = PowerSGDCompressor(rank=1)
    state = ef.init_state(comp, params, specs, KEY)
    g = {"w": jax.random.normal(KEY, (20, 16)), "b": jnp.zeros((5,))}
    new_p, new_state, _ = ef.apply_updates(
        comp, params, g, state, specs, lr=0.0, momentum=0.9,
        weight_decay=0.0, key=KEY)
    # lr=0: params unchanged; e₁ = g − decompress(compress(g))
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(params["w"]))
    resid = np.asarray(g["w"]) - np.asarray(
        comp.step(g, state.comp, specs, key=KEY).agg["w"])
    np.testing.assert_allclose(np.asarray(new_state.error["w"]), resid, atol=1e-5)


def test_error_feedback_recovers_signal_over_time():
    """The defining property of EF: the *cumulative* applied update tracks
    the cumulative gradient even under aggressive rank-1 compression.

    With momentum 0 and lr 1, Algorithm 2 applies 2·Δ'_t per step
    (x ← x − γ(Δ' + m) with m = Δ'), and EF guarantees ΣΔ'_t → T·g for a
    constant gradient — so the total applied update approaches 2·T·g."""
    params, specs = _problem()
    comp = PowerSGDCompressor(rank=1)
    state = ef.init_state(comp, params, specs, KEY)
    g = {"w": jax.random.normal(KEY, (20, 16)), "b": jnp.zeros((5,))}
    p = params
    T = 120
    for _ in range(T):
        p, state, _ = ef.apply_updates(
            comp, p, g, state, specs, lr=1.0, momentum=0.0,
            weight_decay=0.0, key=KEY)
    applied = np.asarray(params["w"]) - np.asarray(p["w"])
    target = 2 * T * np.asarray(g["w"])
    rel = np.linalg.norm(applied - target) / np.linalg.norm(target)
    assert rel < 0.1, rel


def test_weight_decay_skips_uncompressed():
    """Paper: weight decay 0 for BatchNorm (uncompressed) parameters."""
    params, specs = _problem()
    comp = IdentityCompressor()
    state = ef.init_state(comp, params, specs, KEY)
    g = {"w": jnp.zeros((20, 16)), "b": jnp.zeros((5,))}
    params = {"w": params["w"], "b": jnp.ones((5,))}
    new_p, _, _ = ef.apply_updates(
        comp, params, g, state, specs, lr=0.1, momentum=0.0,
        weight_decay=0.1, key=KEY)
    np.testing.assert_array_equal(np.asarray(new_p["b"]), np.ones(5))
    assert float(jnp.abs(new_p["w"] - params["w"]).max()) > 0.0


def test_momentum_is_post_compression():
    """Alg. 2: m ← λm + Δ' uses the *decompressed aggregate*, not the raw
    gradient — check against a manual computation."""
    params, specs = _problem()
    comp = PowerSGDCompressor(rank=1)
    state = ef.init_state(comp, params, specs, KEY)
    g = {"w": jax.random.normal(KEY, (20, 16)), "b": jnp.zeros((5,))}
    out = comp.step(g, state.comp, specs, key=jax.random.fold_in(KEY, 0))
    new_p, new_state, _ = ef.apply_updates(
        comp, params, g, state, specs, lr=0.5, momentum=0.9,
        weight_decay=0.0, key=KEY)
    delta = np.asarray(out.agg["w"])
    m1 = 0.9 * 0 + delta
    expect = np.asarray(params["w"]) - 0.5 * (delta + m1)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, atol=1e-5)
