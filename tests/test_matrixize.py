import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import matrixize
from repro.core.matrixize import MatrixSpec


@settings(deadline=None, max_examples=30)
@given(
    n=st.integers(1, 32),
    m=st.integers(1, 32),
    b=st.integers(0, 3),
    seed=st.integers(0, 1000),
)
def test_matrix_roundtrip(n, m, b, seed):
    batch = tuple(np.random.RandomState(seed).randint(1, 4, size=b))
    shape = batch + (n, m)
    x = jax.random.normal(jax.random.key(seed), shape)
    spec = MatrixSpec("matrix", b)
    mat = matrixize.to_matrix(x, spec)
    assert mat.shape == batch + (n, m)
    back = matrixize.from_matrix(mat, shape, spec)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_conv_flattening_matches_paper_table10():
    """Paper Appendix F: layer4.1.conv2 (512,512,3,3) → 512×4608, 9216 KB,
    compression 461/r×."""
    shape = (512, 512, 3, 3)
    spec = MatrixSpec("conv", 0)
    ms = matrixize.matrix_shape(shape, spec)
    assert ms == ((), 512, 4608)
    uncompressed_kb = int(np.prod(shape)) * 4 // 1024
    assert uncompressed_kb == 9216
    r = 1
    ratio = int(np.prod(shape)) / matrixize.compressed_floats(shape, spec, r)
    assert abs(ratio - 461) < 1.0  # paper: 461/r×


def test_lstm_encoder_matches_paper_table11():
    """encoder (28869, 650): compression 636/r×."""
    shape = (28869, 650)
    spec = MatrixSpec("matrix", 0)
    ratio = int(np.prod(shape)) / matrixize.compressed_floats(shape, spec, 1)
    assert abs(ratio - 636) < 1.0


def test_vector_exempt():
    spec = matrixize.default_spec(jax.ShapeDtypeStruct((128,), jnp.float32))
    assert not spec.is_compressed()
    assert matrixize.matrix_shape((128,), spec) is None
    assert matrixize.compressed_floats((128,), spec, 4) == 128


def test_default_spec_conv():
    spec = matrixize.default_spec(jax.ShapeDtypeStruct((64, 3, 3, 3), jnp.float32))
    assert spec.kind == "conv"
    assert matrixize.matrix_shape((64, 3, 3, 3), spec) == ((), 64, 27)
