"""The α-β autotuner (core/autotune.py): budget feasibility, the greedy
per-bucket rank assignment, wire-policy selection, plan application, and —
the CI smoke — the collective-budget invariant under a tuned (mixed-rank)
configuration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, matrixize, powersgd
from repro.core.dist import CollectiveStats, MeshCtx

KEY = jax.random.key(0)


def _tree():
    specs = {"big": matrixize.MatrixSpec("matrix", 0),
             "big2": matrixize.MatrixSpec("matrix", 0),
             "small": matrixize.MatrixSpec("matrix", 0),
             "v": matrixize.NONE}
    shapes = {"big": jax.ShapeDtypeStruct((256, 128), jnp.float32),
              "big2": jax.ShapeDtypeStruct((250, 128), jnp.float32),
              "small": jax.ShapeDtypeStruct((16, 8), jnp.float32),
              "v": jax.ShapeDtypeStruct((64,), jnp.float32)}
    return shapes, specs


def _budget(shapes, specs, rank):
    return powersgd.compressed_floats_total(shapes, specs, rank) * 32


# ---------------------------------------------------------------------------
# hardware model
# ---------------------------------------------------------------------------

def test_hardware_model_sources():
    hw = autotune.HardwareModel.from_roofline()
    assert hw.bw == pytest.approx(50e9)
    nccl = autotune.HardwareModel.from_backend("nccl_10gbit")
    gloo = autotune.HardwareModel.from_backend("gloo_10gbit")
    assert nccl.bw > gloo.bw and nccl.alpha < gloo.alpha


def test_collective_time_shapes():
    hw = autotune.HardwareModel(alpha=1e-5, bw=1e9)
    assert hw.collective_time(1e6, 1) == 0.0
    r4, r8 = (hw.collective_time(1e6, w, "reduce") for w in (4, 8))
    assert 0 < r4 < r8 < 2 * 1e6 / 1e9 + 1e-3  # bounded by 2·bytes/bw + α
    # gather pays the (W−1)-fold receive traffic
    assert hw.collective_time(1e6, 8, "gather") > r8


def test_comm_time_from_stats_matches_model():
    hw = autotune.HardwareModel.from_backend("nccl_10gbit")
    stats = CollectiveStats()
    stats.record(1000, itemsize=4, kind="reduce")
    stats.record(500, itemsize=2, kind="gather", fanout=8)
    want = (hw.collective_time(4000, 8, "reduce")
            + hw.collective_time(1000, 8, "gather"))
    assert autotune.comm_time_from_stats(stats, 8, hw) == pytest.approx(want)


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------

def test_budget_respected_and_decisions_cover_buckets():
    shapes, specs = _tree()
    budget = _budget(shapes, specs, 4)
    plan = autotune.autotune(shapes, specs, bits_budget=budget, workers=8)
    unc = plan.uncompressed_floats
    assert plan.payload_floats * 32 <= budget - unc * 32
    assert plan.bits_per_step == (plan.payload_floats + unc) * 32
    assert len(plan.decisions) >= 2          # big bucket + small bucket
    assert len(plan.leaf_ranks) == 4
    assert plan.leaf_ranks[list(shapes).index("v")] is None


def test_bigger_budget_never_lowers_ranks():
    shapes, specs = _tree()
    lo = autotune.autotune(shapes, specs,
                           bits_budget=_budget(shapes, specs, 2), workers=8)
    hi = autotune.autotune(shapes, specs,
                           bits_budget=_budget(shapes, specs, 8), workers=8)
    assert hi.payload_floats >= lo.payload_floats
    for dl, dh in zip(lo.decisions, hi.decisions):
        assert dh.rank >= dl.rank


def test_infeasible_budget_degrades_to_min_rank():
    shapes, specs = _tree()
    plan = autotune.autotune(shapes, specs, bits_budget=1, workers=8,
                             ranks=(1, 2, 4))
    assert all(d.rank == 1 for d in plan.decisions)


def test_wire_dtype_selection_prefers_cheaper_wire():
    shapes, specs = _tree()
    budget = _budget(shapes, specs, 4)
    both = autotune.autotune(shapes, specs, bits_budget=budget, workers=8,
                             wire_dtypes=("float32", "bfloat16"))
    f32 = autotune.autotune(shapes, specs, bits_budget=budget, workers=8,
                            wire_dtypes=("float32",))
    assert both.wire_dtype == "bfloat16"     # half the β term
    assert f32.wire_dtype == "float32"
    assert both.predicted_comm_s < f32.predicted_comm_s
    # same bits accounting either way: the budget is payload bits, not wire
    assert both.bits_per_step == f32.bits_per_step
    with pytest.raises(ValueError):
        autotune.autotune(shapes, specs, bits_budget=budget, workers=8,
                          wire_dtypes=("auto",))


def test_max_chunk_bytes_candidates_add_latency_only():
    shapes, specs = _tree()
    budget = _budget(shapes, specs, 4)
    plan = autotune.autotune(
        shapes, specs, bits_budget=budget, workers=8,
        max_chunk_bytes_options=(None, 4096))
    # with no pipelining in the α-β model, splitting only adds α rounds
    assert plan.max_chunk_bytes is None


def test_single_worker_predicts_zero_comm():
    shapes, specs = _tree()
    plan = autotune.autotune(shapes, specs,
                             bits_budget=_budget(shapes, specs, 4), workers=1)
    assert plan.predicted_comm_s == 0.0


def test_measured_residuals_steer_the_walk_down():
    """A bucket whose measured residual is ~0 (subspace already covers its
    gradients) must be cut before one that is starved."""
    shapes, specs = _tree()
    budget = _budget(shapes, specs, 3)  # forces some bucket below max
    n_buckets = len(autotune.autotune(shapes, specs, bits_budget=budget,
                                      workers=8).decisions)
    assert n_buckets >= 2
    # big bucket saturated (residual 1.0), others covered (0.0)
    residuals = [1.0] + [0.0] * (n_buckets - 1)
    plan = autotune.autotune(shapes, specs, bits_budget=budget, workers=8,
                             bucket_residuals=residuals)
    ranks = [d.rank for d in plan.decisions]
    assert ranks[0] == max(ranks), ranks


def test_rank_capped_at_compressive_bound_per_bucket():
    """No bucket may be assigned a rank above min(n, m) or above the point
    where r·(n+m) exceeds n·m — 'compression' that beats sending dense."""
    specs = {"tiny": matrixize.MatrixSpec("matrix", 0),
             "big": matrixize.MatrixSpec("matrix", 0)}
    shapes = {"tiny": jax.ShapeDtypeStruct((16, 4), jnp.float32),
              "big": jax.ShapeDtypeStruct((256, 128), jnp.float32)}
    plan = autotune.autotune(shapes, specs, bits_budget=10**9, workers=8,
                             ranks=(1, 2, 4, 8))
    for d in plan.decisions:
        for e_rank, n, m in [(d.rank, d.n, d.m)]:
            assert e_rank <= min(n, m)
            assert e_rank * (n + m) <= n * m, (d, "worse than dense")


def test_plan_tolerance_threads_into_tuned_compressor():
    """A plan computed at a non-default tolerance must hand the engine the
    same tolerance, or the engine's own bucket plan diverges and mixes
    ranks inside a bucket (ValueError at the first step)."""
    specs = {f"l{i}/w": matrixize.MatrixSpec("matrix", 0) for i in range(2)}
    shapes = {"l0/w": jax.ShapeDtypeStruct((32, 16), jnp.float32),
              "l1/w": jax.ShapeDtypeStruct((30, 16), jnp.float32)}
    plan = autotune.autotune(shapes, specs, bits_budget=10**9, workers=8,
                             tolerance=0.0)
    comp = autotune.make_tuned_compressor(plan)
    assert comp.cfg.bucket_pad_tolerance == 0.0
    state = autotune.apply_plan(plan, comp.init(shapes, specs, KEY),
                                shapes, specs, KEY)
    grads = jax.tree_util.tree_map(
        lambda s: jax.random.normal(KEY, s.shape, s.dtype), shapes)
    out = comp.step(grads, state, specs, key=KEY)  # must not raise
    assert out.bits_per_worker == plan.bits_per_step


def test_deterministic():
    shapes, specs = _tree()
    kw = dict(bits_budget=_budget(shapes, specs, 4), workers=8)
    a = autotune.autotune(shapes, specs, **kw)
    b = autotune.autotune(shapes, specs, **kw)
    assert a == b


# ---------------------------------------------------------------------------
# applying a plan to a live compressor (the CI autotuner smoke)
# ---------------------------------------------------------------------------

def test_apply_plan_installs_ranks_and_budget_guard_holds():
    """End-to-end: tune under a budget, install the per-bucket ranks with
    warm-start-preserving transitions, and verify the engine still issues
    ≤ 2 fused data collectives with the mixed-rank state — the autotuner
    variant of the CI collective-budget regression guard."""
    shapes, specs = _tree()
    plan = autotune.autotune(shapes, specs,
                             bits_budget=_budget(shapes, specs, 4) // 2,
                             workers=16)
    comp = autotune.make_tuned_compressor(plan)
    state = comp.init(shapes, specs, KEY)
    state2 = autotune.apply_plan(plan, state, shapes, specs, KEY)

    rank_tree = plan.rank_tree(shapes, specs)
    for k, r in rank_tree.items():
        if r is None:
            continue
        assert state2[k].shape[-1] == r
        keep = min(r, state[k].shape[-1])
        np.testing.assert_array_equal(            # bit-exact warm start
            np.asarray(state2[k][..., :keep]),
            np.asarray(state[k][..., :keep]))

    grads = jax.tree_util.tree_map(
        lambda s: jax.random.normal(KEY, s.shape, s.dtype), shapes)
    stats = CollectiveStats()
    out = comp.step(grads, state2, specs, ctx=MeshCtx(stats=stats), key=KEY)
    assert stats.data_collectives <= 2, stats.sizes
    assert stats.gather_collectives == 0
    assert out.bits_per_worker == plan.bits_per_step
    # explicit wire dtype ⇒ the chunks actually travel at that itemsize
    want = {"float32": 4, "bfloat16": 2, "int8": 1, "int4": 0.5}
    assert set(stats.itemsizes) == {want[plan.wire_dtype]}


# ---------------------------------------------------------------------------
# quantized wire pricing (ISSUE 9): one budget buys rank OR precision
# ---------------------------------------------------------------------------

def test_quantized_wire_trades_precision_for_rank():
    """The acceptance case: under a tight bits budget the joint
    (rank, wire_dtype) walk must land on a configuration a rank-only walk
    cannot reach — int4 re-prices every payload float at 4 bits, so the
    same budget affords 8× the tracked directions."""
    shapes, specs = _tree()
    tight = _budget(shapes, specs, 1)  # one rank-1 float32 step's bits
    rank_only = autotune.autotune(shapes, specs, bits_budget=tight,
                                  workers=8, wire_dtypes=("float32",))
    joint = autotune.autotune(shapes, specs, bits_budget=tight, workers=8,
                              wire_dtypes=("float32", "int4"))
    # the rank-only walk is pinned to rank 1 everywhere by this budget
    assert all(d.rank == 1 for d in rank_only.decisions)
    assert joint.wire_dtype == "int4"
    assert joint.payload_floats > rank_only.payload_floats
    assert (max(d.rank for d in joint.decisions)
            > max(d.rank for d in rank_only.decisions))
    # and the honest wire accounting still beats the float32 plan: more
    # directions AND fewer bits on the wire
    assert joint.wire_bits_per_step < rank_only.wire_bits_per_step
    # paper-convention bits reflect the extra floats; the honest field is new
    assert joint.bits_per_step == (joint.payload_floats
                                   + joint.uncompressed_floats) * 32


def test_quantized_wire_budget_scaling_monotone():
    """int8 buys 4× and int4 8× the float32 budget floats — payload floats
    under one fixed budget must be monotone in the wire width."""
    shapes, specs = _tree()
    tight = _budget(shapes, specs, 1)
    pays = {}
    for wd in ("float32", "int8", "int4"):
        plan = autotune.autotune(shapes, specs, bits_budget=tight, workers=8,
                                 wire_dtypes=(wd,))
        assert plan.wire_dtype == wd
        pays[wd] = plan.payload_floats
    assert pays["float32"] < pays["int8"] <= pays["int4"]


def test_comm_time_from_stats_prices_scale_sidecar():
    """Fractional itemsizes and overhead bytes flow into the α-β model."""
    hw = autotune.HardwareModel.from_backend("nccl_10gbit")
    stats = CollectiveStats()
    stats.record(1000, itemsize=0.5, kind="reduce", overhead=8)
    want = hw.collective_time(508, 8, "reduce")
    assert autotune.comm_time_from_stats(stats, 8, hw) == pytest.approx(want)
