"""Unit tests for model components (single device)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.dist import SINGLE
from repro.models import attention, common, mamba2, moe

KEY = jax.random.key(0)


def test_sharded_softmax_xent_matches_log_softmax():
    logits = jax.random.normal(KEY, (4, 9, 32))
    labels = jax.random.randint(KEY, (4, 9), 0, 32)
    got = common.sharded_softmax_xent(logits, labels, SINGLE, vocab=32)
    want = -jnp.take_along_axis(
        jax.nn.log_softmax(logits), labels[..., None], axis=-1)[..., 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_sharded_softmax_xent_masks_padded_vocab():
    logits = jnp.concatenate(
        [jax.random.normal(KEY, (2, 3, 10)), jnp.full((2, 3, 6), 100.0)], -1)
    labels = jax.random.randint(KEY, (2, 3), 0, 10)
    got = common.sharded_softmax_xent(logits, labels, SINGLE, vocab=10)
    want = -jnp.take_along_axis(
        jax.nn.log_softmax(logits[..., :10]), labels[..., None], -1)[..., 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_rope_is_relative():
    """q·k after RoPE depends only on the position difference."""
    hd = 64
    q = jax.random.normal(KEY, (1, 1, 1, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, hd))
    def dot(p1, p2):
        qq = common.apply_rope(q, jnp.array([[p1]]), 10000.0)
        kk = common.apply_rope(k, jnp.array([[p2]]), 10000.0)
        return float(jnp.sum(qq * kk))
    assert abs(dot(3, 1) - dot(10, 8)) < 1e-3
    assert abs(dot(3, 1) - dot(5, 1)) > 1e-4  # but not position-free


def test_chunked_attention_matches_dense():
    cfg = get_config("llama3-8b", reduced=True)
    p = attention.init(KEY, cfg, 1)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model)) * 0.1
    full = attention.forward(p, x, cfg, SINGLE, q_chunk=64)
    chunked = attention.forward(p, x, cfg, SINGLE, q_chunk=8)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked), atol=2e-4)


def test_sliding_window_masks_far_context():
    """With window w, position i must not attend to j ≤ i−w: perturbing a
    token outside every query's window leaves those outputs unchanged."""
    cfg = get_config("llama3-8b", reduced=True)
    p = attention.init(KEY, cfg, 1)
    x = jax.random.normal(KEY, (1, 64, cfg.d_model)) * 0.1
    w = 16
    out1 = attention.forward(p, x, cfg, SINGLE, q_chunk=16, window=w)
    x2 = x.at[:, 0].add(10.0)
    out2 = attention.forward(p, x2, cfg, SINGLE, q_chunk=16, window=w)
    # queries at positions ≥ 16 cannot see position 0
    np.testing.assert_allclose(np.asarray(out1[:, w + 1:]),
                               np.asarray(out2[:, w + 1:]), atol=1e-4)
    # but position 1 can
    assert float(jnp.abs(out1[:, 1] - out2[:, 1]).max()) > 1e-4


def test_window_attention_matches_full_for_short_seq():
    cfg = get_config("llama3-8b", reduced=True)
    p = attention.init(KEY, cfg, 1)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model)) * 0.1
    full = attention.forward(p, x, cfg, SINGLE, q_chunk=8)
    win = attention.forward(p, x, cfg, SINGLE, q_chunk=8, window=64)
    np.testing.assert_allclose(np.asarray(full), np.asarray(win), atol=2e-4)


def test_ssd_scan_matches_naive_recurrence():
    """Chunked SSD vs a direct per-step recurrence."""
    b, s, h, p, n = 2, 32, 3, 4, 8
    xh = jax.random.normal(KEY, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, h)))
    bm = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, n))
    cm = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, n))
    a_neg = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 4), (h,)))

    y_chunk, h_fin = mamba2._ssd_scan(xh, dt, bm, cm, a_neg, chunk=8)

    hstate = np.zeros((b, h, n, p))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        decay = np.exp(np.asarray(dt[:, t]) * np.asarray(a_neg))  # (b,h)
        upd = np.einsum("bh,bn,bhp->bhnp", np.asarray(dt[:, t]),
                        np.asarray(bm[:, t]), np.asarray(xh[:, t]))
        hstate = decay[:, :, None, None] * hstate + upd
        ys[:, t] = np.einsum("bn,bhnp->bhp", np.asarray(cm[:, t]), hstate)
    np.testing.assert_allclose(np.asarray(y_chunk), ys, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_fin), hstate, atol=1e-3, rtol=1e-3)


def test_moe_no_drops_equals_dense_mixture():
    """With unlimited capacity, the MoE output equals the explicit
    gate-weighted sum over selected experts."""
    import dataclasses

    cfg = get_config("olmoe-1b-7b", reduced=True)
    cfg = dataclasses.replace(cfg, moe_capacity_factor=100.0)
    p = moe.init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 8, cfg.d_model)) * 0.5
    out, aux = moe.forward(p, x, cfg, SINGLE)

    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, experts = jax.lax.top_k(probs, cfg.moe_top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    want = np.zeros_like(np.asarray(xt))
    for t in range(xt.shape[0]):
        for j in range(cfg.moe_top_k):
            e = int(experts[t, j])
            h = np.asarray(xt[t])
            g = jax.nn.silu(h @ p["w_gate"][e]) * (h @ p["w_up"][e])
            want[t] += float(gates[t, j]) * np.asarray(g @ p["w_down"][e])
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model),
                               want, atol=1e-3, rtol=1e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    import dataclasses

    cfg = get_config("olmoe-1b-7b", reduced=True)
    tight = dataclasses.replace(cfg, moe_capacity_factor=0.01)
    p = moe.init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model))
    out_t, _ = moe.forward(p, x, tight, SINGLE)
    out_f, _ = moe.forward(p, x, cfg, SINGLE)
    assert float(jnp.abs(out_t - out_f).max()) > 1e-6


def test_embed_lookup_and_head_padding():
    cfg = get_config("llama3-8b", reduced=True)
    table = jax.random.normal(KEY, (cfg.vocab_size, 16))
    ids = jax.random.randint(KEY, (2, 5), 0, cfg.vocab_size)
    out = common.embed_lookup(table, ids, SINGLE)
    np.testing.assert_allclose(np.asarray(out), np.asarray(table[ids]), atol=1e-6)
