"""The adaptive-rank subsystem (core/powersgd.py): schedule policies,
warm-start-preserving transitions, state-carried rank in both compress
paths, and the bits accounting following the active ranks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, matrixize, powersgd
from repro.core.compressors import PowerSGDCompressor
from repro.core.dist import CollectiveStats, MeshCtx
from repro.core.powersgd import (FixedRank, PowerSGDConfig, RankController,
                                 ResidualEnergyRank, StaircaseRank,
                                 parse_schedule, transition_factor,
                                 transition_state)

KEY = jax.random.key(0)


# ---------------------------------------------------------------------------
# schedule policies + parsing
# ---------------------------------------------------------------------------

def test_parse_schedule_forms():
    assert parse_schedule(4) == FixedRank(rank=4)
    assert parse_schedule("4") == FixedRank(rank=4)
    assert parse_schedule("4@0,2@60,1@120") == StaircaseRank(
        milestones=((0, 4), (60, 2), (120, 1)))
    assert parse_schedule([(0, 4), (10, 2)]) == StaircaseRank(
        milestones=((0, 4), (10, 2)))
    r = parse_schedule("residual:min=1,max=16,init=4,every=5")
    assert r == ResidualEnergyRank(min_rank=1, max_rank=16, init_rank=4,
                                   every=5)
    sched = parse_schedule(StaircaseRank(milestones=((0, 3),)))
    assert isinstance(sched, StaircaseRank)
    with pytest.raises(TypeError):
        parse_schedule(None)


def test_staircase_rank_at_steps():
    s = StaircaseRank(milestones=((0, 4), (60, 2), (120, 1)))
    assert s.initial_rank() == 4
    assert [s.next_rank(t, 4) for t in (0, 59, 60, 119, 120, 999)] == \
        [4, 4, 2, 2, 1, 1]


def test_staircase_rejects_uncovered_step_zero():
    with pytest.raises(AssertionError):
        StaircaseRank(milestones=((10, 4),))


def test_residual_energy_hysteresis():
    s = ResidualEnergyRank(min_rank=1, max_rank=8, init_rank=2,
                           shrink_below=0.3, grow_above=0.7, every=5)
    # off-cadence steps and missing residuals never move the rank
    assert s.next_rank(3, 2, 0.9) == 2
    assert s.next_rank(5, 2, None) == 2
    # in-band residual holds, outside the band doubles/halves
    assert s.next_rank(5, 2, 0.5) == 2
    assert s.next_rank(5, 2, 0.9) == 4
    assert s.next_rank(5, 8, 0.9) == 8      # clamped at max
    assert s.next_rank(5, 2, 0.1) == 1
    assert s.next_rank(5, 1, 0.1) == 1      # clamped at min


def test_rank_controller_staircase_transitions():
    state = {"w": jax.random.normal(KEY, (16, 4)), "b": None}
    ctl = RankController("4@0,2@3,1@6")
    ranks = []
    for step in range(8):
        state, _ = ctl.update(state, step)
        ranks.append(state["w"].shape[-1])
    assert ranks == [4, 4, 4, 2, 2, 2, 1, 1]
    assert ctl.history == [(0, 4), (3, 2), (6, 1)]


def test_rank_controller_residual_driven():
    state = {"w": jax.random.normal(KEY, (16, 2))}
    ctl = RankController(ResidualEnergyRank(min_rank=1, max_rank=8,
                                            init_rank=2, every=1, ema=0.0))
    state, changed = ctl.update(state, 1, residual=0.9)  # starved: grow
    assert changed and state["w"].shape[-1] == 4
    state, changed = ctl.update(state, 2, residual=0.05)  # over-covered
    assert changed and state["w"].shape[-1] == 2


# ---------------------------------------------------------------------------
# warm-start-preserving transitions (the bit-consistency contract)
# ---------------------------------------------------------------------------

def test_transition_truncate_keeps_leading_columns_bitexact():
    q = jax.random.normal(KEY, (3, 16, 4))  # leading layer-stack dim
    q2 = transition_factor(q, 2, KEY)
    assert q2.shape == (3, 16, 2)
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q[..., :2]))


def test_transition_grow_keeps_existing_columns_bitexact():
    q = jax.random.normal(KEY, (16, 2))
    q2 = transition_factor(q, 5, KEY)
    assert q2.shape == (16, 5)
    np.testing.assert_array_equal(np.asarray(q2[:, :2]), np.asarray(q))
    # fresh columns are non-degenerate exploration directions
    assert float(jnp.abs(q2[:, 2:]).max()) > 0


def test_transition_grow_broadcasts_over_leading_dims():
    """New columns are drawn once and broadcast over stacking dims, so a
    replicated (e.g. SimMesh worker) leading axis stays bit-replicated."""
    q = jnp.broadcast_to(jax.random.normal(KEY, (16, 2))[None], (4, 16, 2))
    q2 = np.asarray(transition_factor(q, 4, KEY))
    assert (q2 == q2[:1]).all()


def test_transition_noop_returns_same_object():
    q = jax.random.normal(KEY, (16, 3))
    assert transition_factor(q, 3, KEY) is q


def test_transition_state_uniform_and_per_leaf():
    state = {"a": jax.random.normal(KEY, (8, 4)),
             "b": jax.random.normal(KEY, (6, 4)),
             "v": None}
    uni = transition_state(state, 2, KEY)
    assert uni["a"].shape == (8, 2) and uni["b"].shape == (6, 2)
    assert uni["v"] is None
    per = transition_state(state, {"a": 1, "b": None, "v": None}, KEY)
    assert per["a"].shape == (8, 1)
    assert per["b"] is state["b"]          # None rank = leave untouched


# ---------------------------------------------------------------------------
# state-carried rank through both compress paths
# ---------------------------------------------------------------------------

def _tree():
    grads = {"a": jax.random.normal(KEY, (24, 16)),
             "b": jax.random.normal(jax.random.fold_in(KEY, 1), (23, 16)),
             "c": jax.random.normal(jax.random.fold_in(KEY, 2), (64, 32)),
             "v": jnp.ones((16,))}
    specs = {k: matrixize.default_spec(v) for k, v in grads.items()}
    shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads)
    return grads, specs, shapes


@pytest.mark.parametrize("bucketing", ["auto", "off"])
def test_bits_follow_state_ranks(bucketing):
    grads, specs, shapes = _tree()
    cfg = PowerSGDConfig(rank=4, bucketing=bucketing)
    state = powersgd.init_state(cfg, shapes, specs, KEY)
    out4 = powersgd.compress_aggregate(cfg, grads, state, specs)
    assert out4.bits_per_worker == \
        powersgd.compressed_floats_total(shapes, specs, 4) * 32
    # rank switch: same cfg object, bits follow the transitioned state
    state2 = transition_state(state, 2, KEY)
    out2 = powersgd.compress_aggregate(cfg, grads, state2, specs)
    assert out2.bits_per_worker == \
        powersgd.compressed_floats_total(shapes, specs, 2) * 32
    assert out2.bits_per_worker < out4.bits_per_worker


def test_mixed_per_bucket_ranks_bucketed_matches_per_leaf():
    """Different buckets at different ranks: the fused engine must match the
    per-leaf reference path at every leaf."""
    grads, specs, shapes = _tree()
    cfg = PowerSGDConfig(rank=4, bucketing="auto", bucket_pad_tolerance=0.25)
    state = powersgd.init_state(cfg, shapes, specs, KEY)
    # a/b share the (24,16)-ish bucket -> rank 2; c alone -> rank 4
    ranks = {"a": 2, "b": 2, "c": 4, "v": None}
    state = transition_state(state, ranks, KEY)

    out = powersgd.compress_aggregate(cfg, grads, state, specs)
    cfg_ref = powersgd.PowerSGDConfig(rank=4, bucketing="off")
    ref = powersgd.compress_aggregate(cfg_ref, grads, state, specs)
    for k in grads:
        np.testing.assert_allclose(np.asarray(out.agg[k]),
                                   np.asarray(ref.agg[k]), atol=1e-5)
    for k, r in ranks.items():
        if r is not None:
            assert out.state[k].shape[-1] == r
    assert out.bits_per_worker == ref.bits_per_worker == \
        powersgd.compressed_floats_total(shapes, specs, state) * 32


def test_mixed_ranks_inside_one_bucket_rejected():
    grads, specs, shapes = _tree()
    cfg = PowerSGDConfig(rank=4, bucketing="auto")
    state = powersgd.init_state(cfg, shapes, specs, KEY)
    state = transition_state(state, {"a": 2, "b": 4, "c": 4, "v": None}, KEY)
    with pytest.raises(ValueError, match="share a rank"):
        powersgd.compress_aggregate(cfg, grads, state, specs)


def test_compressed_floats_total_state_tree():
    grads, specs, shapes = _tree()
    cfg = PowerSGDConfig(rank=3)
    state = powersgd.init_state(cfg, shapes, specs, KEY)
    assert powersgd.compressed_floats_total(shapes, specs, state) == \
        powersgd.compressed_floats_total(shapes, specs, 3)


def test_residual_metrics_reported_and_shrink_with_rank():
    """track_residual emits the ‖M−P̂Qᵀ‖/‖M‖ signal; more rank captures more
    energy, so the ratio must fall as rank grows."""
    grads, specs, shapes = _tree()
    ratios = {}
    for r in (1, 8):
        cfg = PowerSGDConfig(rank=r, track_residual=True)
        state = powersgd.init_state(cfg, shapes, specs, KEY)
        out = powersgd.compress_aggregate(cfg, grads, state, specs)
        assert out.metrics is not None
        assert out.metrics["bucket_residual_ratio"].shape[0] >= 1
        ratios[r] = float(out.metrics["residual_ratio"])
        assert 0.0 <= ratios[r] <= 1.5
    assert ratios[8] < ratios[1]


def test_transition_then_compress_keeps_two_collective_budget():
    """The collective-budget guard with a schedule active: every stage of a
    staircase stays within the fused engine's 2-collectives-per-step."""
    grads, specs, shapes = _tree()
    comp = PowerSGDCompressor(rank_schedule="4@0,2@2,1@4")
    state = comp.init(shapes, specs, KEY)
    ctl = comp.controller()
    for step in range(6):
        state, _ = ctl.update(state, step)
        stats = CollectiveStats()
        out = comp.step(grads, state, specs, ctx=MeshCtx(stats=stats),
                        key=KEY)
        state = out.state
        assert stats.data_collectives <= 2, (step, stats.sizes)
    assert state["a"].shape[-1] == 1
