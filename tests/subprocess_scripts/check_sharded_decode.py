"""Subprocess check: sharded decode (batch-sharded and seq-sharded cache
layouts) reproduces single-device decode token-for-token."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.launch.serve import make_decode_step
from repro.launch import specs as specs_lib
from repro.configs.base import get_config, InputShape
from repro.models import model as model_lib
from repro.core.dist import SINGLE


def main():
    key = jax.random.key(0)
    for arch in ["llama3-8b", "mamba2-1.3b", "jamba-v0.1-52b"]:
        for shp in [InputShape("batchsharded", 64, 8, "decode"),
                    InputShape("seqsharded", 64, 1, "decode")]:
            cfg = dataclasses.replace(get_config(arch, reduced=True),
                                      decode_window=0)
            m = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
            step_fn, _ = make_decode_step(cfg, m, shp)
            params = model_lib.init(key, cfg, 2)
            b = shp.global_batch
            toks = jax.random.randint(jax.random.key(1), (b, 6), 0, cfg.vocab_size)
            c_ref = model_lib.init_cache(cfg, 1, b, shp.seq_len)
            for pos in range(6):
                nxt_ref, lg, c_ref = model_lib.decode_step(
                    params, c_ref, toks[:, pos:pos + 1], jnp.int32(pos), cfg, SINGLE)
            with jax.set_mesh(m):
                layout = specs_lib.decode_layout(cfg, shp, ("pod", "data"))
                cache = model_lib.init_cache(cfg, 1, b, shp.seq_len)
                _, cache_ps = specs_lib.abstract_cache(cfg, layout, shp, m, 2)
                put = lambda a, s: jax.device_put(a, NamedSharding(m, s))
                cache = jax.tree_util.tree_map(
                    put, cache, cache_ps, is_leaf=lambda x: isinstance(x, P))
                pps = model_lib.pspecs(cfg)
                params_sh = jax.tree_util.tree_map(
                    put, params, pps, is_leaf=lambda x: isinstance(x, P))
                for pos in range(6):
                    nxt, cache = step_fn(params_sh, cache,
                                         {"tokens": toks[:, pos:pos + 1]},
                                         jnp.int32(pos))
            ok = bool(jnp.all(nxt_ref == np.asarray(nxt)))
            print(f"{arch} {shp.name}: match={ok}")
            assert ok
    print("SHARDED_DECODE_OK")


if __name__ == "__main__":
    main()
