"""Subprocess check: the tp_local_kv perf variant (skip the K/V all-gather
when kv heads shard evenly over the model axis) is numerically identical to
the baseline gather path, for both the train loss/grads and the prefill
cache+logits."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.configs.base import LayerSlot, ModelConfig, InputShape
from repro.core.dist import MeshCtx
from repro.models import model as model_lib


def cfg_with(local_kv: bool) -> ModelConfig:
    # heads and kv heads both divisible by model shards (4)
    return ModelConfig(
        name="tpkv-test", arch_type="dense", num_layers=2, d_model=128,
        num_heads=8, num_kv_heads=8, d_ff=256, vocab_size=512,
        qk_norm=True, slots=(LayerSlot("attn", "dense"),),
        tp_local_kv=local_kv)


def run(local_kv: bool):
    cfg = cfg_with(local_kv)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = MeshCtx(data_axes=("data",), model_axis="model",
                  seq_axes=("model",))
    key = jax.random.key(0)
    params = model_lib.init(key, cfg, model_shards=4)
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    def local(params, batch):
        loss, _ = model_lib.loss_fn(params, batch, cfg, ctx, q_chunk=16,
                                    remat=False)
        grads = jax.grad(
            lambda p: model_lib.loss_fn(p, batch, cfg, ctx, q_chunk=16,
                                        remat=False)[0])(params)
        logits, cache = model_lib.prefill_step(params, batch, cfg, ctx,
                                               q_chunk=16)
        # decode 2 tokens from the prefilled cache — validates the cache
        # contents end-to-end without exposing its sharded layout
        s = batch["tokens"].shape[1]
        tok = jnp.argmax(logits[..., : cfg.vocab_size], axis=-1).astype(jnp.int32)
        dec = []
        for i in range(2):
            tok, dlogits, cache = model_lib.decode_step(
                params, cache, tok, jnp.int32(s + i), cfg, ctx)
            dec.append(dlogits)
        return loss, grads, logits, jnp.concatenate(dec, axis=1)

    pps = model_lib.pspecs(cfg)
    fn = jax.jit(jax.shard_map(
        local, mesh=mesh,
        in_specs=(pps, {"tokens": P("data", None), "labels": P("data", None)}),
        out_specs=(P(), pps, P("data", None, None), P("data", None, None)),
        check_vma=False))
    with jax.set_mesh(mesh):
        loss, grads, logits, dec = fn(params, batch)
    return (np.asarray(loss),
            [np.asarray(g) for g in jax.tree_util.tree_leaves(grads)],
            np.asarray(logits), np.asarray(dec))


def main():
    loss_a, grads_a, logits_a, dec_a = run(False)
    loss_b, grads_b, logits_b, dec_b = run(True)
    np.testing.assert_allclose(loss_a, loss_b, rtol=2e-6)
    np.testing.assert_allclose(logits_a, logits_b, atol=2e-4)
    np.testing.assert_allclose(dec_a, dec_b, atol=2e-4)
    worst = max(float(np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-12))
                for a, b in zip(grads_a, grads_b))
    assert worst < 5e-5, f"grad mismatch: {worst}"
    print(f"loss {loss_a} == {loss_b}; worst grad rel diff {worst:.2e}")
    print("TP_LOCAL_KV_OK")


if __name__ == "__main__":
    main()
