"""Subprocess check: PowerSGD linearity (paper Appendix A.3 / Lemma 3).

Running the distributed EF-PowerSGD train step on W data-parallel workers
must equal running it on 1 worker with the full batch — exactly (up to f32
reassociation).  Exits non-zero on failure.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.launch.train import TrainHyper, make_train_step
from repro.configs.base import get_config
from repro.data.synthetic import MarkovLM


def run(mesh_shape, steps=3):
    cfg = get_config("llama3-8b", reduced=True)
    hyper = TrainHyper(q_chunk=32, warmup_steps=5, remat=False)
    key = jax.random.key(0)
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    step_fn, _, init_state = make_train_step(cfg, mesh, hyper)
    data = MarkovLM(vocab=cfg.vocab_size, seed=0)
    it = data.batches(8, 64)
    with jax.set_mesh(mesh):
        params, ef = init_state(key)
        for _ in range(steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, ef, _ = step_fn(params, ef, batch, key)
    return jax.tree_util.tree_map(lambda x: np.asarray(x), params)


def main():
    # same model-parallel degree (2), data parallelism 4 vs 1:
    # the compression blocking is identical, so Lemma 3 applies exactly
    p_multi = run((4, 2))
    p_single = run((1, 2))
    worst = 0.0
    for a, b in zip(jax.tree_util.tree_leaves(p_multi),
                    jax.tree_util.tree_leaves(p_single)):
        rel = float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-12))
        worst = max(worst, rel)
    print(f"worst relative diff over params: {worst:.3e}")
    assert worst < 5e-5, f"linearity violated: {worst}"
    print("LINEARITY_OK")


if __name__ == "__main__":
    main()
