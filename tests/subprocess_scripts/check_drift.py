"""Subprocess drift suite: replica determinism on a real 4×2 fake-device mesh.

History: docs/checkpoint.md (PR 5) measured "replicated" state drifting apart
on an uninterrupted ``make_train_step`` run (params ~1e-2, Q factors ~5e-1 by
step ~9 on reduced llama3-8b) and attributed it to rank-dependent ULP-level
all-reduce.  That diagnosis was wrong.  Grouping same-global-index shards by
*mesh coordinate* shows the divergence was across the MODEL axis, not the
data axis: per-rank backward passes produced partial (and ×W-inflated)
gradients at every replicated→sharded tensor-parallel boundary, because the
self-transposing ``lax.psum`` is the wrong adjoint under this codebase's
replicated-loss convention.  The fix is the Megatron f/g operator pair
(``MeshCtx.psum_model`` reduce-fwd/identity-bwd + ``common.grad_synced``
identity-fwd/psum-bwd), default-on via ``TrainHyper.tp_grad_sync``.

This script pins the whole story, one phase per invocation (``argv[1]``):

``legacy``
    With ``tp_grad_sync=False`` (the historical gradients) the documented
    divergence reproduces — params and Q factors drift apart across model
    ranks within 10 steps — while the *cross-data* drift is exactly 0.0
    even under plain all-reduce: the substrate's data-axis all-reduce was
    never the culprit on this platform.

``broadcast``
    With the fix (default) under ``sync_mode="broadcast"``: ≥50
    uninterrupted steps with params and momentum bit-identical across ALL
    mesh ranks (data and model), Q factors bit-identical across data ranks
    (across model ranks each holds its own shard's factors, by design),
    plus a replicated-batch arm where the per-rank EF error buffers must
    also stay bit-identical and the in-metric ``drift_*`` probes read
    exactly 0.0.  ``sync_mode="broadcast"`` makes the cross-data guarantee
    by construction (canonical reduction order + rank-0 broadcast) rather
    than by substrate luck.

``equiv``
    SimMesh W=4 and a ``shard_map`` (4, 1) mesh running the same broadcast-
    mode schedule track each other to a few f32 ULPs.  NOT bit-exact: the
    collectives agree bitwise (canonical reduction order), but XLA lowers
    the *local* matmul backward differently under vmap batching (SimMesh)
    vs per-device execution, which reassociates a handful of f32 sums
    (~1e-7/step, measured).  Within-substrate bit-exactness is asserted on
    both sides; cross-substrate agreement at an ULP-scale envelope.

Exits non-zero on failure; prints a phase sentinel on success.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import collections
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.configs.base import get_config
from repro.core.simmesh import SimMesh
from repro.data.synthetic import MarkovLM
from repro.launch.train import TrainHyper, make_sim_train_step, make_train_step

W, BATCH, SEQ = 4, 8, 128
STEPS_LEGACY = 10      # documented drift is ~1e-2 by step 9 (docs/checkpoint.md)
STEPS_BROADCAST = 50   # acceptance: ≥50 uninterrupted bit-identical steps
STEPS_EF = 12          # replicated-batch arm (EF buffers comparable)
STEPS_EQUIV = 8
EQUIV_ATOL = 2e-6      # measured cross-substrate residual: ≤5.1e-7 @ 8 steps


def make_hyper(sync_mode, track_drift=False, tp_grad_sync=True):
    # the PR-5 repro settings: reduced llama3-8b, rank 2, the CLI defaults
    return TrainHyper(lr=0.05, rank=2, q_chunk=64, warmup_steps=20,
                      remat=False, sync_mode=sync_mode,
                      track_drift=track_drift, tp_grad_sync=tp_grad_sync)


def model_coord(mesh):
    """device id → model-axis coordinate."""
    out = {}
    devs = mesh.devices  # (data, model) array of devices
    for d in range(devs.shape[0]):
        for m in range(devs.shape[1]):
            out[devs[d, m].id] = m
    return out


def shard_drift(tree, mcoord=None):
    """Worst |Δ| between shards holding the same global slice.

    Replicated-over-data leaves (params, momentum, Q) place one shard per
    device; shards with equal ``index`` are logically the same array.  With
    ``mcoord=None`` every same-index pair is compared — bit-identity across
    the WHOLE mesh, model ranks included.  Passing the :func:`model_coord`
    map additionally groups by model coordinate, measuring cross-DATA drift
    only (the right scope for per-model-shard state like the Q factors).
    Leaves actually sharded over an axis have distinct indices along it and
    are compared only within their replica group.
    """
    worst = 0.0
    for _, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        groups = collections.defaultdict(list)
        for s in leaf.addressable_shards:
            key = (str(s.index) if mcoord is None
                   else (str(s.index), mcoord[s.device.id]))
            groups[key].append(np.asarray(s.data))
        for datas in groups.values():
            ref = datas[0].astype(np.float64)
            for d in datas[1:]:
                worst = max(worst, float(
                    np.abs(d.astype(np.float64) - ref).max()))
    return worst


def ef_drift(error_tree):
    """Worst |Δ| across the EF buffers' leading per-rank dim.  Only
    meaningful when every rank saw the same local batch."""
    worst = 0.0
    for leaf in jax.tree_util.tree_leaves(error_tree):
        a = np.asarray(leaf).astype(np.float64)
        worst = max(worst, float(np.abs(a - a[:1]).max()))
    return worst


def run_mesh(sync_mode, steps, mesh_shape=(4, 2), replicate_batch=False,
             track_drift=False, tp_grad_sync=True):
    """Train ``steps`` steps on a fake-device mesh.

    Returns (worst drift per state tree over all measured steps, final
    metrics).  Drift dict keys: params/momentum (whole-mesh bit-identity),
    q_data (cross-data only), q_mesh (whole mesh — nonzero by design for
    model-sharded leaves' factors), error (replicated-batch arm only).
    """
    cfg = get_config("llama3-8b", reduced=True)
    hyper = make_hyper(sync_mode, track_drift, tp_grad_sync)
    key = jax.random.key(0)
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    mcoord = model_coord(mesh)
    step_fn, _, init_state = make_train_step(cfg, mesh, hyper)
    data = MarkovLM(vocab=cfg.vocab_size, seed=0)
    worst = {"params": 0.0, "params_data": 0.0, "momentum": 0.0,
             "q_data": 0.0, "q_mesh": 0.0, "error": 0.0}
    metrics = {}
    with jax.set_mesh(mesh):
        params, ef = init_state(key)
        for i in range(steps):
            if replicate_batch:
                # every data rank gets the same local shard of BATCH // W
                toks = np.tile(data.sample(BATCH // W, SEQ, step=i), (W, 1))
            else:
                toks = data.sample(BATCH, SEQ, step=i)
            batch = {"tokens": jnp.asarray(toks[:, :-1]),
                     "labels": jnp.asarray(toks[:, 1:].copy())}
            params, ef, metrics = step_fn(params, ef, batch,
                                          jax.random.fold_in(key, i))
            if (i + 1) % 5 == 0 or i == steps - 1:
                worst["params"] = max(worst["params"], shard_drift(params))
                worst["params_data"] = max(worst["params_data"],
                                           shard_drift(params, mcoord))
                worst["momentum"] = max(worst["momentum"],
                                        shard_drift(ef.momentum))
                worst["q_data"] = max(worst["q_data"],
                                      shard_drift(ef.comp, mcoord))
                worst["q_mesh"] = max(worst["q_mesh"], shard_drift(ef.comp))
                if replicate_batch:
                    worst["error"] = max(worst["error"], ef_drift(ef.error))
                print(f"  step {i:3d} drift: " + " ".join(
                    f"{k}={v:.3e}" for k, v in worst.items()), flush=True)
    return worst, metrics


def phase_legacy():
    """The documented PR-5 divergence reproduces with ``tp_grad_sync=False``
    and is entirely a cross-MODEL effect — cross-data drift stays 0.0."""
    worst, _ = run_mesh("allreduce", STEPS_LEGACY, tp_grad_sync=False)
    assert worst["params"] > 0.0 and worst["q_mesh"] > 0.0, (
        "the legacy TP gradient bug no longer reproduces with "
        f"tp_grad_sync=False ({worst}) — if the debug switch was removed, "
        "retire this phase and the history section of docs/checkpoint.md "
        "together")
    # the corrected diagnosis: data ranks never disagreed on this substrate;
    # the documented divergence lives entirely on the model axis
    assert worst["params_data"] == 0.0 and worst["q_data"] == 0.0, (
        "legacy cross-DATA drift nonzero — the historical divergence was "
        f"model-axis-only when diagnosed; measured {worst}")
    print(f"legacy (tp_grad_sync=False) drift: {worst}")
    print("LEGACY_DRIFT_OK")


def phase_broadcast():
    """With the TP gradient fix (default) under ``sync_mode="broadcast"``:
    bit-identical replicas through ≥50 uninterrupted steps — params and
    momentum across the WHOLE mesh, Q factors across data ranks, EF buffers
    in the replicated-batch arm, and in-metric probes reading exactly 0.0."""
    worst, _ = run_mesh("broadcast", STEPS_BROADCAST)
    for name in ("params", "momentum", "q_data"):
        assert worst[name] == 0.0, (
            f"{name} replicas diverged under sync_mode='broadcast' "
            f"within {STEPS_BROADCAST} steps: {worst}")
    print(f"broadcast drift over {STEPS_BROADCAST} steps: {worst}")
    print("  (q_mesh > 0 is by design: each model rank holds the factors "
          "of ITS weight shard)")

    worst_ef, metrics = run_mesh("broadcast", STEPS_EF,
                                 replicate_batch=True, track_drift=True)
    for name in ("params", "momentum", "q_data", "error"):
        assert worst_ef[name] == 0.0, (
            f"{name} diverged in the replicated-batch arm: {worst_ef}")
    for name in ("params", "momentum", "q", "error"):
        assert float(metrics[f"drift_{name}"]) == 0.0, (
            f"in-metric drift_{name} nonzero under broadcast: "
            f"{float(metrics[f'drift_{name}']):.3e}")
    print(f"replicated-batch arm ({STEPS_EF} steps, EF included): "
          f"{worst_ef}")
    print("DRIFT_VANISHES_OK")


def phase_equiv():
    """SimMesh W=4 ≡ shard_map (4,1) under broadcast, to a few f32 ULPs."""
    cfg = get_config("llama3-8b", reduced=True)
    hyper = make_hyper("broadcast")
    key = jax.random.key(0)
    data = MarkovLM(vocab=cfg.vocab_size, seed=0)

    def batch_at(i):
        toks = data.sample(BATCH, SEQ, step=i)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:].copy())}

    # shard_map: data-parallel only, so per-rank local compute is comparable
    mesh = jax.make_mesh((W, 1), ("data", "model"))
    step_fn, _, init_state = make_train_step(cfg, mesh, hyper)
    losses_mesh = []
    with jax.set_mesh(mesh):
        p_d, ef_d = init_state(key)
        for i in range(STEPS_EQUIV):
            p_d, ef_d, met = step_fn(p_d, ef_d, batch_at(i),
                                     jax.random.fold_in(key, i))
            losses_mesh.append(float(met["lm_loss"]))
        assert shard_drift(p_d) == 0.0 and shard_drift(ef_d.comp) == 0.0, \
            "shard_map replicas not bit-identical under broadcast"

    sim = SimMesh(W)
    sstep, sinit = make_sim_train_step(cfg, sim, hyper)
    p_s, ef_s = sinit(key)
    losses_sim = []
    for i in range(STEPS_EQUIV):
        p_s, ef_s, met = sstep(p_s, ef_s, sim.shard(batch_at(i)),
                               jax.random.fold_in(key, i))
        losses_sim.append(float(met["lm_loss"][0]))
    sim.assert_replicated(p_s, "sim params")
    sim.assert_replicated(ef_s.comp, "sim Q factors")

    np.testing.assert_allclose(losses_sim, losses_mesh, rtol=0,
                               atol=EQUIV_ATOL)
    pairs = (("params", p_d, sim.unreplicate(p_s)),
             ("momentum", ef_d.momentum, sim.unreplicate(ef_s.momentum)),
             ("q", ef_d.comp, sim.unreplicate(ef_s.comp)),
             # per-rank buffers: mesh (dp, n, m) ↔ sim (W, n, m), same order
             ("error", ef_d.error, ef_s.error))
    for name, a, b in pairs:
        worst = 0.0
        for la, lb in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(b)):
            x = np.asarray(la).astype(np.float64).reshape(-1)
            y = np.asarray(lb).astype(np.float64).reshape(-1)
            worst = max(worst, float(np.abs(x - y).max()))
        print(f"  cross-substrate |Δ| {name}: {worst:.3e}")
        assert worst <= EQUIV_ATOL, (
            f"{name} diverged across substrates beyond the ULP envelope: "
            f"{worst:.3e} > {EQUIV_ATOL}")
    print("SUBSTRATE_EQUIV_OK")


PHASES = {"legacy": phase_legacy, "broadcast": phase_broadcast,
          "equiv": phase_equiv}

if __name__ == "__main__":
    PHASES[sys.argv[1]]()
