"""Subprocess model-parallel checkpoint suite: per-rank state round-trips
on a real 2×2 (data × model) fake-device mesh.

The bug this suite pins (and its fix certifies): Q factors of *row-parallel*
weights (embed ``P("model", None)``, attention out-proj, MLP down-proj) are
declared replicated over the model axis — their shape carries no model dim —
but each model rank's warm-start iteration ``Q = Mᵀ P̂`` is a function of its
LOCAL n-rows, so the "replicated" leaf holds distinct per-rank content
(model-LOCAL in ``repro.core.engine.StatePartition`` terms).  ``np.asarray``
at save time silently serializes device 0's (model rank 0's) replica, and a
plain restore broadcasts that copy to every rank: ranks ≥ 1 resume with the
wrong factors and the warm-start ablation (§3) silently degrades.

One phase per invocation (``argv[1]``):

``regression``
    Pins the pre-fix corruption against the PLAIN save/restore path (no
    mesh canonicalization — exactly what a pre-PR-7 driver did): after
    training long enough for the per-model-rank factors to diverge, a plain
    round-trip hands every rank model-rank-0's copy — bit-equal to rank 0's
    pre-save content, bit-different from rank 1's own.

``resume``
    The fixed path: ``canonicalize_mesh`` → ``save_train_state`` → (kill) →
    ``stack_model_template`` → ``restore_train_state(model_axis_size=...)``
    → ``replicate_mesh`` resumes bit-exactly — EVERY model rank's Q factors
    and EF buffers restore to their own pre-kill bytes, and the per-step
    losses of the continued run reproduce the uninterrupted run's
    bit-for-bit.  Also checks the degree guard: restoring the same envelope
    while claiming a different model degree raises CheckpointError naming
    both sizes.

Exits non-zero on failure; prints a phase sentinel on success.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.checkpoint import (CheckpointError, TrainState, canonicalize_mesh,
                              replicate_mesh, restore_train_state,
                              save_train_state, stack_model_template)
from repro.configs.base import get_config
from repro.core.engine import MODEL_LOCAL
from repro.core.error_feedback import EFState
from repro.data.synthetic import MarkovLM
from repro.launch.train import (TrainHyper, make_train_step,
                                train_state_partition)

BATCH, SEQ = 8, 128
SAVE_AT, STEPS = 3, 6
MESH_SHAPE = (2, 2)  # (data, model)


def build(cfg, mesh, hyper):
    step_fn, _, init_state = make_train_step(cfg, mesh, hyper)
    data = MarkovLM(vocab=cfg.vocab_size, seed=0)

    def batch_at(i):
        toks = data.sample(BATCH, SEQ, step=i)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:].copy())}

    return step_fn, init_state, batch_at


def setup():
    cfg = get_config("llama3-8b", reduced=True)
    # sync_mode="broadcast": replica-deterministic data-axis aggregation, so
    # "bit-exact resume" is a meaningful target on any substrate
    hyper = TrainHyper(lr=0.05, rank=2, q_chunk=64, warmup_steps=20,
                       remat=False, sync_mode="broadcast")
    mesh = jax.make_mesh(MESH_SHAPE, ("data", "model"))
    parts = train_state_partition(cfg, mesh)
    return cfg, hyper, mesh, parts


def per_rank_comp(mesh, params, ef, parts):
    """Host-side stacked per-model-rank content of every model-LOCAL comp
    leaf (reuses the save path's gather), as a flat {path: (S, ...) array}."""
    _, ef_c = canonicalize_mesh(mesh, params, ef, parts)
    out = {}
    flat_p = jax.tree_util.tree_flatten_with_path(
        parts.comp, is_leaf=lambda x: x is None)[0]
    flat_q = jax.tree_util.tree_flatten_with_path(
        ef_c.comp, is_leaf=lambda x: x is None)[0]
    for (pp, part), (qp, q) in zip(flat_p, flat_q):
        assert jax.tree_util.keystr(pp) == jax.tree_util.keystr(qp)
        if part is not None and part.model == MODEL_LOCAL:
            out[jax.tree_util.keystr(pp)] = np.asarray(q)
    return out


def run_to(step_fn, mesh, params, ef, key, batch_at, lo, hi):
    losses = []
    with jax.set_mesh(mesh):
        for i in range(lo, hi):
            params, ef, met = step_fn(params, ef, batch_at(i),
                                      jax.random.fold_in(key, i))
            losses.append(float(met["lm_loss"]))
    return params, ef, losses


def phase_regression():
    """Plain (pre-fix) save/restore hands every model rank model-rank-0's
    warm-start factors — pinned at the bytes level."""
    cfg, hyper, mesh, parts = setup()
    step_fn, init_state, batch_at = build(cfg, mesh, hyper)
    key = jax.random.key(0)
    with jax.set_mesh(mesh):
        params, ef = init_state(key)
    params, ef, _ = run_to(step_fn, mesh, params, ef, key, batch_at,
                           0, SAVE_AT)

    pre = per_rank_comp(mesh, params, ef, parts)
    assert pre, "no model-LOCAL comp leaves on a (2,2) mesh — mspecs changed?"
    diverged = [p for p, q in pre.items()
                if any(not np.array_equal(q[m], q[0])
                       for m in range(1, q.shape[0]))]
    assert diverged, (
        f"model ranks' Q factors are bit-identical after {SAVE_AT} steps — "
        f"the regression scenario is vacuous (warm start off? rank-invariant "
        f"init?): {sorted(pre)}")

    with tempfile.TemporaryDirectory() as d:
        # the pre-fix path: no canonicalize_mesh, no model_axis_size —
        # np.asarray inside the envelope writer picks device 0's replica
        save_train_state(d, TrainState(
            params=params, ef=ef, key=key,
            data_step=jnp.asarray(int(ef.step), jnp.int32)))
        with jax.set_mesh(mesh):
            p2, ef2 = init_state(key)
        state, _ = restore_train_state(d, TrainState(
            params=p2, ef=ef2, key=key,
            data_step=jnp.zeros((), jnp.int32)))

    flat = dict(
        (jax.tree_util.keystr(p), leaf) for p, leaf in
        jax.tree_util.tree_flatten_with_path(
            state.ef.comp, is_leaf=lambda x: x is None)[0])
    for path in diverged:
        got = np.asarray(flat[path])
        q = pre[path]
        assert np.array_equal(got, q[0]), (
            f"{path}: plain restore no longer equals model-rank-0's copy — "
            f"did the envelope writer stop using np.asarray on replicated "
            f"leaves?  Update this phase and docs/checkpoint.md together")
        assert not np.array_equal(got, q[1]), f"{path}: expected corruption"
    print(f"pinned rank-0-copy corruption on {len(diverged)} model-LOCAL "
          f"leaves (of {len(pre)}): plain restore == rank 0's bytes, != "
          f"rank 1's own")
    print("REGRESSION_PINNED_OK")


def phase_resume():
    """Mesh-aware save → kill → restore: bit-exact on every model rank."""
    cfg, hyper, mesh, parts = setup()
    step_fn, init_state, batch_at = build(cfg, mesh, hyper)
    model_size = int(mesh.shape["model"])
    key = jax.random.key(0)

    # uninterrupted reference run, snapshotting at SAVE_AT
    with jax.set_mesh(mesh):
        params, ef = init_state(key)
    params, ef, _ = run_to(step_fn, mesh, params, ef, key, batch_at,
                           0, SAVE_AT)
    pre = per_rank_comp(mesh, params, ef, parts)
    pre_error = np.asarray(jax.tree_util.tree_leaves(ef.error)[0])
    with tempfile.TemporaryDirectory() as d:
        p_c, ef_c = canonicalize_mesh(mesh, params, ef, parts)
        save_train_state(
            d, TrainState(params=p_c, ef=ef_c, key=key,
                          data_step=jnp.asarray(int(ef.step), jnp.int32)),
            model_axis_size=model_size,
            mesh_shape={a: int(mesh.shape[a]) for a in mesh.axis_names})
        params, ef, ref_losses = run_to(step_fn, mesh, params, ef, key,
                                        batch_at, SAVE_AT, STEPS)
        ref_final = per_rank_comp(mesh, params, ef, parts)

        # "kill": fresh state, restore through the mesh-aware path
        with jax.set_mesh(mesh):
            p2, ef2 = init_state(jax.random.key(7))  # different init — all
            #   restored content must come from the envelope, not survive here

        # degree guard first: same envelope, wrong claimed degree
        try:
            restore_train_state(
                d, TrainState(params=p2,
                              ef=stack_model_template(ef2, parts, 4),
                              key=key, data_step=jnp.zeros((), jnp.int32)),
                model_axis_size=4)
        except CheckpointError as e:
            assert "2" in str(e) and "4" in str(e), str(e)
        else:
            raise AssertionError("degree-mismatched restore did not raise")

        state, meta = restore_train_state(
            d, TrainState(params=p2,
                          ef=stack_model_template(ef2, parts, model_size),
                          key=key, data_step=jnp.zeros((), jnp.int32)),
            model_axis_size=model_size)
    assert meta["model_axis_size"] == model_size, meta
    assert meta["ef_rescale"]["path"] == "identity", meta["ef_rescale"]
    with jax.set_mesh(mesh):
        p3, ef3 = replicate_mesh(mesh, state.params, state.ef, parts)

    # every model rank's Q factors are its OWN pre-kill bytes again
    post = per_rank_comp(mesh, p3, ef3, parts)
    for path, q in pre.items():
        assert np.array_equal(post[path], q), (
            f"{path}: restored per-model-rank factors differ from their "
            f"own pre-kill content")
    assert np.array_equal(
        np.asarray(jax.tree_util.tree_leaves(ef3.error)[0]), pre_error), \
        "EF buffers did not round-trip bit-exactly"
    print(f"per-rank round-trip bit-exact on {len(pre)} model-LOCAL leaves")

    # continue: per-step losses must reproduce the reference run's bits
    p3, ef3, res_losses = run_to(step_fn, mesh, p3, ef3, key, batch_at,
                                 SAVE_AT, STEPS)
    assert [l.hex() for l in res_losses] == [l.hex() for l in ref_losses], (
        f"post-resume losses diverged from the uninterrupted run:\n"
        f"  ref    {[l.hex() for l in ref_losses]}\n"
        f"  resume {[l.hex() for l in res_losses]}")
    res_final = per_rank_comp(mesh, p3, ef3, parts)
    for path, q in ref_final.items():
        assert np.array_equal(res_final[path], q), (
            f"{path}: factors diverged from the uninterrupted run "
            f"after resume")
    print(f"losses {SAVE_AT}..{STEPS - 1} bit-equal after resume: "
          f"{[f'{l:.6f}' for l in res_losses]}")
    print("MODEL_RESUME_OK")


PHASES = {"regression": phase_regression, "resume": phase_resume}

if __name__ == "__main__":
    PHASES[sys.argv[1]]()
