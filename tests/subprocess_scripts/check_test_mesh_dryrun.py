"""Subprocess check: the dry-run machinery (lower + compile + roofline)
works end-to-end on the CI-sized test meshes (2×2 and 2×2×2) for one
architecture per family and all four step kinds."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.configs.base import get_config, InputShape
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as roofline_lib
from repro.launch.train import TrainHyper, make_train_step
from repro.launch.serve import make_decode_step, make_prefill_step
from repro.launch import specs as specs_lib


def main():
    for multi_pod in (False, True):
        mesh = mesh_lib.make_test_mesh(multi_pod=multi_pod)
        for arch in ["llama3-8b", "mamba2-1.3b", "qwen3-moe-30b-a3b"]:
            cfg = get_config(arch, reduced=True)
            hyper = TrainHyper(q_chunk=32, remat=True)
            # train
            shape = InputShape("t", 128, 8, "train")
            step_fn, abstract_state, _ = make_train_step(cfg, mesh, hyper)
            params_sds, ef_sds = abstract_state()
            batch = specs_lib.with_sharding(
                specs_lib.batch_specs(cfg, shape),
                specs_lib.batch_pspecs(cfg, shape, mesh_lib.data_axes(mesh)),
                mesh)
            key = jax.eval_shape(lambda: jax.random.key(0))
            compiled = step_fn.lower(params_sds, ef_sds, batch, key).compile()
            roof = roofline_lib.analyse(compiled, chips=8)
            assert roof.flops > 0 and roof.coll_bytes > 0
            # prefill + decode
            pf, pf_abs = make_prefill_step(cfg, mesh,
                                           InputShape("p", 128, 8, "prefill"),
                                           q_chunk=32)
            pf.lower(*pf_abs()).compile()
            dc, dc_abs = make_decode_step(cfg, mesh,
                                          InputShape("d", 128, 8, "decode"))
            dc.lower(*dc_abs()).compile()
            print(f"mesh={'2x2x2' if multi_pod else '2x2'} {arch}: ok "
                  f"(coll={roof.coll_bytes:.2e}B)")
    print("TEST_MESH_DRYRUN_OK")


if __name__ == "__main__":
    main()
