"""Multi-device semantics, via subprocesses with 8 fake host devices
(XLA locks the device count at first init, so these cannot run in-process)."""

import os
import subprocess
import sys

import pytest

SCRIPTS = os.path.join(os.path.dirname(__file__), "subprocess_scripts")


def _run(script, timeout=900):
    proc = subprocess.run(
        [sys.executable, os.path.join(SCRIPTS, script)],
        capture_output=True, text=True, timeout=timeout,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{script} failed\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    return proc.stdout


def test_linearity_multiworker_equals_single():
    """Paper Lemma 3: W-worker EF-PowerSGD ≡ 1 worker with the full batch."""
    out = _run("check_linearity.py")
    assert "LINEARITY_OK" in out


def test_sharded_decode_matches_single_device():
    out = _run("check_sharded_decode.py")
    assert "SHARDED_DECODE_OK" in out


def test_dryrun_on_test_meshes():
    out = _run("check_test_mesh_dryrun.py")
    assert "TEST_MESH_DRYRUN_OK" in out


def test_tp_local_kv_matches_gather_path():
    """The tp_local_kv perf variant (§Perf) is numerically identical to the
    baseline K/V all-gather path: loss, grads, prefill logits, decode."""
    out = _run("check_tp_local_kv.py")
    assert "TP_LOCAL_KV_OK" in out
