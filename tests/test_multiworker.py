"""Slow tier: real multi-device semantics via subprocesses with 8 fake host
devices (XLA locks the device count at first init, so these cannot run
in-process).  Everything here carries ``@pytest.mark.slow`` and is excluded
from the default (fast) run — select with ``pytest -m slow``.

The fast in-process equivalents live in ``tests/sim/`` (SimMesh substrate):
``check_linearity.py`` is retained below as the one subprocess smoke test
pinning Lemma 3 on a *real* shard_map mesh; its W-sweep now runs in-process
(``tests/sim/test_linearity.py``), as does the train-step portion of the
mesh dry-run (``tests/sim/test_dryrun.py``)."""

import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.slow, pytest.mark.timeout(1200)]

SCRIPTS = os.path.join(os.path.dirname(__file__), "subprocess_scripts")


def _run(script, timeout=900):
    proc = subprocess.run(
        [sys.executable, os.path.join(SCRIPTS, script)],
        capture_output=True, text=True, timeout=timeout,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{script} failed\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    return proc.stdout


def test_linearity_multiworker_equals_single():
    """Paper Lemma 3: W-worker EF-PowerSGD ≡ 1 worker with the full batch —
    the retained subprocess smoke test backing tests/sim/test_linearity.py
    with a real (4, 2) shard_map mesh."""
    out = _run("check_linearity.py")
    assert "LINEARITY_OK" in out


def test_sharded_decode_matches_single_device():
    out = _run("check_sharded_decode.py")
    assert "SHARDED_DECODE_OK" in out


def test_dryrun_on_test_meshes():
    """Full lower+compile+roofline on the 2×2 / 2×2×2 meshes (train, prefill
    and decode) — the parts of the dry-run SimMesh cannot simulate."""
    out = _run("check_test_mesh_dryrun.py")
    assert "TEST_MESH_DRYRUN_OK" in out


def test_tp_local_kv_matches_gather_path():
    """The tp_local_kv perf variant (§Perf) is numerically identical to the
    baseline K/V all-gather path: loss, grads, prefill logits, decode."""
    out = _run("check_tp_local_kv.py")
    assert "TP_LOCAL_KV_OK" in out
