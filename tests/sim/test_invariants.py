"""W-worker invariants of the compression engine itself, replayed on the
SimMesh substrate: the 2-collectives-per-step communication model, warm-start
subspace tracking (§4.2 / Theorem I) under worker noise, the ``error_mode``
semantics, and sim-vs-single-device exactness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import error_feedback as ef_lib
from repro.core import matrixize, powersgd
from repro.core.compressors import PowerSGDCompressor
from repro.core.dist import CollectiveStats, SINGLE
from repro.core.powersgd import PowerSGDConfig
from repro.core.simmesh import SimMesh
from repro.launch.train import TrainHyper, make_sim_train_step

from _helpers import KEY, sim_train

SPECS = {"w": matrixize.MatrixSpec("matrix", 0)}


# ---------------------------------------------------------------------------
# communication model
# ---------------------------------------------------------------------------

def test_two_collectives_per_step():
    """The bucketed engine's invariant survives the W-worker step: exactly 2
    data-axis collectives per optimizer step, however many weight matrices
    (CollectiveStats counts identically under SimBackend)."""
    stats = CollectiveStats()
    sim_train(workers=2, steps=1, stats=stats)
    assert stats.data_collectives == 2, stats.sizes


def test_per_leaf_engine_collective_count():
    """``bucketing="off"`` is the contrast case: 2 collectives per *matrix*
    plus 1 per uncompressed leaf — the latency-bound pattern the bucketed
    engine exists to avoid."""
    from repro.models import model as model_lib

    cfg = get_config("llama3-8b", reduced=True)
    mspecs = model_lib.mspecs(cfg)
    n_mat = sum(1 for s in jax.tree_util.tree_leaves(
        mspecs, is_leaf=lambda x: isinstance(x, matrixize.MatrixSpec))
        if s.is_compressed())
    n_vec = sum(1 for s in jax.tree_util.tree_leaves(
        mspecs, is_leaf=lambda x: isinstance(x, matrixize.MatrixSpec))
        if not s.is_compressed())
    stats = CollectiveStats()
    sim_train(workers=2, steps=1, stats=stats,
              compressor=PowerSGDCompressor(rank=2, bucketing="off"))
    assert stats.data_collectives == 2 * n_mat + n_vec, (
        stats.data_collectives, n_mat, n_vec)


# ---------------------------------------------------------------------------
# warm-start subspace tracking under per-worker noise (§4.2)
# ---------------------------------------------------------------------------

def _decaying_matrix(key, n=48, m=32, decay=0.7):
    u, _ = jnp.linalg.qr(jax.random.normal(key, (n, n)))
    v, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1),
                                           (m, m)))
    s = decay ** jnp.arange(m)
    return (u[:, :m] * s) @ v.T


def test_warm_start_tracks_subspace_across_workers():
    """Each worker holds M̄ + ζ_w with Σ_w ζ_w = 0: the worker mean is M̄, so
    repeated warm-started rank-r steps must converge to the best rank-r
    approximation of M̄ (power iteration through the *aggregated* factors —
    the W-worker reading of Theorem I)."""
    W, r = 4, 4
    key = jax.random.key(7)
    m_bar = _decaying_matrix(key)
    noise = jax.random.normal(jax.random.fold_in(key, 2),
                              (W - 1,) + m_bar.shape) * 0.1
    noise = jnp.concatenate([noise, -jnp.sum(noise, 0, keepdims=True)])
    deltas_w = {"w": m_bar[None] + noise}           # (W, n, m), mean = M̄

    cfg = PowerSGDConfig(rank=r, warm_start=True)
    sim = SimMesh(W)
    state = sim.replicate(powersgd.init_state(
        cfg, {"w": jax.ShapeDtypeStruct(m_bar.shape, m_bar.dtype)},
        SPECS, KEY))

    def one_step(deltas, state):
        out = powersgd.compress_aggregate(cfg, deltas, state, SPECS,
                                          ctx=sim.ctx())
        return out.agg, out.state

    step = jax.jit(sim.run(one_step))
    errs = []
    for _ in range(25):
        agg, state = step(deltas_w, state)
        errs.append(float(jnp.linalg.norm(m_bar - agg["w"][0])))

    u, s, vt = jnp.linalg.svd(m_bar)
    best = float(jnp.linalg.norm(
        m_bar - (u[:, :r] * s[:r]) @ vt[:r]))
    assert errs[-1] < 1.05 * best + 1e-6, (errs[-1], best)
    assert errs[-1] < 0.8 * errs[0]                 # it actually *tracked*
    sim.assert_replicated(state, "Q factors")


# ---------------------------------------------------------------------------
# error_mode="local" vs "global" (Alg. 2 literal vs reference impl)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("error_mode", ["local", "global"])
def test_error_mode_recon_replication(error_mode):
    """"global" memorizes against the *aggregated* reconstruction (identical
    on every worker); "local" against the worker's own back-projection
    (Alg. 2 line 7 literally) — so recon must replicate across workers in
    global mode and diverge in local mode."""
    W = 4
    key = jax.random.key(3)
    deltas_w = {"w": jax.random.normal(key, (W, 24, 16))}
    cfg = PowerSGDConfig(rank=2, error_mode=error_mode)
    sim = SimMesh(W)
    state = sim.replicate(powersgd.init_state(
        cfg, {"w": jax.ShapeDtypeStruct((24, 16), jnp.float32)},
        SPECS, KEY))

    def one_step(deltas, state):
        out = powersgd.compress_aggregate(cfg, deltas, state, SPECS,
                                          ctx=sim.ctx())
        return out.agg, out.recon, out.state

    agg, recon, _ = jax.jit(sim.run(one_step))(deltas_w, state)
    sim.assert_replicated(agg, "agg")
    r = np.asarray(recon["w"])
    identical = bool((r == r[:1]).all())
    assert identical == (error_mode == "global"), error_mode


# ---------------------------------------------------------------------------
# sim(W=1) ≡ single-device SINGLE context, bit-exactly
# ---------------------------------------------------------------------------

def test_sim_one_worker_matches_single_device_bitexact():
    """A 1-worker SimMesh is the SINGLE context plus a size-1 stacked axis:
    the compressor must produce bit-identical factors and reconstructions."""
    key = jax.random.key(11)
    delta = {"w": jax.random.normal(key, (24, 16))}
    cfg = PowerSGDConfig(rank=2)
    state0 = powersgd.init_state(
        cfg, {"w": jax.ShapeDtypeStruct((24, 16), jnp.float32)}, SPECS, KEY)

    ref = powersgd.compress_aggregate(cfg, delta, state0, SPECS, ctx=SINGLE)

    sim = SimMesh(1)

    def one_step(deltas, state):
        out = powersgd.compress_aggregate(cfg, deltas, state, SPECS,
                                          ctx=sim.ctx())
        return out.agg, out.recon, out.state

    agg, recon, new_state = sim.run(one_step)(
        sim.replicate(delta), sim.replicate(state0))
    np.testing.assert_array_equal(np.asarray(agg["w"][0]),
                                  np.asarray(ref.agg["w"]))
    np.testing.assert_array_equal(np.asarray(recon["w"][0]),
                                  np.asarray(ref.recon["w"]))
    np.testing.assert_array_equal(np.asarray(new_state["w"][0]),
                                  np.asarray(ref.state["w"]))
