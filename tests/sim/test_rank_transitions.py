"""Rank-transition invariants on the SimMesh substrate (ISSUE 4 acceptance):
through a full staircase schedule, (a) the fused engine's 2-collectives-
per-step budget holds at every rank stage, (b) Lemma 3 linearity holds —
W workers equal 1 worker with the full batch, transitions included — for
W ∈ {1, 4}, and (c) a rank switch preserves the error-feedback buffers
exactly (bit-for-bit) and the retained warm-start columns bit-exactly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compressors import PowerSGDCompressor
from repro.core.dist import CollectiveStats
from repro.core.error_feedback import EFState
from repro.core.powersgd import transition_state

from _helpers import sim_train, worst_rel_diff

TOL = 5e-5  # same bound as test_linearity.py: f32 reassociation only

# 6 steps crossing two transitions: ranks 4 (steps 0-1), 2 (2-3), 1 (4-5)
STAIR = "4@0,2@2,1@4"


def _stair_compressor():
    return PowerSGDCompressor(rank_schedule=STAIR)


@pytest.mark.parametrize("workers", [1, 4])
def test_collective_budget_at_every_stage(workers):
    """CollectiveStats records at trace time and the jitted sim step
    retraces exactly once per rank stage (factor shapes change), so a
    3-stage staircase must record exactly 3 × 2 fused data collectives —
    2 per step at EVERY rank, or the O(1)-collectives invariant broke."""
    stats = CollectiveStats()
    comp = _stair_compressor()
    sim_train(workers=workers, steps=6, stats=stats, compressor=comp,
              controller=comp.controller())
    assert stats.data_collectives == 3 * 2, (stats.data_collectives,
                                             stats.sizes)
    assert stats.gather_collectives == 0
    # payloads shrink with the rank: stage P-phase sizes strictly decrease
    p_sizes = stats.sizes[0::2]
    assert p_sizes[0] > p_sizes[1] > p_sizes[2], stats.sizes


@pytest.fixture(scope="module")
def single_worker_stair():
    comp = _stair_compressor()
    _, params, _, _ = sim_train(workers=1, steps=6, compressor=comp,
                                controller=comp.controller())
    return params


@pytest.mark.parametrize("workers", [4])
def test_linearity_through_transitions(workers, single_worker_stair):
    """Splitting the batch over W workers must not change training even
    across rank switches: transitions are deterministic (path-keyed fresh
    columns, truncation of aggregated factors), so Lemma 3 applies at every
    stage."""
    comp = _stair_compressor()
    _, params, sim, (params_w, ef) = sim_train(
        workers=workers, steps=6, compressor=comp,
        controller=comp.controller())
    worst = worst_rel_diff(params, single_worker_stair)
    assert worst < TOL, f"linearity violated across transitions: {worst:.3e}"
    # workers stay bit-identical through the switches
    sim.assert_replicated(params_w, "params")
    sim.assert_replicated(ef.comp, "Q factors")
    sim.assert_replicated(ef.momentum, "momentum")
    # the schedule actually fired: final factors are rank 1
    ranks = {q.shape[-1] for q in jax.tree_util.tree_leaves(ef.comp)}
    assert ranks == {1}, ranks


@pytest.mark.parametrize("workers", [1, 4])
@pytest.mark.parametrize("new_rank", [2, 8])  # truncate and grow
def test_error_buffers_preserved_exactly_across_switch(workers, new_rank):
    """A rank switch must be invisible to everything but the factors: run
    real steps to build non-zero error buffers, transition, and require the
    error / momentum / step leaves bit-identical and the retained factor
    columns bit-exact."""
    comp = PowerSGDCompressor(rank=4)
    _, _, sim, (params, ef) = sim_train(workers=workers, steps=3,
                                        compressor=comp)
    err_leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(ef.error)]
    assert max(np.abs(e).max() for e in err_leaves) > 0  # EF is live

    comp_w0 = jax.tree_util.tree_map(lambda x: x[0], ef.comp)
    new_comp = sim.replicate(transition_state(comp_w0, new_rank,
                                              jax.random.key(5)))
    ef2 = EFState(error=ef.error, momentum=ef.momentum, comp=new_comp,
                  step=ef.step)

    for a, b in zip(jax.tree_util.tree_leaves(ef.error),
                    jax.tree_util.tree_leaves(ef2.error)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(ef.momentum),
                    jax.tree_util.tree_leaves(ef2.momentum)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(ef.step), np.asarray(ef2.step))
    keep = min(4, new_rank)
    for a, b in zip(jax.tree_util.tree_leaves(ef.comp),
                    jax.tree_util.tree_leaves(ef2.comp)):
        assert b.shape[-1] == new_rank
        np.testing.assert_array_equal(np.asarray(a)[..., :keep],
                                      np.asarray(b)[..., :keep])

    # and training continues healthily from the transitioned state
    sim.assert_replicated(ef2.comp, "transitioned Q")


@pytest.mark.parametrize("workers", [1, 4])
def test_residual_schedule_runs_end_to_end(workers):
    """The residual-driven policy survives the full sim train step: the
    residual metric flows worker-aggregated through the step metrics and
    the controller consumes it without breaking replication."""
    comp = PowerSGDCompressor(
        rank_schedule="residual:min=1,max=8,init=2,every=2,shrink=0.05,grow=0.5")
    ctl = comp.controller()
    losses, _, sim, (params, ef) = sim_train(
        workers=workers, steps=5, compressor=comp, controller=ctl)
    assert np.isfinite(losses).all()
    sim.assert_replicated(params, "params")
    sim.assert_replicated(ef.comp, "Q factors")
    # early-training residuals on this task are high: the policy grew
    assert ctl.rank >= 2
