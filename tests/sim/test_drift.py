"""Slow tier: the cross-substrate drift-tracking suite (ISSUE 6 tentpole)
plus the model-parallel checkpoint round-trip suite (ISSUE 7 tentpole).

Promotes the ``docs/checkpoint.md`` substrate-caveat repro into committed
regression tests on a real fake-device mesh (subprocess, 8 host devices —
XLA locks the device count at first init, so these cannot run in-process):

* the historical divergence *reproduces* under ``tp_grad_sync=False`` and
  is a cross-MODEL effect (per-rank partial/×W-inflated TP gradients), not
  data-axis all-reduce nondeterminism — cross-data drift is 0.0 even under
  plain all-reduce on this substrate;
* with the Megatron f/g gradient fix (default) and ``sync_mode=
  "broadcast"``, ≥50 uninterrupted steps keep params and momentum
  bit-identical across the whole mesh and Q factors bit-identical across
  data ranks (across model ranks each holds its own shard's factors);
* SimMesh and ``shard_map`` track each other under broadcast mode to a few
  f32 ULPs (collectives bit-identical; local vmap-vs-per-device compute
  reassociates a handful of sums — see check_drift.py for the measured
  envelope);
* checkpointing that per-model-rank Q state is a separate failure mode
  (check_model_ckpt.py): a plain ``np.asarray`` save keeps model rank 0's
  replica of every model-LOCAL leaf and a restore broadcasts it — the
  pre-fix corruption is pinned as a regression, and the mesh-aware
  ``canonicalize_mesh``/``replicate_mesh`` path is certified bit-exact on
  EVERY model rank across a save→kill→resume cycle.
"""

import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.slow, pytest.mark.timeout(1200)]

SCRIPT = os.path.join(os.path.dirname(__file__), "..", "subprocess_scripts",
                      "check_drift.py")
CKPT_SCRIPT = os.path.join(os.path.dirname(__file__), "..",
                           "subprocess_scripts", "check_model_ckpt.py")


def _run(phase, timeout=1100, script=None):
    proc = subprocess.run(
        [sys.executable, script or SCRIPT, phase],
        capture_output=True, text=True, timeout=timeout,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{os.path.basename(script or SCRIPT)} {phase} failed\n"
            f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    return proc.stdout


def test_legacy_divergence_is_cross_model():
    """Guards the corrected diagnosis of the docs/checkpoint.md caveat: with
    ``tp_grad_sync=False`` the documented drift reproduces across MODEL
    ranks while data ranks stay bit-identical."""
    out = _run("legacy")
    assert "LEGACY_DRIFT_OK" in out


def test_replicas_bit_identical_under_broadcast():
    """The acceptance bar: ≥50 uninterrupted steps under
    sync_mode="broadcast" with bit-identical replicas (params, momentum,
    EF buffers, Q factors), in-metric drift probes reading exactly 0.0."""
    out = _run("broadcast")
    assert "DRIFT_VANISHES_OK" in out


def test_simmesh_matches_shard_map_under_broadcast():
    """Cross-substrate equivalence: SimMesh W=4 ≡ shard_map (4,1) to a few
    f32 ULPs, with within-substrate bit-exactness on both sides."""
    out = _run("equiv")
    assert "SUBSTRATE_EQUIV_OK" in out


def test_plain_checkpoint_keeps_rank0_copy_of_model_local_state():
    """The ISSUE 7 regression pin: against the pre-fix plain save/restore
    path, every model rank's restored warm-start factors are bit-equal to
    model rank 0's pre-save copy and bit-different from their own."""
    out = _run("regression", script=CKPT_SCRIPT)
    assert "REGRESSION_PINNED_OK" in out


def test_model_parallel_resume_bit_exact_on_every_rank():
    """The fixed path on a 2×2 (data × model) mesh: canonicalize_mesh →
    save → kill → stacked-template restore → replicate_mesh resumes with
    every model rank's own Q/EF bytes, bit-equal per-step losses, and a
    degree-mismatch guard that raises CheckpointError naming both sizes."""
    out = _run("resume", script=CKPT_SCRIPT)
    assert "MODEL_RESUME_OK" in out
