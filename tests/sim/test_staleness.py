"""ISSUE 8: the one-step-stale delayed-parameter-update pipeline
(``TrainHyper.staleness="one_step"``) on the SimMesh substrate.

Four contracts:
  * regression guard — ``staleness="none"`` (with and without the
    double-buffered ``PipelinedTransport`` engine) is bit-identical to the
    pre-pipeline synchronous path, per-step losses compared as hex;
  * the pipeline bubble — step 0 applies the zero aggregate, so the first
    recorded loss is bit-equal across modes;
  * Lemma-3 linearity survives the delay — W stale workers equal one stale
    worker with the full batch (the delay commutes with the worker mean);
  * convergence under staleness — clean, dropout and straggler runs keep
    converging (Alg. 2's EF absorbs the one-step shift as one more bounded
    perturbation), with the stale-vs-sync final-loss gap pinned.

The collective-budget arm asserts the stale schedule's trace is *identical*
(kinds/sizes/itemsizes) to the synchronous one — the guard cannot silently
pass because overlap reordered or split the fused collectives.
"""

import jax
import numpy as np
import pytest

from repro.core.compressors import PowerSGDCompressor
from repro.core.dist import CollectiveStats
from repro.data.synthetic import MarkovLM
from repro.launch.train import TrainHyper

from _helpers import sim_train, worst_rel_diff

LINEARITY_TOL = 5e-5
# stale-vs-sync final-loss (mean of last 5) pinned tolerance at the shared
# stable operating point below — overlap_profile measures 0.28–0.48 across
# clean/dropout/straggler arms
STALE_GAP_TOL = 0.75


def _hyper(staleness, lr=0.05, momentum=0.0):
    """Shared operating point where both arms are stable: one-step delay
    halves the heavy-ball stability region (x ← x − γ(Δ'+m) carries a
    ~(2−λ)/(1−λ)·γ steady-state step, oscillatory under delay at λ=0.9),
    so the staleness suite trains momentum-free at moderate lr."""
    return TrainHyper(lr=lr, momentum=momentum, q_chunk=32, warmup_steps=5,
                      remat=False, weight_decay=0.0, staleness=staleness)


def _stream():
    return MarkovLM(vocab=1024, seed=0, order=1, clusters=8)


def test_staleness_none_bit_identical_to_default_path():
    """Regression guard: threading the staleness knob must not perturb the
    synchronous path — explicit ``staleness="none"`` reproduces the default
    run bit-for-bit (loss hex), even on the double-buffered
    ``PipelinedTransport`` engine (``pipeline=True``), whose chunk schedule
    is reordered but value- and trace-identical."""
    base, params_base, _, _ = sim_train(workers=2, steps=6)
    expl, params_expl, _, _ = sim_train(
        workers=2, steps=6,
        hyper=TrainHyper(q_chunk=32, warmup_steps=5, remat=False,
                         weight_decay=0.0, staleness="none"))
    pipe, params_pipe, _, _ = sim_train(
        workers=2, steps=6,
        hyper=TrainHyper(q_chunk=32, warmup_steps=5, remat=False,
                         weight_decay=0.0, staleness="none"),
        compressor=PowerSGDCompressor(rank=2, pipeline=True))
    assert [float(x).hex() for x in base] == [float(x).hex() for x in expl]
    assert [float(x).hex() for x in base] == [float(x).hex() for x in pipe]
    for a, b in ((params_base, params_expl), (params_base, params_pipe)):
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_one_step_pipeline_bubble_and_trace_identity():
    """Step 0 of the stale pipeline applies the zero in-flight aggregate, so
    the first loss is bit-equal to the synchronous run's; the fused-
    collective trace (recorded at trace time) is identical in kind, size
    and wire itemsize — same 2-reduce budget, overlappable schedule."""
    s_sync, s_stale = CollectiveStats(), CollectiveStats()
    sync, _, _, _ = sim_train(workers=4, steps=2, hyper=_hyper("none"),
                              stats=s_sync, data=_stream())
    stale, _, _, _ = sim_train(workers=4, steps=2, hyper=_hyper("one_step"),
                               stats=s_stale, data=_stream())
    assert float(sync[0]).hex() == float(stale[0]).hex()
    assert s_stale.reduce_collectives == 2, s_stale.kinds
    assert (s_sync.kinds, s_sync.sizes, s_sync.itemsizes) == \
           (s_stale.kinds, s_stale.sizes, s_stale.itemsizes)


@pytest.mark.parametrize("workers", [4])
def test_one_step_linearity(workers):
    """Lemma 3 under delay: the stale update Δ'_{t−1} is itself a function of
    all-reduced quantities, so splitting the batch over W workers changes
    nothing — W stale workers equal one stale worker with the full batch."""
    _, single, _, _ = sim_train(workers=1, steps=3, hyper=_hyper("one_step"))
    _, multi, sim, (params, ef) = sim_train(workers=workers, steps=3,
                                            hyper=_hyper("one_step"))
    sim.assert_replicated(params, "params")
    sim.assert_replicated(ef.inflight, "in-flight aggregate")
    worst = worst_rel_diff(multi, single)
    assert worst < LINEARITY_TOL, f"stale linearity violated: {worst:.3e}"


def test_one_step_converges_with_pinned_gap():
    """The 30-step smoke CI runs: stale training converges and lands within
    STALE_GAP_TOL of the synchronous arm's final loss."""
    steps = 30
    sync, _, _, _ = sim_train(workers=4, steps=steps, batch=8, seq=64,
                              hyper=_hyper("none"), data=_stream())
    stale, _, sim, (params, ef) = sim_train(
        workers=4, steps=steps, batch=8, seq=64, hyper=_hyper("one_step"),
        data=_stream())
    assert np.mean(stale[-5:]) < np.mean(stale[:5]) - 0.5, stale
    gap = float(np.mean(stale[-5:]) - np.mean(sync[-5:]))
    assert abs(gap) < STALE_GAP_TOL, (gap, stale[-5:], sync[-5:])
    sim.assert_replicated(params, "params")
    # the pipeline actually ran: a non-zero aggregate is parked in flight
    assert any(float(np.max(np.abs(np.asarray(x)))) > 0
               for x in jax.tree_util.tree_leaves(ef.inflight))


def test_one_step_dropout_converges():
    """Rotating 1-of-4 worker dropout under staleness: the EF memories keep
    absorbing both the compression error and the delay."""
    W = 4

    def drop_rotating(step):
        w = np.ones((W,), np.float32)
        w[step % W] = 0.0
        return w

    losses, _, sim, (params, _) = sim_train(
        workers=W, steps=40, batch=8, seq=64, hyper=_hyper("one_step"),
        weights_for_step=drop_rotating, data=_stream())
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses
    sim.assert_replicated(params, "params")


def test_one_step_straggler_converges():
    """A persistent every-other-round straggler under staleness."""
    W = 4

    def straggler(step):
        w = np.ones((W,), np.float32)
        if step % 2 == 1:
            w[3] = 0.0
        return w

    losses, _, sim, (params, _) = sim_train(
        workers=W, steps=40, batch=8, seq=64, hyper=_hyper("one_step"),
        weights_for_step=straggler, data=_stream())
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses
    sim.assert_replicated(params, "params")
