"""Fault-tolerant resume on SimMesh (ISSUE 5 tentpole coverage).

The headline guarantee: save → kill → resume reproduces the uninterrupted
run's per-step losses *bit-for-bit*, because the checkpoint carries the
whole algorithm state — EF error buffers, momentum, warm-start Q factors,
step counter, rank-controller position, base PRNG key and data cursor.
"Kill" is simulated by rebuilding everything from scratch (fresh compressor,
fresh jitted step, fresh controller) and restoring only from the envelope
bytes, exactly what a new process does.

Also pinned here: the *elastic* resume contract — restoring into a
different worker count rescales the error buffers worker-mean-preservingly
(W=1→4 duplicates, W=4→2 pairwise-averages; see ``rescale_error_buffers``),
so the continuation tracks the uninterrupted run within the Lemma-3
linearity tolerance rather than bit-exactly; the rescaled continuation runs
under ``sync_mode="broadcast"`` so its workers are bit-identical by
construction — and corrupted/truncated envelope rejection end-to-end."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import worst_rel_diff
from repro.checkpoint import (CheckpointError, TrainState, canonicalize_sim,
                              replicate_sim, restore_train_state,
                              save_train_state)
from repro.configs.base import get_config
from repro.core.compressors import PowerSGDCompressor
from repro.core.error_feedback import EFState
from repro.core.simmesh import SimMesh
from repro.data.synthetic import MarkovLM
from repro.launch.train import TrainHyper, make_sim_train_step

KEY = jax.random.key(0)
BATCH, SEQ = 8, 32
STEPS, CKPT_AT = 8, 4
LINEARITY_TOL = 5e-5  # f32 reassociation across the worker-mean


def build(workers, schedule=None, sync_mode="allreduce", staleness="none",
          wire_dtype="auto"):
    """A fresh "process": new compressor, new jitted step, new controller."""
    cfg = get_config("llama3-8b", reduced=True)
    hyper = TrainHyper(q_chunk=32, warmup_steps=5, remat=False,
                       weight_decay=0.0, rank_schedule=schedule,
                       wire_dtype=wire_dtype,
                       sync_mode=sync_mode, staleness=staleness)
    compressor = PowerSGDCompressor(rank=2, rank_schedule=schedule,
                                    wire_dtype=wire_dtype,
                                    pipeline=staleness == "one_step")
    sim = SimMesh(workers)
    step_fn, init_state = make_sim_train_step(cfg, sim, hyper,
                                              compressor=compressor)
    controller = compressor.controller() if schedule else None
    return cfg, sim, step_fn, init_state, controller


def run(cfg, sim, step_fn, params, ef, controller, start, steps,
        residual=None):
    """Drive steps [start, steps) — data batches keyed by absolute step."""
    data = MarkovLM(vocab=cfg.vocab_size, seed=0)
    losses = []
    for i in range(start, steps):
        if controller is not None:
            comp_w0 = jax.tree_util.tree_map(lambda x: x[0], ef.comp)
            new_comp, changed = controller.update(comp_w0, i, residual)
            if changed:
                ef = EFState(error=ef.error, momentum=ef.momentum,
                             comp=sim.replicate(new_comp), step=ef.step,
                             inflight=ef.inflight)
        toks = data.sample(BATCH, SEQ, step=i)
        b = sim.shard({"tokens": jnp.asarray(toks[:, :-1]),
                       "labels": jnp.asarray(toks[:, 1:].copy())})
        params, ef, met = step_fn(params, ef, b, KEY)
        losses.append(float(met["lm_loss"][0]))
    return params, ef, losses


def save_at(tmpdir, sim, params, ef, controller=None, schedule=None,
            residual=None, wire_dtype="auto"):
    p, e = canonicalize_sim(sim, params, ef)
    return save_train_state(
        str(tmpdir), TrainState(params=p, ef=e, key=KEY,
                                data_step=jnp.asarray(e.step)),
        controller=controller,
        extra_meta={"rank_schedule": schedule, "last_residual": residual,
                    "wire_dtype": wire_dtype})


def restore_into(tmpdir, workers, schedule=None, sync_mode="allreduce",
                 staleness="none", wire_dtype="auto"):
    """The resumed process: rebuild from config, restore, re-replicate."""
    cfg, sim, step_fn, init_state, controller = build(workers, schedule,
                                                      sync_mode, staleness,
                                                      wire_dtype)
    p0, e0 = init_state(KEY)
    template = TrainState(*canonicalize_sim(sim, p0, e0), key=KEY,
                          data_step=jnp.zeros((), jnp.int32))
    state, meta = restore_train_state(str(tmpdir), template)
    if controller is not None and meta.get("controller"):
        controller.load_state_dict(meta["controller"])
    params, ef = replicate_sim(sim, state.params, state.ef)
    return cfg, sim, step_fn, controller, params, ef, meta


@pytest.fixture(scope="module", params=[1, 4], ids=["W1", "W4"])
def fixed_rank_runs(request, tmp_path_factory):
    """Per worker count: the uninterrupted reference run and a checkpoint
    taken at CKPT_AT by an independent 'process'."""
    w = request.param
    ckdir = tmp_path_factory.mktemp(f"ck_fixed_w{w}")

    cfg, sim, step_fn, init_state, _ = build(w)
    params, ef = init_state(KEY)
    params, ef, losses = run(cfg, sim, step_fn, params, ef, None, 0, STEPS)
    reference = (losses,
                 jax.tree_util.tree_map(lambda x: np.asarray(x[0]), params))

    cfg, sim, step_fn, init_state, _ = build(w)  # fresh process
    params, ef = init_state(KEY)
    params, ef, head = run(cfg, sim, step_fn, params, ef, None, 0, CKPT_AT)
    save_at(ckdir, sim, params, ef)
    assert head == reference[0][:CKPT_AT], \
        "pre-checkpoint prefix must already be deterministic"
    return w, ckdir, reference


def test_resume_bit_exact_fixed_rank(fixed_rank_runs):
    """save → kill → resume: per-step losses and final params bit-for-bit
    equal to the uninterrupted run, at W=1 and W=4."""
    w, ckdir, (ref_losses, ref_params) = fixed_rank_runs
    cfg, sim, step_fn, _, params, ef, meta = restore_into(ckdir, w)
    assert meta["workers"] == w and int(ef.step[0]) == CKPT_AT
    # same-W restore: the recorded rescale provenance is the identity path
    assert meta["ef_rescale"] == {"from": w, "to": w, "path": "identity"}
    params, ef, tail = run(cfg, sim, step_fn, params, ef, None,
                           CKPT_AT, STEPS)
    assert tail == ref_losses[CKPT_AT:], (
        "resumed losses diverge from the uninterrupted run", tail,
        ref_losses[CKPT_AT:])
    got = jax.tree_util.tree_map(lambda x: np.asarray(x[0]), params)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(ref_params)):
        np.testing.assert_array_equal(a, b)


def test_resume_bit_exact_mid_staircase(tmp_path):
    """Checkpoint taken *between* staircase milestones (rank already moved
    1→2, the 2→4 transition still ahead): the resumed run must replay the
    remaining transition — including the fresh N(0,1) growth columns drawn
    from the controller's restored PRNG key — bit-exactly."""
    schedule = "1@0,2@3,4@6"
    steps = 9

    cfg, sim, step_fn, init_state, ctrl = build(4, schedule)
    params, ef = init_state(KEY)
    params, ef, ref_losses = run(cfg, sim, step_fn, params, ef, ctrl,
                                 0, steps)
    ref_params = jax.tree_util.tree_map(lambda x: np.asarray(x[0]), params)
    ref_history = list(ctrl.history)

    cfg, sim, step_fn, init_state, ctrl = build(4, schedule)
    params, ef = init_state(KEY)
    params, ef, _ = run(cfg, sim, step_fn, params, ef, ctrl, 0, CKPT_AT)
    assert ctrl.rank == 2  # mid-staircase: after 2@3, before 4@6
    save_at(tmp_path, sim, params, ef, controller=ctrl, schedule=schedule)

    cfg, sim, step_fn, ctrl2, params, ef, meta = restore_into(
        tmp_path, 4, schedule)
    assert meta["rank_schedule"] == schedule
    assert ctrl2.rank == 2  # restored, not re-initialized (would be 1)
    # restored factors sit at the checkpointed rank, not the config rank
    ranks = {q.shape[-1] for q in jax.tree_util.tree_leaves(ef.comp)}
    assert ranks == {2}, ranks
    params, ef, tail = run(cfg, sim, step_fn, params, ef, ctrl2,
                           CKPT_AT, steps)
    assert tail == ref_losses[CKPT_AT:]
    assert ctrl2.history == ref_history
    got = jax.tree_util.tree_map(lambda x: np.asarray(x[0]), params)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(ref_params)):
        np.testing.assert_array_equal(a, b)


def test_elastic_resume_1_to_4(fixed_rank_runs, tmp_path):
    """Elastic worker-count rescale, both fixture arms (ISSUE 6 re-enabled
    the long-skipped W4 arm under ``sync_mode="broadcast"``):

    * W1 arm — grow: restore the W=1 checkpoint into W=4 workers; error
      buffers duplicate bit-exactly (worker-mean preserved).
    * W4 arm — shrink: restore the W=4 checkpoint into W=2 workers; each
      new buffer is bit-exactly the mean of the two it absorbs.

    Either way the continuation runs under ``sync_mode="broadcast"`` (the
    canonical deterministic aggregation order, so the replicated-worker
    invariant is guaranteed rather than substrate luck), stays bit-identical
    across workers, and tracks the uninterrupted source-W run within the
    Lemma-3 linearity tolerance.  ISSUE 7 adds the ``meta["ef_rescale"]``
    provenance record: which rescale path actually ran is asserted here, not
    inferred from worker counts after the fact."""
    w, ckdir, (ref_losses, ref_params) = fixed_rank_runs
    w_new = 4 if w == 1 else 2

    cfg, sim, step_fn, _, params, ef, meta = restore_into(
        ckdir, w_new, sync_mode="broadcast")
    assert meta["workers"] == w
    assert meta["ef_rescale"] == {
        "from": w, "to": w_new, "path": "grow" if w == 1 else "shrink"}
    src, _ = restore_train_state(
        str(ckdir),
        TrainState(*canonicalize_sim(SimMesh(w), *_fresh_state(w)), key=KEY,
                   data_step=jnp.zeros((), jnp.int32)))
    if w == 1:
        # grow semantics: every worker starts from the W=1 buffer, bit-exact
        for e4, e1 in zip(jax.tree_util.tree_leaves(ef.error),
                          jax.tree_util.tree_leaves(src.ef.error)):
            for wk in range(w_new):
                np.testing.assert_array_equal(np.asarray(e4[wk]),
                                              np.asarray(e1[0]))
    else:
        # shrink semantics: new worker k absorbs source workers 2k, 2k+1
        for e2, e4 in zip(jax.tree_util.tree_leaves(ef.error),
                          jax.tree_util.tree_leaves(src.ef.error)):
            for wk in range(w_new):
                want = np.asarray(e4[2 * wk:2 * wk + 2]).mean(0)
                np.testing.assert_array_equal(np.asarray(e2[wk]), want)

    params, ef, tail = run(cfg, sim, step_fn, params, ef, None,
                           CKPT_AT, STEPS)
    sim.assert_replicated(params, "params after elastic resume")
    got = jax.tree_util.tree_map(lambda x: np.asarray(x[0]), params)
    worst = worst_rel_diff(got, ref_params)
    assert worst < LINEARITY_TOL, (
        f"elastic W={w}→{w_new} resume violates Lemma-3 linearity: "
        f"{worst:.3e}")
    # and the losses agree to the same (loose) tolerance, step by step
    np.testing.assert_allclose(tail, ref_losses[CKPT_AT:], rtol=1e-4)


def _fresh_state(workers):
    _, sim, _, init_state, _ = build(workers)
    return init_state(KEY)


def test_resume_bit_exact_one_step_mid_pipeline(tmp_path):
    """ISSUE 8 satellite: a checkpoint taken *mid-pipeline* — a non-zero
    aggregate parked in ``EFState.inflight`` — must resume bit-exactly.
    The v2 envelope carries the in-flight buffers like any other state
    leaf; losing them would silently replay the pipeline bubble and fork
    the trajectory."""
    w = 4
    cfg, sim, step_fn, init_state, _ = build(w, staleness="one_step")
    params, ef = init_state(KEY)
    params, ef, ref_losses = run(cfg, sim, step_fn, params, ef, None,
                                 0, STEPS)
    ref_params = jax.tree_util.tree_map(lambda x: np.asarray(x[0]), params)

    cfg, sim, step_fn, init_state, _ = build(w, staleness="one_step")
    params, ef = init_state(KEY)
    params, ef, head = run(cfg, sim, step_fn, params, ef, None, 0, CKPT_AT)
    assert head == ref_losses[:CKPT_AT]
    # mid-pipeline for real: the parked aggregate is non-zero
    assert any(float(np.max(np.abs(np.asarray(x)))) > 0
               for x in jax.tree_util.tree_leaves(ef.inflight))
    save_at(tmp_path, sim, params, ef)

    cfg, sim, step_fn, _, params, ef, meta = restore_into(
        tmp_path, w, staleness="one_step")
    # the in-flight records restored structurally — no splice adaptation ran
    assert "inflight" not in meta, meta
    params, ef, tail = run(cfg, sim, step_fn, params, ef, None,
                           CKPT_AT, STEPS)
    assert tail == ref_losses[CKPT_AT:], (tail, ref_losses[CKPT_AT:])
    got = jax.tree_util.tree_map(lambda x: np.asarray(x[0]), params)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(ref_params)):
        np.testing.assert_array_equal(a, b)


def test_legacy_envelope_zero_fills_inflight(tmp_path):
    """Forward-compat splice: a pre-pipeline (v1) envelope has no
    ``['ef'].inflight`` records at all.  Restoring it into a
    ``staleness="one_step"`` template must zero-fill the in-flight buffers
    (one extra pipeline-bubble step, not a failure) and record the
    adaptation as ``meta["inflight"] == "zero_filled"``."""
    import msgpack
    import zlib

    w = 2
    cfg, sim, step_fn, init_state, _ = build(w)  # synchronous writer
    params, ef = init_state(KEY)
    params, ef, _ = run(cfg, sim, step_fn, params, ef, None, 0, CKPT_AT)
    path = save_at(tmp_path, sim, params, ef)

    # surgery: strip the inflight record(s), recompute the crc, mark v1
    payload = msgpack.unpackb(open(path, "rb").read(), raw=False)
    kept = [d for d in payload["leaves"]
            if not d["path"].startswith("['ef'].inflight")]
    assert len(kept) < len(payload["leaves"])  # the record existed
    payload["leaves"] = kept
    crc = 0
    for d in kept:
        if d["kind"] == "array":
            crc = zlib.crc32(d["data"], crc)
    payload["crc32"] = crc
    payload["meta"]["train_state_version"] = 1
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))

    cfg, sim, step_fn, _, params, ef, meta = restore_into(
        tmp_path, w, staleness="one_step")
    assert meta["inflight"] == "zero_filled", meta
    for leaf in jax.tree_util.tree_leaves(ef.inflight):
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.zeros_like(np.asarray(leaf)))
    # the continuation trains through the replayed bubble
    params, ef, tail = run(cfg, sim, step_fn, params, ef, None,
                           CKPT_AT, CKPT_AT + 2)
    assert all(np.isfinite(x) for x in tail), tail


def test_one_step_envelope_into_sync_template_drops(tmp_path):
    """The reverse splice: a pipelined envelope restored into a synchronous
    (``staleness="none"``) template discards the in-flight aggregate and
    says so — ``meta["inflight"] == "dropped"`` — instead of failing the
    strict structure check."""
    w = 2
    cfg, sim, step_fn, init_state, _ = build(w, staleness="one_step")
    params, ef = init_state(KEY)
    params, ef, _ = run(cfg, sim, step_fn, params, ef, None, 0, CKPT_AT)
    save_at(tmp_path, sim, params, ef)

    cfg, sim, step_fn, _, params, ef, meta = restore_into(tmp_path, w)
    assert meta["inflight"] == "dropped", meta
    assert ef.inflight is None
    params, ef, tail = run(cfg, sim, step_fn, params, ef, None,
                           CKPT_AT, CKPT_AT + 2)
    assert all(np.isfinite(x) for x in tail), tail


def test_resume_bit_exact_int4_wire(tmp_path):
    """ISSUE 9 satellite: save → kill → resume under ``wire_dtype="int4"``
    is bit-exact.  Quantization error flows into the EF buffers every step,
    so the quantized trajectory is part of the algorithm state — a resumed
    process must replay the exact same quantize/dequantize decisions."""
    w = 4
    cfg, sim, step_fn, init_state, _ = build(w, wire_dtype="int4")
    params, ef = init_state(KEY)
    params, ef, ref_losses = run(cfg, sim, step_fn, params, ef, None,
                                 0, STEPS)
    ref_params = jax.tree_util.tree_map(lambda x: np.asarray(x[0]), params)

    cfg, sim, step_fn, init_state, _ = build(w, wire_dtype="int4")
    params, ef = init_state(KEY)
    params, ef, head = run(cfg, sim, step_fn, params, ef, None, 0, CKPT_AT)
    assert head == ref_losses[:CKPT_AT]
    save_at(tmp_path, sim, params, ef, wire_dtype="int4")

    cfg, sim, step_fn, _, params, ef, meta = restore_into(
        tmp_path, w, wire_dtype="int4")
    assert meta["wire_dtype"] == "int4"
    params, ef, tail = run(cfg, sim, step_fn, params, ef, None,
                           CKPT_AT, STEPS)
    assert tail == ref_losses[CKPT_AT:], (tail, ref_losses[CKPT_AT:])
    got = jax.tree_util.tree_map(lambda x: np.asarray(x[0]), params)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(ref_params)):
        np.testing.assert_array_equal(a, b)
    # the quantized trajectory must actually differ from the float one —
    # otherwise this test would pass vacuously
    cfg, sim, step_fn, init_state, _ = build(w)
    params, ef = init_state(KEY)
    _, _, float_losses = run(cfg, sim, step_fn, params, ef, None, 0, STEPS)
    assert float_losses != ref_losses


def test_resume_mismatched_wire_dtype_rejected(tmp_path):
    """Restoring under a different ``--wire-dtype`` must fail with a clear
    error naming both policies (the CLI's resume guard)."""
    from repro.launch.train import check_wire_dtype_meta

    w = 1
    cfg, sim, step_fn, init_state, _ = build(w, wire_dtype="int4")
    params, ef = init_state(KEY)
    params, ef, _ = run(cfg, sim, step_fn, params, ef, None, 0, 1)
    save_at(tmp_path, sim, params, ef, wire_dtype="int4")
    _, _, _, _, _, _, meta = restore_into(tmp_path, w, wire_dtype="int4")

    with pytest.raises(SystemExit) as exc:
        check_wire_dtype_meta(meta, "float32")
    msg = str(exc.value)
    assert "'float32'" in msg and "'int4'" in msg and "wire" in msg
    check_wire_dtype_meta(meta, "int4")  # matching policy passes
    # legacy envelopes without the key imply the default policy
    check_wire_dtype_meta({}, "auto")
    with pytest.raises(SystemExit):
        check_wire_dtype_meta({}, "int8")


def test_truncated_sim_checkpoint_rejected(tmp_path):
    cfg, sim, step_fn, init_state, _ = build(1)
    params, ef = init_state(KEY)
    params, ef, _ = run(cfg, sim, step_fn, params, ef, None, 0, 1)
    path = save_at(tmp_path, sim, params, ef)
    raw = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(raw[:len(raw) - len(raw) // 3])
    with pytest.raises(CheckpointError):
        restore_into(tmp_path, 1)
    # a truncated envelope must also never be silently skipped: the error
    # names the file so operators can fall back to an older retained step
    try:
        restore_into(tmp_path, 1)
    except CheckpointError as e:
        assert os.path.basename(path) in str(e)
