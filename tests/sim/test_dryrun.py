"""In-process port of ``check_test_mesh_dryrun.py``'s train-step coverage:
one architecture per family (dense / SSM / MoE) compiles and runs a full
W-worker EF-PowerSGD step on the SimMesh substrate, keeping the bucketed
engine's communication invariant.  The serve-path (prefill/decode) and real
shard_map lowering remain covered by the ``-m slow`` subprocess tier."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.dist import CollectiveStats

from _helpers import sim_train

ARCHS = ["llama3-8b", "mamba2-1.3b", "qwen3-moe-30b-a3b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_sim_train_step_runs(arch):
    stats = CollectiveStats()
    losses, params, sim, (params_stacked, ef) = sim_train(
        arch=arch, workers=2, steps=2, batch=4, seq=32, stats=stats)
    assert all(jnp.isfinite(jnp.asarray(l)) for l in losses), losses
    for leaf in jax.tree_util.tree_leaves(params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    sim.assert_replicated(params_stacked, "params")
    # the communication model holds for every family: 2 data-axis
    # collectives per step (stats counts one traced step)
    assert stats.data_collectives == 2, stats.sizes
