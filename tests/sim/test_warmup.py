"""``start_compress_step`` warmup (the PyTorch DDP PowerSGD hook's
``start_powerSGD_iter``): dense fused aggregation for the first k steps,
error buffers pinned at zero, then compression kicks in."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compressors import IdentityCompressor
from repro.launch.train import TrainHyper

from _helpers import sim_train

K = 3


def _hyper(start_compress_step=0):
    return TrainHyper(q_chunk=32, warmup_steps=5, remat=False,
                      weight_decay=0.0, start_compress_step=start_compress_step)


def test_warmup_steps_bit_identical_to_identity():
    """Through step k−1 the warmed-up PowerSGD run must be bit-identical to
    the identity compressor: both aggregate the same dense deltas through
    the same fused flat all-reduce, and the error buffers stay exactly
    zero."""
    _, p_warm, _, (_, ef_warm) = sim_train(
        workers=2, steps=K, hyper=_hyper(start_compress_step=K))
    _, p_id, _, (_, ef_id) = sim_train(
        workers=2, steps=K, hyper=_hyper(), compressor=IdentityCompressor())
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(p_warm)[0],
            jax.tree_util.tree_flatten_with_path(p_id)[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=jax.tree_util.keystr(path))
    for leaf in jax.tree_util.tree_leaves(ef_warm.error):
        assert float(jnp.max(jnp.abs(leaf))) == 0.0


def test_compression_kicks_in_after_warmup():
    """At step k the trajectories must diverge (compression starts) and the
    error buffers must become non-zero (error feedback active)."""
    _, p_warm, _, (_, ef_warm) = sim_train(
        workers=2, steps=K + 2, hyper=_hyper(start_compress_step=K))
    _, p_id, _, _ = sim_train(
        workers=2, steps=K + 2, hyper=_hyper(),
        compressor=IdentityCompressor())
    diffs = [float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
             for a, b in zip(jax.tree_util.tree_leaves(p_warm),
                             jax.tree_util.tree_leaves(p_id))]
    assert max(diffs) > 0.0
    errs = [float(jnp.max(jnp.abs(leaf)))
            for leaf in jax.tree_util.tree_leaves(ef_warm.error)]
    assert max(errs) > 0.0


def test_warmup_matches_no_warmup_after_transient():
    """A warmed-up run and a never-warmed run share the compressor state
    layout — the cond's two branches must be structurally interchangeable
    (this is what makes the schedule jittable)."""
    _, _, _, (params_a, ef_a) = sim_train(
        workers=2, steps=2, hyper=_hyper(start_compress_step=1))
    _, _, _, (params_b, ef_b) = sim_train(
        workers=2, steps=2, hyper=_hyper())
    ta = jax.tree_util.tree_structure(ef_a.comp)
    tb = jax.tree_util.tree_structure(ef_b.comp)
    assert ta == tb
