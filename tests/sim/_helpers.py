"""Shared helpers for the SimMesh conformance suite.

Everything here runs W logical workers in-process on the single CPU device —
see ``src/repro/core/simmesh.py`` for the substrate.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.simmesh import SimMesh
from repro.data.synthetic import MarkovLM
from repro.launch.train import TrainHyper, make_sim_train_step

KEY = jax.random.key(0)


def sim_train(arch="llama3-8b", workers=1, steps=3, batch=8, seq=32,
              weights_for_step=None, stats=None, hyper=None, data=None,
              compressor=None, shard_fn=None, controller=None):
    """Run ``steps`` of the W-worker EF-PowerSGD sim train step.

    ``weights_for_step(step) -> (W,) array or None`` injects per-round
    scenario weights (dropout / heterogeneous batches / stragglers).
    ``shard_fn(batch) -> stacked batch`` overrides the default even split
    (``sim.shard``), e.g. to stack heterogeneous per-worker shards.
    ``controller`` (:class:`repro.core.powersgd.RankController`) drives an
    adaptive-rank schedule: consulted before each step with the previous
    step's residual metric; a switch transitions worker 0's (replicated)
    compressor state and re-replicates, so every worker takes the identical
    transition.  Returns ``(losses, params_w0, sim, (params, ef))`` —
    ``losses`` is the per-step worker-aggregated lm_loss, ``params_w0`` is
    worker 0's final params as numpy.
    """
    cfg = get_config(arch, reduced=True)
    if hyper is None:
        hyper = TrainHyper(q_chunk=32, warmup_steps=5, remat=False,
                           weight_decay=0.0)
    sim = SimMesh(workers)
    step_fn, init_state = make_sim_train_step(cfg, sim, hyper,
                                              compressor=compressor,
                                              stats=stats)
    if data is None:
        data = MarkovLM(vocab=cfg.vocab_size, seed=0)
    if shard_fn is None:
        shard_fn = sim.shard
    it = data.batches(batch, seq)
    params, ef = init_state(KEY)
    losses = []
    residual = None
    for i in range(steps):
        if controller is not None:
            from repro.core.error_feedback import EFState

            comp_w0 = jax.tree_util.tree_map(lambda x: x[0], ef.comp)
            new_comp, changed = controller.update(comp_w0, i, residual)
            if changed:
                ef = EFState(error=ef.error, momentum=ef.momentum,
                             comp=sim.replicate(new_comp), step=ef.step,
                             inflight=ef.inflight)
        b = shard_fn({k: jnp.asarray(v) for k, v in next(it).items()})
        w = weights_for_step(i) if weights_for_step is not None else None
        params, ef, met = step_fn(params, ef, b, KEY, w)
        losses.append(float(met["lm_loss"][0]))
        if "residual_ratio" in met:
            residual = float(met["residual_ratio"][0])
    params_w0 = jax.tree_util.tree_map(lambda x: np.asarray(x[0]), params)
    return losses, params_w0, sim, (params, ef)


def worst_rel_diff(tree_a, tree_b) -> float:
    """max over leaves of max|a−b| / max|b| — the subprocess linearity
    check's metric (check_linearity.py)."""
    worst = 0.0
    for a, b in zip(jax.tree_util.tree_leaves(tree_a),
                    jax.tree_util.tree_leaves(tree_b)):
        a, b = np.asarray(a), np.asarray(b)
        worst = max(worst, float(np.max(np.abs(a - b))
                                 / (np.max(np.abs(b)) + 1e-12)))
    return worst
