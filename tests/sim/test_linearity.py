"""Paper Lemma 3 / Appendix A.3, in-process: W-worker EF-PowerSGD training
equals 1 worker with the full batch — exactly (up to f32 reassociation).

This is the SimMesh port of ``tests/subprocess_scripts/check_linearity.py``
(which needs 8 fake XLA devices and a subprocess per mesh shape).  Here the
W workers are a stacked vmap axis on the single CPU device, so the whole
W ∈ {1, 2, 8} sweep runs in seconds and is bit-deterministic.  The retained
subprocess smoke test (``tests/test_multiworker.py``, ``-m slow``) pins the
same invariant on a real shard_map mesh.
"""

import numpy as np
import pytest

from _helpers import sim_train, worst_rel_diff

# the subprocess check's tolerance: f32 reassociation across the
# worker-mean, nothing else
TOL = 5e-5


@pytest.fixture(scope="module")
def single_worker_params():
    _, params, _, _ = sim_train(workers=1)
    return params


@pytest.mark.parametrize("workers", [2, 8])
def test_w_workers_equal_single(workers, single_worker_params):
    """Splitting the global batch over W workers must not change training."""
    _, params, _, _ = sim_train(workers=workers)
    worst = worst_rel_diff(params, single_worker_params)
    assert worst < TOL, f"linearity violated at W={workers}: {worst:.3e}"


@pytest.mark.parametrize("workers", [2, 8])
def test_workers_stay_bit_identical(workers):
    """Data-parallel sync invariant: every update is a function of
    all-reduced quantities only, so worker replicas never diverge."""
    _, _, sim, (params, ef) = sim_train(workers=workers, steps=2)
    sim.assert_replicated(params, "params")
    sim.assert_replicated(ef.momentum, "momentum")
    sim.assert_replicated(ef.comp, "Q factors")


def test_heterogeneous_batch_sizes_equal_single(single_worker_params):
    """Weighted linearity: workers with *different* batch sizes (weights ∝
    local token count) still reproduce the full-batch run exactly.

    Worker 0 owns 2 of the 8 sequences, worker 1 owns 6; worker 0's unused
    rows are padding (labels −1 → masked from the loss, zero gradient).
    The weighted worker-mean with w = valid-token count equals the global
    token mean — the generalization of Lemma 3 the capacity-heterogeneity
    scenario relies on.  Same driver defaults as the fixture, so the only
    deltas are the shard layout and the weights."""
    import jax.numpy as jnp

    sizes = (2, 6)
    pad_to = max(sizes)

    def stack_heterogeneous(batch):
        """(8, S) global batch → (2, 6, S) with worker 0 rows 2..5 padded."""
        out = {}
        for k, v in batch.items():
            w0, w1 = v[:sizes[0]], v[sizes[0]:]
            pad = ((0, pad_to - sizes[0]),) + ((0, 0),) * (v.ndim - 1)
            fill = -1 if k == "labels" else 0  # -1 masks the loss
            out[k] = jnp.stack([jnp.pad(w0, pad, constant_values=fill), w1])
        return out

    weights = np.array(sizes, np.float32)  # ∝ valid-token counts

    _, got, sim, (params, _) = sim_train(
        workers=2, shard_fn=stack_heterogeneous,
        weights_for_step=lambda step: weights)
    sim.assert_replicated(params, "params")
    worst = worst_rel_diff(got, single_worker_params)
    assert worst < TOL, f"weighted linearity violated: {worst:.3e}"
