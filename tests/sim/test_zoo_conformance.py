"""Zoo-wide transport-engine conformance on the SimMesh substrate.

For EVERY compressor in the ``make_compressor`` registry (the ISSUE
acceptance criterion):

* the fused engine path must numerically match the per-leaf reference path
  (``transport="per_leaf"`` / ``bucketing="off"``) for W ∈ {1, 4} workers —
  bit-exactly for the single-round schemes (no wire cast, elementwise
  fusion) and to float tolerance for bucketed PowerSGD (batched-matmul
  reassociation),
* one step must issue EXACTLY the documented number of fused data-axis
  collectives, independent of W and of the number of weight matrices, with
  the reduce-vs-gather split matching the scheme's linearity (§3),
* under scenario weights (worker dropout / heterogeneous batches) the
  gather path's receiver-side weighted combine must match the reference
  weighted ``pmean``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import matrixize
from repro.core.compressors import make_compressor
from repro.core.dist import CollectiveStats
from repro.core.simmesh import SimMesh

KEY = jax.random.key(0)

# name -> (exact fused collectives per step, reduce count, gather count)
# on the mixed tree below (3 weight matrices incl a stacked one + 2 vectors).
ZOO_BUDGETS = {
    "identity":             (1, 1, 0),   # everything fuses into one reduce
    "powersgd":             (2, 2, 0),   # P phase, Q phase
    "powersgd_cold":        (2, 2, 0),
    "powersgd_best_approx": (8, 8, 0),   # 4 power iterations × 2
    "unbiased_rank_k":      (1, 1, 0),   # MU factors + vectors, one reduce
    "random_block":         (1, 1, 0),
    "random_k":             (1, 1, 0),
    "sign_norm":            (3, 1, 2),   # int8 signs + f32 norms gathers, vec reduce
    "top_k":                (3, 1, 2),   # f32 values + int32 indices gathers
    "spectral_atomo":       (2, 1, 1),   # (P,V) triplet gather, vec reduce
    "exact_rank_k":         (1, 1, 0),   # dense oracle reduce
}


def _reference(name, rank=2):
    if name.startswith("powersgd"):
        return make_compressor(name, rank=rank, bucketing="off")
    return make_compressor(name, rank=rank, transport="per_leaf")


def _mixed_tree(w=1):
    k = KEY
    grads = {
        "w1": jax.random.normal(k, (w, 24, 16)),
        "conv": jax.random.normal(jax.random.fold_in(k, 1), (w, 8, 4, 3, 3)),
        "stack": jax.random.normal(jax.random.fold_in(k, 2), (w, 3, 12, 6)),
        "bias": jnp.broadcast_to(jnp.linspace(-1.0, 1.0, 7), (w, 7)),
        "scale": jnp.broadcast_to(jnp.ones((5,)), (w, 5)),
    }
    specs = {
        "w1": matrixize.MatrixSpec("matrix", 0),
        "conv": matrixize.MatrixSpec("conv", 0),
        "stack": matrixize.MatrixSpec("matrix", 1),
        "bias": matrixize.NONE,
        "scale": matrixize.NONE,
    }
    shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), grads)
    return grads, specs, shapes


def _run(comp, grads, specs, shapes, sim, weights=None, stats=None):
    state = sim.replicate(comp.init(shapes, specs, KEY))

    def one(g, s, wgt):
        ctx = sim.ctx(weight=wgt, stats=stats)
        out = comp.step(g, s, specs, ctx=ctx, key=KEY)
        return out.agg, out.recon, out.state, out.bits_per_worker

    wvec = jnp.ones((sim.workers,)) if weights is None else jnp.asarray(weights)
    return sim.run(one, in_axes=(0, 0, 0))(grads, state, wvec)


# exact single-round transports: elementwise fusion, no wire cast, identical
# per-worker decode → bit-exact vs the per-leaf reference.  Bucketed PowerSGD
# batches the matmuls (float reassociation) → allclose.
EXACT = {"identity", "unbiased_rank_k", "random_block", "random_k",
         "sign_norm", "top_k", "spectral_atomo", "exact_rank_k"}


@pytest.mark.parametrize("workers", [1, 4])
@pytest.mark.parametrize("name", sorted(ZOO_BUDGETS))
def test_engine_matches_per_leaf_reference(name, workers):
    grads, specs, shapes = _mixed_tree(workers)
    sim = SimMesh(workers)
    a_agg, a_rec, a_st, a_bits = _run(make_compressor(name, rank=2),
                                      grads, specs, shapes, sim)
    b_agg, b_rec, b_st, b_bits = _run(_reference(name), grads, specs, shapes,
                                      sim)
    assert int(a_bits[0]) == int(b_bits[0])
    for k in grads:
        a, b = np.asarray(a_agg[k]), np.asarray(b_agg[k])
        ar, br = np.asarray(a_rec[k]), np.asarray(b_rec[k])
        if name in EXACT:
            np.testing.assert_array_equal(a, b, err_msg=f"agg[{k}]")
            np.testing.assert_array_equal(ar, br, err_msg=f"recon[{k}]")
        else:
            np.testing.assert_allclose(a, b, atol=1e-5, err_msg=f"agg[{k}]")
            np.testing.assert_allclose(ar, br, atol=1e-5,
                                       err_msg=f"recon[{k}]")
    sim.assert_replicated(a_agg, f"{name} agg")


@pytest.mark.parametrize("workers", [1, 4])
@pytest.mark.parametrize("name", sorted(ZOO_BUDGETS))
def test_fused_collective_count_invariant(name, workers):
    """Exactly the documented number of fused data-axis collectives per
    step, split reduce vs gather per the scheme's linearity, for W ∈ {1,4}
    (trace-time counts are W-independent by construction — asserting both
    pins that)."""
    grads, specs, shapes = _mixed_tree(workers)
    sim = SimMesh(workers)
    stats = CollectiveStats()
    _run(make_compressor(name, rank=2), grads, specs, shapes, sim,
         stats=stats)
    total, n_reduce, n_gather = ZOO_BUDGETS[name]
    assert stats.data_collectives == total, (name, stats.sizes, stats.kinds)
    assert stats.reduce_collectives == n_reduce, (name, stats.kinds)
    assert stats.gather_collectives == n_gather, (name, stats.kinds)
    # gather records must carry the W fanout for byte accounting
    for kind, fanout in zip(stats.kinds, stats.fanouts):
        assert fanout == (workers if kind == "gather" else 1)


@pytest.mark.parametrize("name", ["sign_norm", "top_k", "spectral_atomo"])
def test_gather_combine_matches_weighted_reference(name):
    """Scenario weights (dropout / heterogeneous batches) travel with the
    gathered payloads: the engine's receiver-side weighted combine must
    match the reference path's weighted pmean of reconstructions."""
    W = 4
    grads, specs, shapes = _mixed_tree(W)
    sim = SimMesh(W)
    weights = [1.0, 0.0, 2.0, 0.5]
    a_agg, _, _, _ = _run(make_compressor(name, rank=2), grads, specs,
                          shapes, sim, weights=weights)
    b_agg, _, _, _ = _run(_reference(name), grads, specs, shapes, sim,
                          weights=weights)
    for k in grads:
        np.testing.assert_allclose(np.asarray(a_agg[k]),
                                   np.asarray(b_agg[k]), atol=1e-6,
                                   err_msg=k)


# ---------------------------------------------------------------------------
# quantized wire formats (ISSUE 9): budgets unchanged, loss envelope pinned
# ---------------------------------------------------------------------------

# per-leaf relative-error envelope of the quantized wire vs float32 wire:
# one quantization is ≤ 1/(2·qmax) relative per slot; powersgd quantizes
# BOTH factor phases (errors compound through P·Qᵀ), hence the headroom.
QUANT_REL_TOL = {"int8": 0.05, "int4": 0.5}
QUANT_SCHEMES = ["powersgd", "sign_norm", "top_k"]


@pytest.mark.parametrize("workers", [1, 4])
@pytest.mark.parametrize("wd", ["int8", "int4"])
@pytest.mark.parametrize("name", QUANT_SCHEMES)
def test_quantized_wire_budget_and_envelope(name, wd, workers):
    """Quantized wire must not change the transport's shape: exactly the
    documented collective count and reduce/gather split (the scale sidecar
    rides its payload's collective, it never adds one), gather fanout still
    W — and the aggregate stays inside the pinned tolerance of the float32
    wire (error feedback absorbs what is left)."""
    grads, specs, shapes = _mixed_tree(workers)
    sim = SimMesh(workers)
    stats = CollectiveStats()
    q_agg, _, _, _ = _run(make_compressor(name, rank=2, wire_dtype=wd),
                          grads, specs, shapes, sim, stats=stats)
    total, n_reduce, n_gather = ZOO_BUDGETS[name]
    assert stats.data_collectives == total, (name, wd, stats.kinds)
    assert stats.reduce_collectives == n_reduce, (name, wd, stats.kinds)
    assert stats.gather_collectives == n_gather, (name, wd, stats.kinds)
    for kind, fanout in zip(stats.kinds, stats.fanouts):
        assert fanout == (workers if kind == "gather" else 1)
    # quantized payload records carry the sub-byte itemsize + scale sidecar
    q_records = [(i, o) for i, o in zip(stats.itemsizes, stats.overheads)
                 if o > 0]
    assert q_records, (name, wd, stats.itemsizes, stats.overheads)
    assert all(i == (1 if wd == "int8" else 0.5) for i, _ in q_records)

    f_agg, _, _, _ = _run(make_compressor(name, rank=2, wire_dtype="auto"),
                          grads, specs, shapes, sim)
    sim.assert_replicated(q_agg, f"{name}/{wd} agg")
    for k in grads:
        a, b = np.asarray(q_agg[k]), np.asarray(f_agg[k])
        rel = np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-12)
        assert rel <= QUANT_REL_TOL[wd], (name, wd, k, rel)


@pytest.mark.parametrize("wd", ["int8", "int4"])
def test_quantized_wire_integer_payloads_exact(wd):
    """Integer payload parts (top_k's i32 indices, sign_norm's i8 signs)
    never quantize: with per-worker-identical norms/values the schemes'
    discrete selections must be bit-identical to the float32 wire."""
    W = 4
    grads, specs, shapes = _mixed_tree(1)
    grads = {k: jnp.broadcast_to(v, (W,) + v.shape[1:]) for k, v in
             grads.items()}
    sim = SimMesh(W)
    a, _, _, _ = _run(make_compressor("top_k", rank=2, wire_dtype=wd),
                      grads, specs, shapes, sim)
    b, _, _, _ = _run(make_compressor("top_k", rank=2, wire_dtype="auto"),
                      grads, specs, shapes, sim)
    for k in grads:
        qa, fb = np.asarray(a[k]), np.asarray(b[k])
        # identical support: quantization rescales surviving values but must
        # not move which coordinates survive
        np.testing.assert_array_equal(qa != 0, fb != 0, err_msg=k)


@pytest.mark.parametrize("wd", ["int8", "int4"])
@pytest.mark.parametrize("name", QUANT_SCHEMES)
def test_quantized_wire_lemma3_linearity(name, wd):
    """Lemma-3 linearity under quantized wire: quantization happens per
    worker *before* the combine and the combine stays the exact linear mean
    of the dequantized payloads — so W workers holding identical gradients
    must reproduce the single-worker aggregate bit-for-bit (any
    nonlinearity in the combine would break this)."""
    W = 4
    g1, specs, shapes = _mixed_tree(1)
    gW = {k: jnp.broadcast_to(v, (W,) + v.shape[1:]) for k, v in g1.items()}
    a1, _, _, _ = _run(make_compressor(name, rank=2, wire_dtype=wd),
                       g1, specs, shapes, SimMesh(1))
    aW, _, _, _ = _run(make_compressor(name, rank=2, wire_dtype=wd),
                       gW, specs, shapes, SimMesh(W))
    for k in g1:
        np.testing.assert_array_equal(np.asarray(aW[k])[:1],
                                      np.asarray(a1[k]), err_msg=(name, wd, k))


def test_gather_payload_bytes_scale_with_workers():
    """The satellite fix: non-linear schemes' recorded traffic must be the
    W-scaled gather payload, not a dense all-reduce.  sign_norm's sign
    payload must also travel at 1-byte itemsize."""
    W = 4
    grads, specs, shapes = _mixed_tree(W)
    sim = SimMesh(W)
    stats = CollectiveStats()
    _run(make_compressor("sign_norm", rank=2), grads, specs, shapes, sim,
         stats=stats)
    n_coords = sum(np.prod(s.shape) for k, s in shapes.items()
                   if specs[k].is_compressed())
    sign_bytes = [b for i, kind, b in zip(stats.itemsizes, stats.kinds,
                                          stats.bytes_per_collective())
                  if kind == "gather" and i == 1]
    assert sign_bytes == [int(n_coords) * 1 * W]
