"""Injected W-worker scenarios that subprocess meshes cannot express:
worker dropout, straggler-skipped rounds, divergent per-worker EF memories.
Error feedback must keep converging through all of them (Alg. 2's claim that
the compression error is *memorized*, not lost)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import MarkovLM

from _helpers import sim_train


def _learnable_stream():
    # order-1 with 8 token clusters: learnable in tens of steps AND low-rank
    # gradients — the same regime test_system.py trains in
    return MarkovLM(vocab=1024, seed=0, order=1, clusters=8)


def test_worker_dropout_converges():
    """One of 4 workers drops out of aggregation every round (rotating), so
    every worker's contribution is lost 25% of the time.  Training still
    converges and replicas stay in sync: a dropped worker still *receives*
    the aggregated update (weight 0 only removes its contribution)."""
    W = 4

    def drop_rotating(step):
        w = np.ones((W,), np.float32)
        w[step % W] = 0.0
        return w

    losses, _, sim, (params, ef) = sim_train(
        workers=W, steps=40, batch=8, seq=64,
        weights_for_step=drop_rotating, data=_learnable_stream())
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses
    sim.assert_replicated(params, "params")
    for leaf in jax.tree_util.tree_leaves(params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_straggler_skipped_rounds_converge():
    """A persistent straggler (worker 3) misses every other round.  Its EF
    memory keeps accumulating what the aggregate missed, so convergence
    survives with a biased-but-bounded error process."""
    W = 4

    def straggler(step):
        w = np.ones((W,), np.float32)
        if step % 2 == 1:
            w[3] = 0.0
        return w

    losses, _, sim, (params, _) = sim_train(
        workers=W, steps=40, batch=8, seq=64,
        weights_for_step=straggler, data=_learnable_stream())
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses
    sim.assert_replicated(params, "params")


def test_heterogeneous_batches_converge():
    """Workers weighted ∝ their (unequal) token counts converge too — the
    exactness half of this scenario is test_linearity.py::
    test_heterogeneous_batch_sizes_equal_single."""
    W = 4
    weights = np.array([1.0, 1.0, 3.0, 3.0], np.float32)

    losses, _, sim, (params, _) = sim_train(
        workers=W, steps=40, batch=8, seq=64,
        weights_for_step=lambda step: weights, data=_learnable_stream())
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses
    sim.assert_replicated(params, "params")


def test_error_memories_diverge_but_params_do_not():
    """Algorithm 2's per-worker state, observable at last: each worker's
    error buffer e_w tracks *its own* data shard, so the buffers must
    diverge across workers while the all-reduced params stay identical."""
    _, _, sim, (params, ef) = sim_train(workers=4, steps=3,
                                        data=_learnable_stream())
    sim.assert_replicated(params, "params")
    # at least the big matrix leaves' error buffers must differ across
    # workers (each worker compressed a different Δ_w)
    diverged = 0
    for leaf in jax.tree_util.tree_leaves(ef.error):
        a = np.asarray(leaf)
        if a.ndim > 1 and not (a == a[:1]).all():
            diverged += 1
    assert diverged > 0, "per-worker EF memories unexpectedly identical"
