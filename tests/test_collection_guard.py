"""Collection hygiene guard (ISSUE 5 CI satellite).

This repo's test dirs have no ``__init__.py`` (rootdir-style pytest
layout), so two test modules with the same basename in different
directories — e.g. ``tests/test_foo.py`` and ``tests/sim/test_foo.py`` —
collide in ``sys.modules`` and abort collection with an import-mismatch
error.  That bit us once (``test_rank_schedule.py``, 2026-07-30); this
guard turns the pitfall into a named failure at the moment the duplicate
is introduced, not a confusing collection crash later."""

import collections
import pathlib


def test_no_duplicate_test_module_basenames():
    root = pathlib.Path(__file__).resolve().parent
    by_name = collections.defaultdict(list)
    for path in sorted(root.rglob("test_*.py")):
        by_name[path.name].append(path.relative_to(root.parent))
    dups = {name: [str(p) for p in paths]
            for name, paths in by_name.items() if len(paths) > 1}
    assert not dups, (
        "duplicate test-module basenames break pytest collection in this "
        f"repo (no __init__.py in test dirs) — rename one of each: {dups}")
