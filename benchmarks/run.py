"""Benchmark driver: one function per paper table (DESIGN.md §6).

Prints ``table,key=value,...`` CSV-ish lines and writes JSON to
experiments/benchmarks/.  ``--quick`` shrinks step counts for CI.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
sys.path.insert(0, os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "src")))

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced step counts (CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated table names (e.g. table1,fig3)")
    ap.add_argument("--out", default="experiments/benchmarks")
    args = ap.parse_args()

    from benchmarks import tables
    from benchmarks.common import LMSpec
    from repro.models import model as model_lib
    from repro.configs.base import get_config

    steps = 40 if args.quick else 150
    spec = LMSpec(steps=steps, workers=4, batch_per_worker=4)

    # small params tree for timing-model tables
    cfg_small = get_config("llama3-8b", reduced=True)
    params_small = model_lib.init(jax.random.key(0), cfg_small, 1)
    specs_small = model_lib.mspecs(cfg_small)

    runs = {
        "table1_error_feedback": lambda: tables.table1_error_feedback(spec),
        "table2_warm_start": lambda: tables.table2_warm_start(spec),
        "table3_rank_sweep": lambda: tables.table3_rank_sweep(spec),
        "table4_compressor_zoo": lambda: tables.table4_compressor_zoo(spec),
        "table5_time_breakdown": lambda: tables.table5_time_breakdown(
            params_small, specs_small),
        "table6_other_methods": lambda: tables.table6_other_methods(spec),
        "table7_lstm": lambda: tables.table7_lstm(40 if args.quick else 120),
        "fig3_scaling": lambda: tables.fig3_scaling(params_small, specs_small),
        "adaptive_rank_profile": lambda: tables.adaptive_rank_profile(spec),
        "resume_overhead": lambda: tables.resume_overhead(
            spec, ckpt_every=10 if args.quick else 20),
        "comm_profile": lambda: tables.comm_profile(params_small, specs_small),
        "sync_mode_profile": lambda: tables.sync_mode_profile(
            params_small, specs_small),
        "zoo_transport_profile": lambda: tables.zoo_transport_profile(
            params_small, specs_small),
        "overlap_profile": lambda: tables.overlap_profile(
            params_small, specs_small),
        "appendixD_transformer": lambda: tables.appendixD_transformer(spec),
    }
    if args.only:
        keep = {k.strip() for k in args.only.split(",")}
        runs = {k: v for k, v in runs.items() if any(s in k for s in keep)}

    os.makedirs(args.out, exist_ok=True)
    for name, fn in runs.items():
        t0 = time.time()
        rows = fn()
        dt = time.time() - t0
        print(f"\n=== {name} ({dt:.1f}s) ===")
        for row in rows:
            print(name + "," + ",".join(f"{k}={v}" for k, v in row.items()))
        with open(os.path.join(args.out, name + ".json"), "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
