"""§Perf hillclimbing driver: named iterations over the three chosen
(arch × shape) pairs.  Each iteration re-lowers + re-compiles on the
production 16×16 mesh and records the three roofline terms.

    PYTHONPATH=src python -m benchmarks.perf_hillclimb [--only PAIR]

Results land in experiments/perf/<pair>__<label>.json; the table for
EXPERIMENTS.md §Perf comes from --report.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import glob
import json

# (pair, label, hypothesis, cfg_overrides, hyper_overrides)
ITERATIONS = [
    # ---- llama3-8b × train_4k: the paper-representative pair -------------
    ("llama3_8b/train_4k", "baseline",
     "paper-faithful: fp32, Gram-Schmidt, remat on, rank 2", {}, {}),
    ("llama3_8b/train_4k", "remat_off",
     "footprint is 0.2 GiB/chip of 16 GB - remat recompute reads/flops are "
     "pure waste; predict memory term -25%%, useful -> ~1.0", {},
     {"remat": False}),
    ("llama3_8b/train_4k", "bf16",
     "bf16 params+activations halve every byte moved (HBM and wire); "
     "predict memory and collective terms both ~-50%%",
     {"dtype": "bfloat16"}, {}),
    ("llama3_8b/train_4k", "bf16_remat_off",
     "combine the two confirmed wins", {"dtype": "bfloat16"},
     {"remat": False}),
    ("llama3_8b/train_4k", "bf16_remat_off_cholqr",
     "CholeskyQR replaces the sequential rank-2 Gram-Schmidt with two "
     "tall-skinny matmuls (MXU-native); roofline terms ~unchanged (r=2 is "
     "tiny) but removes the serial dependency chain",
     {"dtype": "bfloat16"}, {"remat": False, "orthogonalizer": "cholesky_qr"}),

    # ---- qwen3-moe-30b-a3b × train_4k: worst roofline fraction -----------
    ("qwen3_moe_30b_a3b/train_4k", "baseline",
     "paper-faithful baseline", {}, {}),
    ("qwen3_moe_30b_a3b/train_4k", "remat_off",
     "remat recompute re-reads every expert weight (30B params) twice; "
     "predict memory term -30%%", {}, {"remat": False}),
    ("qwen3_moe_30b_a3b/train_4k", "bf16",
     "expert weights dominate bytes; bf16 halves them", {"dtype": "bfloat16"},
     {}),
    ("qwen3_moe_30b_a3b/train_4k", "bf16_remat_off",
     "combine", {"dtype": "bfloat16"}, {"remat": False}),
    ("qwen3_moe_30b_a3b/train_4k", "bf16_remat_off_cap10",
     "capacity factor 1.25 -> 1.0 shrinks dispatch buffers and dropped-token "
     "compute by 20%%; predict small memory win on top",
     {"dtype": "bfloat16", "moe_capacity_factor": 1.0}, {"remat": False}),

    # ---- codeqwen1.5-7b × prefill_32k: most collective-bound -------------
    ("codeqwen15_7b/prefill_32k", "baseline",
     "paper-faithful baseline (Megatron TP with K/V all-gather)", {}, {}),
    ("codeqwen15_7b/prefill_32k", "local_kv",
     "kv=32 heads shard evenly over 16 chips: q heads only need local kv "
     "heads, so skip the 68.7 GB K/V all-gather in forward and emit the "
     "cache via one all-to-all (result 1/16 the gather); predict "
     "collective term ~-45%%", {"tp_local_kv": True}, {}),
    ("codeqwen15_7b/prefill_32k", "local_kv_bf16",
     "halve the remaining psum(model) wire bytes too",
     {"tp_local_kv": True, "dtype": "bfloat16"}, {}),

    # ---- round 2: attack the new dominant terms (fp32 — bf16 refuted on
    # the CPU-lowered artifact, see the iteration log) ----------------------
    ("llama3_8b/train_4k", "remat_off_qc2048",
     "4x larger flash q-chunks -> 4x fewer scan steps over scores; "
     "predict small memory-term win from fewer intermediate spills", {},
     {"remat": False, "q_chunk": 2048}),
    ("llama3_8b/train_4k", "remat_off_unroll4",
     "unroll 4 layers per scan step: cross-layer fusion opportunities; "
     "predict <=5%% memory win at 4x compile time", {},
     {"remat": False, "unroll": 4}),
    ("qwen3_moe_30b_a3b/train_4k", "remat_off_cap10",
     "isolate capacity 1.0 without bf16 (bf16 refuted): dispatch buffers "
     "and expert flops shrink 20%%", {"moe_capacity_factor": 1.0},
     {"remat": False}),
    ("codeqwen15_7b/prefill_32k", "local_kv_qc2048",
     "dominant flipped to memory (1.55s): larger q chunks cut score-tensor "
     "spills in the 32k-long flash loop", {"tp_local_kv": True},
     {"q_chunk": 2048}),

    # ---- bonus pair 4: qwen3-moe decode_32k (production serving regime;
    # useful=0.09, memory 99.5ms vs ~10ms napkin) --------------------------
    ("qwen3_moe_30b_a3b/decode_32k", "baseline",
     "paper-faithful baseline (expand-kv decode attention)", {}, {}),
    ("qwen3_moe_30b_a3b/decode_32k", "gqa_grouped",
     "per-layer probe showed decode reads the kv cache expanded to every q "
     "head (group=8x duplication via jnp.take); grouping q heads by kv head "
     "in the einsum avoids the expansion — predict memory term -50%%",
     {"gqa_grouped_decode": True}, {}),
]


def tagify(pair: str, label: str) -> str:
    return pair.replace("/", "_") + "__" + label


def run(args):
    import dataclasses

    from repro.launch.dryrun import lower_combo
    from repro.launch.train import TrainHyper

    os.makedirs(args.out, exist_ok=True)
    for pair, label, hypothesis, cfg_over, hyp_over in ITERATIONS:
        if args.only and args.only not in pair:
            continue
        arch, shape = pair.split("/")
        path = os.path.join(args.out, tagify(pair, label) + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[skip] {pair} {label}")
            continue
        hyper = dataclasses.replace(TrainHyper(), **hyp_over)
        report = lower_combo(arch, shape, multi_pod=False, hyper=hyper,
                             cfg_overrides=cfg_over or None)
        report["label"] = label
        report["hypothesis"] = hypothesis
        report["cfg_overrides"] = cfg_over
        report["hyper_overrides"] = hyp_over
        with open(path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"[done] {pair} {label}")


def report(args):
    rows = []
    for path in sorted(glob.glob(os.path.join(args.out, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    order = {tagify(p, l): i for i, (p, l, *_rest) in enumerate(ITERATIONS)}
    rows.sort(key=lambda d: order.get(
        tagify(d["arch"] + "/" + d["shape"], d["label"]), 999))
    print("| pair | iteration | compute | memory | collective | dominant | useful |")
    print("|---|---|---:|---:|---:|---|---:|")
    for d in rows:
        r = d["roofline"]
        print(f"| {d['arch']}×{d['shape']} | {d['label']} "
              f"| {r['compute_s']:.2f}s | {r['memory_s']:.2f}s "
              f"| {r['collective_s']:.2f}s | {r['dominant']} "
              f"| {r['useful_flops_frac']:.2f} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--only", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--report", action="store_true")
    args = ap.parse_args()
    if args.report:
        report(args)
    else:
        run(args)


if __name__ == "__main__":
    main()
