"""Render the §Roofline table in EXPERIMENTS.md from experiments/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline_table [--mesh 16x16] [--md]

Each row: arch × shape — the three roofline terms (seconds), the dominant
term, MODEL_FLOPS/HLO_FLOPs usefulness, and a per-device HBM figure.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = ["llama3_8b", "mamba2_1p3b", "jamba_v01_52b", "musicgen_medium",
              "llava_next_34b", "qwen3_moe_30b_a3b", "codeqwen15_7b",
              "olmoe_1b_7b", "qwen3_4b", "yi_6b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath: str, mesh: str):
    rows = []
    for path in glob.glob(os.path.join(dirpath, "*.json")):
        with open(path) as f:
            d = json.load(f)
        if d["mesh"] == mesh:
            rows.append(d)
    rows.sort(key=lambda d: (ARCH_ORDER.index(d["arch"]),
                             SHAPE_ORDER.index(d["shape"])))
    return rows


def fmt_s(x: float) -> str:
    if x >= 0.1:
        return f"{x:7.2f}s "
    return f"{x*1e3:7.2f}ms"


def render(rows, md: bool = False) -> str:
    out = []
    if md:
        out.append("| arch | shape | compute | memory | collective | "
                   "dominant | useful | HBM/chip |")
        out.append("|---|---|---:|---:|---:|---|---:|---:|")
    for d in rows:
        r = d["roofline"]
        hbm = (d["memory"]["argument_size_in_bytes"]
               + d["memory"]["temp_size_in_bytes"]) / d["chips"] / 2**30
        cells = [d["arch"], d["shape"], fmt_s(r["compute_s"]).strip(),
                 fmt_s(r["memory_s"]).strip(),
                 fmt_s(r["collective_s"]).strip(), r["dominant"],
                 f"{r['useful_flops_frac']:.2f}", f"{hbm:.1f} GiB"]
        if md:
            out.append("| " + " | ".join(cells) + " |")
        else:
            out.append(f"{cells[0]:<18} {cells[1]:<12} {cells[2]:>10} "
                       f"{cells[3]:>10} {cells[4]:>10} {cells[5]:<10} "
                       f"{cells[6]:>6} {cells[7]:>9}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir, args.mesh)
    if not args.md:
        print(f"{'arch':<18} {'shape':<12} {'compute':>10} {'memory':>10} "
              f"{'collective':>10} {'dominant':<10} {'useful':>6} {'HBM':>9}")
    print(render(rows, md=args.md))
    print(f"\n{len(rows)} combos on mesh {args.mesh}")


if __name__ == "__main__":
    main()
