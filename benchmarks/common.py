"""Shared benchmark harness.

Reproduces the paper's experimental axes at CPU scale:

* **Quality** — real training of a small transformer LM / LSTM / ResNet on
  deterministic synthetic tasks, under every compressor, with the paper's
  W-worker semantics simulated exactly: the per-worker gradient + compressor
  step runs under ``jax.vmap(axis_name="data")`` so every ``pmean``/``psum``
  inside the compressors aggregates over simulated workers — faithful for
  non-linear schemes (sign, top-K, Signum majority vote) too.

* **Bytes** — exact analytic accounting (identical to the paper's tables).

* **Time** — coding/decoding time is *measured* on this host; communication
  time is *modeled* with the standard α-β cost model at the paper's two
  backends (NCCL-like on 10 Gbit/s, GLOO-like effective 2.5 Gbit/s):
      all-reduce : 2·(W−1)/W · bytes / bw
      all-gather : (W−1) · bytes / bw   (and decode cost scales with W)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import error_feedback as ef_lib
from repro.core import matrixize
from repro.core.compressors import Compressor
from repro.core.dist import MeshCtx
from repro.data.synthetic import MarkovLM

SIM_AXIS = "data"
SIM_CTX = MeshCtx(data_axes=(SIM_AXIS,))


@dataclasses.dataclass
class LMSpec:
    vocab: int = 256
    d_model: int = 128
    layers: int = 2
    heads: int = 4
    seq: int = 64
    batch_per_worker: int = 4
    workers: int = 4
    steps: int = 150
    lr: float = 0.1
    momentum: float = 0.9
    seed: int = 0
    # order-1 Markov with 8 token clusters: learnable within the step budget
    # and with genuinely low-rank gradients (the paper's premise, §2) —
    # order-2 hash transitions are a memorization cliff no compressor (nor
    # uncompressed SGD) can descend in this budget.
    order: int = 1
    clusters: int = 8


def _make_cfg(spec: LMSpec):
    from repro.configs.base import LayerSlot, ModelConfig

    return ModelConfig(
        name="bench-lm", arch_type="dense", num_layers=spec.layers,
        d_model=spec.d_model, num_heads=spec.heads, num_kv_heads=spec.heads,
        head_dim=spec.d_model // spec.heads, d_ff=spec.d_model * 4,
        vocab_size=spec.vocab, rope_theta=10000.0,
        slots=(LayerSlot("attn", "dense"),))


def payload_floats(params, specs, comp_state):
    """(compressed, uncompressed) floats ONE step sends per worker, at the
    state's *active* per-leaf ranks (adaptive schedules move them)."""
    comp, unc = [0], [0]

    def leaf(p, sp, q):
        if q is None or matrixize.matrix_shape(p.shape, sp) is None:
            unc[0] += matrixize.uncompressed_floats(p.shape)
        else:
            comp[0] += matrixize.compressed_floats(p.shape, sp, q.shape[-1])

    jax.tree_util.tree_map(leaf, params, specs, comp_state,
                           is_leaf=lambda x: x is None)
    return comp[0], unc[0]


def train_lm(compressor: Compressor, spec: LMSpec = LMSpec(),
             eval_batches: int = 8, controller=None,
             init_comp_transform=None):
    """Train the benchmark LM under EF + ``compressor`` with W simulated
    workers.  Returns a result dict.

    ``controller`` (a :class:`repro.core.powersgd.RankController`) drives an
    adaptive-rank schedule: it is consulted before every step with the
    previous step's worker-mean residual ratio (requires a compressor built
    with ``track_residual=True`` for residual-driven schedules) and rank
    switches transition the warm-start factors in place — the jitted step
    retraces on the new shapes.  The result then also reports the rank
    switch history and the *cumulative* compressed floats actually sent,
    the adaptive-vs-fixed bits comparison of ``adaptive_rank_profile``.

    ``init_comp_transform(comp_state) -> comp_state`` rewrites the freshly
    initialized compressor state before training — how an
    :func:`repro.core.autotune.apply_plan` installs per-bucket ranks.
    """
    from repro.core.dist import SINGLE
    from repro.models import model as model_lib

    cfg = _make_cfg(spec)
    key = jax.random.key(spec.seed)
    params = model_lib.init(key, cfg, model_shards=1)
    specs = model_lib.mspecs(cfg)
    state = ef_lib.init_state(compressor, params, specs, key)
    if init_comp_transform is not None:
        state = ef_lib.replace_comp(state, init_comp_transform(state.comp))
    # per-worker error buffers: broadcast zeros over the worker axis
    state = ef_lib.EFState(
        error=jax.tree_util.tree_map(
            lambda e: jnp.zeros((spec.workers,) + e.shape, e.dtype), state.error),
        momentum=state.momentum, comp=state.comp, step=state.step)

    data = MarkovLM(vocab=spec.vocab, seed=spec.seed, order=spec.order,
                    clusters=spec.clusters)
    it = data.batches(spec.batch_per_worker * spec.workers, spec.seq)
    eval_data = []
    for i in range(eval_batches):
        b = data.sample(32, spec.seq, step=10_000 + i)
        eval_data.append({"tokens": jnp.asarray(b[:, :-1]),
                          "labels": jnp.asarray(b[:, 1:])})

    def worker_step(params, err, batch, comp_state, step_idx, key):
        def loss_fn(p):
            return model_lib.loss_fn(p, batch, cfg, SINGLE, q_chunk=32,
                                     remat=False)

        grads, metrics = jax.grad(loss_fn, has_aux=True)(params)
        st = ef_lib.EFState(error=err, momentum=None, comp=comp_state,
                            step=step_idx)
        deltas = jax.tree_util.tree_map(jnp.add, grads, err)
        out = compressor.step(deltas, comp_state,
                              specs, ctx=SIM_CTX, key=key)
        new_err = jax.tree_util.tree_map(jnp.subtract, deltas, out.recon)
        res = (out.metrics["residual_ratio"] if out.metrics is not None
               else jnp.zeros(()))
        return out.agg, out.state, new_err, metrics["lm_loss"], res

    @jax.jit
    def train_step(params, state, batch, key):
        key = jax.random.fold_in(key, state.step)
        bw = jax.tree_util.tree_map(
            lambda x: x.reshape((spec.workers, spec.batch_per_worker) + x.shape[1:]),
            batch)
        agg, comp_state, new_err, losses, res = jax.vmap(
            worker_step, in_axes=(None, 0, 0, None, None, None),
            out_axes=0, axis_name=SIM_AXIS,
        )(params, state.error, bw, state.comp, state.step, key)
        # agg / comp_state are pmean'd inside ⇒ identical on every worker
        agg = jax.tree_util.tree_map(lambda x: x[0], agg)
        comp_state = jax.tree_util.tree_map(lambda x: x[0], comp_state)
        new_m = jax.tree_util.tree_map(
            lambda m, d: spec.momentum * m + d, state.momentum, agg)
        new_p = jax.tree_util.tree_map(
            lambda x, d, m: x - spec.lr * (d + m), params, agg, new_m)
        new_state = ef_lib.EFState(error=new_err, momentum=new_m,
                                   comp=comp_state, step=state.step + 1)
        return new_p, new_state, losses, jnp.mean(res)

    @jax.jit
    def eval_loss(params, batch):
        loss, _ = model_lib.loss_fn(params, batch, cfg, SINGLE, q_chunk=32,
                                    remat=False)
        return loss

    key_run = jax.random.key(123)
    t0 = time.time()
    bits = None
    residual = None
    # exact per-step payload accounting needs per-leaf state (PowerSGD's Q
    # factors carry the active ranks); stateless schemes fall back to the
    # constant probe bits below
    stateful = state.comp is not None
    step_floats = payload_floats(params, specs, state.comp) if stateful \
        else (0, 0)
    floats_sent = 0
    for i in range(spec.steps):
        if controller is not None:
            new_comp, changed = controller.update(state.comp, i, residual)
            if changed:  # factor shapes moved: the step retraces
                state = ef_lib.replace_comp(state, new_comp)
                step_floats = payload_floats(params, specs, state.comp)
        floats_sent += step_floats[0]
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, state, losses, res = train_step(params, state, batch, key_run)
        residual = float(res)
        if bits is None:
            shapes = jax.tree_util.tree_map(
                lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
            probe = compressor.step(
                jax.tree_util.tree_map(jnp.zeros_like, params),
                compressor.init(shapes, specs, key_run), specs, key=key_run)
            bits = probe.bits_per_worker
    train_time = time.time() - t0

    ev = float(np.mean([float(eval_loss(params, b)) for b in eval_data]))
    result = {
        "compressor": compressor.name,
        "eval_loss": ev,
        "eval_ppl": float(np.exp(ev)),
        "bits_per_worker_per_step": int(bits),
        "allreduce": compressor.allreduce,
        "train_time_s": train_time,
        "steps": spec.steps,
        "workers": spec.workers,
        # cumulative *compressed* floats over the run, at each step's active
        # ranks — constant-rank runs send steps × (payload floats); for
        # stateless schemes this falls back to the probe's payload count
        "compressed_floats_total": (int(floats_sent) if stateful
                                    else int(bits) // 32 * spec.steps),
    }
    if controller is not None:
        result["rank_history"] = list(controller.history)
        result["final_rank"] = controller.rank
    return result


# ---------------------------------------------------------------------------
# fault-tolerant resume: overhead + what dropping each state piece costs
# ---------------------------------------------------------------------------


def resume_profile(spec: LMSpec, ckpt_dir: str, ckpt_every: int = 20) -> list:
    """Measure the full-state checkpoint subsystem on the benchmark LM.

    Runs the W-worker SimMesh trainer (the same ``make_sim_train_step`` +
    ``repro.checkpoint.train_state`` path the CLI resume uses) and reports:

    * per-checkpoint cost — envelope size, save / restore wall time, and
      the save overhead as a fraction of train wall time at ``ckpt_every``;
    * the kill/resume ablation — from a checkpoint at 80% of the horizon
      (the realistic preemption point; an earlier kill lets the tail
      re-absorb the damage below measurability), continue four ways:
      uninterrupted (reference), ``resume_full``
      (must be **bit-exact**: identical per-step losses), and the two
      degraded restores the docs quote — ``resume_drop_ef`` (error buffers
      zeroed: Alg. 1's accumulated feedback discarded) and
      ``resume_drop_warm_start`` (Q factors re-randomized: §3's warm start
      restarted) — quantifying why EF memory and warm-start factors are
      algorithm state, not derivable caches.
    """
    import os

    from repro.checkpoint import (TrainState, canonicalize_sim,
                                  replicate_sim, restore_train_state,
                                  save_train_state)
    from repro.core.compressors import PowerSGDCompressor
    from repro.core.simmesh import SimMesh
    from repro.launch.train import TrainHyper, make_sim_train_step
    from repro.models import model as model_lib

    cfg = _make_cfg(spec)
    sim = SimMesh(spec.workers)
    key = jax.random.key(spec.seed)
    hyper = TrainHyper(lr=spec.lr, momentum=spec.momentum, q_chunk=32,
                       warmup_steps=20, remat=False, weight_decay=0.0)

    def build():
        """A fresh 'process': new compressor instance, new jitted step."""
        return make_sim_train_step(cfg, sim, hyper,
                                   compressor=PowerSGDCompressor(rank=2))

    data = MarkovLM(vocab=spec.vocab, seed=spec.seed, order=spec.order,
                    clusters=spec.clusters)
    eval_data = []
    for i in range(8):
        b = data.sample(32, spec.seq, step=10_000 + i)
        eval_data.append({"tokens": jnp.asarray(b[:, :-1]),
                          "labels": jnp.asarray(b[:, 1:])})

    @jax.jit
    def eval_loss_fn(params, batch):
        from repro.core.dist import SINGLE

        loss, _ = model_lib.loss_fn(params, batch, cfg, SINGLE, q_chunk=32,
                                    remat=False)
        return loss

    def eval_loss(params):
        p0 = jax.tree_util.tree_map(lambda x: x[0], params)
        return float(np.mean([float(eval_loss_fn(p0, b))
                              for b in eval_data]))

    def batch_for(i):
        toks = data.sample(spec.batch_per_worker * spec.workers, spec.seq,
                           step=i)
        return sim.shard({"tokens": jnp.asarray(toks[:, :-1]),
                          "labels": jnp.asarray(toks[:, 1:].copy())})

    def run(step_fn, params, ef, start, stop, save_every=0, save_dir=None,
            save_times=None):
        losses = []
        for i in range(start, stop):
            params, ef, met = step_fn(params, ef, batch_for(i), key)
            losses.append(float(met["lm_loss"][0]))
            # the mid-run save is the ablations' kill point — force it even
            # when the cadence doesn't land on it
            if save_every and ((i + 1) % save_every == 0 or i + 1 == mid):
                jax.block_until_ready(params)  # don't bill async dispatch
                t0 = time.perf_counter()
                p, e = canonicalize_sim(sim, params, ef)
                path = save_train_state(
                    save_dir, TrainState(params=p, ef=e, key=key,
                                         data_step=jnp.asarray(e.step)),
                    keep=1000)
                save_times.append(time.perf_counter() - t0)
                save_times_bytes[0] = os.path.getsize(path)
        return params, ef, losses

    # kill at 80% of the horizon: the realistic preemption case, and short
    # enough a tail that the degraded restores can't fully re-absorb their
    # damage before eval (at steps/2 both wash out to ~0.003 nats)
    steps, mid = spec.steps, (4 * spec.steps) // 5
    save_times, save_times_bytes = [], [0]

    # uninterrupted reference (with periodic saves, which we time)
    step_fn, init_state = build()
    params, ef = init_state(key)
    t0 = time.perf_counter()
    params, ef, ref_losses = run(step_fn, params, ef, 0, steps,
                                 save_every=ckpt_every, save_dir=ckpt_dir,
                                 save_times=save_times)
    train_wall = time.perf_counter() - t0
    ref_eval = eval_loss(params)

    def resume(mutate=None):
        """Fresh process: restore the step-``mid`` checkpoint, optionally
        degrade one state piece, continue to the horizon."""
        step_fn, init_state = build()
        p0, e0 = init_state(key)
        template = TrainState(*canonicalize_sim(sim, p0, e0), key=key,
                              data_step=jnp.zeros((), jnp.int32))
        t0 = time.perf_counter()
        state, _ = restore_train_state(ckpt_dir, template, step=mid)
        restore_s = time.perf_counter() - t0
        ef = state.ef
        if mutate is not None:
            ef = mutate(ef)
        params, ef = replicate_sim(sim, state.params, ef)
        params, _, tail = run(step_fn, params, ef, mid, steps)
        return eval_loss(params), tail, restore_s

    full_eval, full_tail, restore_s = resume()

    def drop_ef(ef):
        return ef_lib.EFState(
            error=jax.tree_util.tree_map(jnp.zeros_like, ef.error),
            momentum=ef.momentum, comp=ef.comp, step=ef.step)

    def drop_warm(ef):
        shapes = jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params_tmpl)
        comp = PowerSGDCompressor(rank=2).init(
            shapes, model_lib.mspecs(cfg), jax.random.key(999))
        return ef_lib.replace_comp(ef, comp)

    params_tmpl = jax.tree_util.tree_map(lambda x: x[0], params)
    ef_eval, ef_tail, _ = resume(drop_ef)
    warm_eval, warm_tail, _ = resume(drop_warm)

    def spike(tail):
        """Worst per-step train-loss excess over the full restore in the
        first 5 resumed steps — the re-absorption transient."""
        return round(max(a - b for a, b in
                         zip(tail[:5], full_tail[:5])), 4)

    bitexact = full_tail == ref_losses[mid:]
    return [
        {"mode": "uninterrupted", "eval_loss": round(ref_eval, 4),
         "final_loss_hex": float(ref_losses[-1]).hex()},
        {"mode": "resume_full", "eval_loss": round(full_eval, 4),
         "bitexact_vs_uninterrupted": bool(bitexact),
         "final_loss_hex": float(full_tail[-1]).hex()},
        {"mode": "resume_drop_ef", "eval_loss": round(ef_eval, 4),
         "loss_cost_vs_full": round(ef_eval - full_eval, 4),
         "post_resume_loss_spike": spike(ef_tail)},
        {"mode": "resume_drop_warm_start", "eval_loss": round(warm_eval, 4),
         "loss_cost_vs_full": round(warm_eval - full_eval, 4),
         "post_resume_loss_spike": spike(warm_tail)},
        {"mode": "checkpoint_cost",
         "workers": spec.workers, "steps": steps, "ckpt_every": ckpt_every,
         "ckpt_mb": round(save_times_bytes[0] / 1e6, 3),
         "save_ms_mean": round(1e3 * float(np.mean(save_times)), 2),
         "restore_ms": round(1e3 * restore_s, 2),
         "save_overhead_pct_of_train":
             round(100 * sum(save_times) / train_wall, 3)},
    ]


# ---------------------------------------------------------------------------
# communication model (paper Appendix B cluster: 10 Gbit/s ethernet)
# ---------------------------------------------------------------------------

BW = {"nccl_10gbit": 10e9 / 8, "gloo_10gbit": 2.5e9 / 8}
LATENCY = {"nccl_10gbit": 30e-6, "gloo_10gbit": 150e-6}


def comm_time(bytes_per_worker: float, workers: int, allreduce: bool,
              backend: str = "nccl_10gbit") -> float:
    """Seconds to aggregate one step's messages among W workers.

    ``bytes_per_worker`` is the payload ONE worker contributes; the
    all-gather branch scales it by (W−1) — every worker receives every
    other worker's payload — which is exactly the W-scaling
    :meth:`repro.core.dist.CollectiveStats.bytes_per_collective` reports for
    ``kind="gather"`` records.  Mis-modeling gather traffic as all-reduce
    (constant in W) flips speedup conclusions for sign/top-K/Atomo.
    """
    import math

    bw = BW[backend]
    lat = LATENCY[backend]
    if workers <= 1:
        return 0.0
    if allreduce:
        rounds = math.ceil(math.log2(workers))
        return 2 * (workers - 1) / workers * bytes_per_worker / bw + lat * rounds
    # all-gather: every worker receives (W−1) messages
    return (workers - 1) * bytes_per_worker / bw + lat * (workers - 1)


def broadcast_time(bytes_root: float, workers: int,
                   backend: str = "nccl_10gbit") -> float:
    """Seconds for rank 0 to broadcast ``bytes_root`` to W−1 receivers.

    Scatter + all-gather broadcast (van de Geijn): the bandwidth term is
    half an all-reduce's, the latency term the same ⌈log2 W⌉ tree depth.
    This is the extra per-aggregate leg ``sync_mode="broadcast"`` pays
    (:class:`repro.core.dist.MeshCtx`) — flat in W on the wire, which is
    exactly the ``fanout=1`` accounting ``CollectiveStats`` records for
    ``kind="broadcast"`` entries.
    """
    import math

    if workers <= 1:
        return 0.0
    rounds = math.ceil(math.log2(workers))
    return ((workers - 1) / workers * bytes_root / BW[backend]
            + LATENCY[backend] * rounds)


def comm_time_from_stats(stats, workers: int,
                         backend: str = "nccl_10gbit", *,
                         overlap_compute_s: float = 0.0) -> float:
    """Seconds of modeled gradient exchange for one recorded step.

    Walks a :class:`repro.core.dist.CollectiveStats` trace and applies the
    α-β model per collective with its *actual* wire size, itemsize and
    transport kind — reduce-pattern entries stay flat in W, gather-pattern
    entries pay the (W−1)-fold receive traffic.  This is the honest
    per-engine model: latency multiplies by the number of collectives, which
    is exactly what the fused transport engine minimizes.

    ``overlap_compute_s`` models a pipelined (``staleness="one_step"``)
    schedule where the exchange runs concurrently with the next step's
    compute (e.g. :meth:`repro.launch.roofline.Roofline.compute_s`): the
    return value becomes the *exposed* comm, ``max(0, total − overlap)`` —
    the only part that lengthens the critical path.
    """
    total = 0.0
    overheads = list(getattr(stats, "overheads", ()) or ())
    overheads += [0] * (len(stats.sizes) - len(overheads))
    for size, itemsize, kind, overhead in zip(stats.sizes, stats.itemsizes,
                                              stats.kinds, overheads):
        nbytes = size * itemsize + overhead  # fractional int4 + scale sidecar
        if kind == "broadcast":
            total += broadcast_time(nbytes, workers, backend)
        else:
            total += comm_time(nbytes, workers, kind == "reduce", backend)
    return max(0.0, total - overlap_compute_s)


def measure_coding_time(compressor: Compressor, params, specs,
                        iters: int = 5) -> float:
    """Measured compress+decompress wall time per step on this host."""
    key = jax.random.key(0)
    shapes = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
    state = compressor.init(shapes, specs, key)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.ones_like(p) * 0.01, params)

    stepf = jax.jit(lambda g, s, k: compressor.step(g, s, specs, key=k).agg)
    out = stepf(grads, state, key)
    jax.block_until_ready(out)
    t0 = time.time()
    for i in range(iters):
        out = stepf(grads, state, jax.random.fold_in(key, i))
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def bytes_per_epoch_mb(bits_per_step: int, steps_per_epoch: int) -> float:
    return bits_per_step / 8 / 1e6 * steps_per_epoch
