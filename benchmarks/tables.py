"""One benchmark per paper table/figure (DESIGN.md §6 index).

Each function returns a list of row-dicts; ``benchmarks.run`` prints them as
CSV and writes them under experiments/benchmarks/.
"""

from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (LMSpec, bytes_per_epoch_mb, comm_time,
                               measure_coding_time, train_lm)
from repro.core.compressors import make_compressor

STEPS_PER_EPOCH = 40  # epoch definition for the synthetic task


def _fmt(result, rank=None, backend="nccl_10gbit", workers=16):
    mb = bytes_per_epoch_mb(result["bits_per_worker_per_step"], STEPS_PER_EPOCH)
    ct = comm_time(result["bits_per_worker_per_step"] / 8, workers,
                   result["allreduce"], backend)
    return {
        "algorithm": result["compressor"] + (f"_rank{rank}" if rank else ""),
        "eval_loss": round(result["eval_loss"], 4),
        "data_per_epoch_mb": round(mb, 3),
        "allreduce": result["allreduce"],
        "modeled_comm_ms_w16": round(ct * 1e3, 3),
    }


def table1_error_feedback(spec: LMSpec) -> list:
    """Table 1: biased rank-r + EF vs the unbiased rank-r operator."""
    rows = []
    rows.append(_fmt(train_lm(make_compressor("identity"), spec)))
    for r in (1, 2):
        rows.append(_fmt(train_lm(make_compressor("powersgd", rank=r), spec), r))
    for r in (1, 2):
        rows.append(_fmt(train_lm(make_compressor("unbiased_rank_k", rank=r), spec), r))
    return rows


def table2_warm_start(spec: LMSpec) -> list:
    """Table 2: warm start vs cold start vs best rank-r approximation."""
    rows = []
    rows.append(_fmt(train_lm(make_compressor("powersgd_best_approx", rank=2), spec), 2))
    rows.append(_fmt(train_lm(make_compressor("powersgd", rank=2), spec), 2))
    rows.append(_fmt(train_lm(make_compressor("powersgd_cold", rank=2), spec), 2))
    return rows


def table3_rank_sweep(spec: LMSpec) -> list:
    """Table 3: quality/compression trade-off over rank."""
    rows = [_fmt(train_lm(make_compressor("identity"), spec))]
    for r in (1, 2, 4):
        rows.append(_fmt(train_lm(make_compressor("powersgd", rank=r), spec), r))
    return rows


def table4_compressor_zoo(spec: LMSpec) -> list:
    """Table 4: the EF compressor zoo at medium (r=7-equivalent budget) and
    high (r=2) compression."""
    rows = []
    rows.append(_fmt(train_lm(make_compressor("identity"), spec)))
    for regime, r in (("medium", 7), ("high", 2)):
        for name in ("powersgd", "random_block", "random_k", "sign_norm", "top_k"):
            # sign+norm has a fixed ~32× rate (paper): only in medium regime
            if name == "sign_norm" and regime == "high":
                continue
            res = train_lm(make_compressor(name, rank=r), spec)
            row = _fmt(res, r)
            row["regime"] = regime
            rows.append(row)
    return rows


def table5_time_breakdown(params, specs) -> list:
    """Table 5: per-step time breakdown vs number of workers.

    fwd/bwd is constant (measured once); coding time is measured per
    compressor; gradient exchange is modeled (all-reduce vs all-gather) —
    the paper's observation is the *scaling shape*: all-gather decode cost
    grows linearly in W, all-reduce stays flat."""
    rows = []
    total_bits = sum(int(np.prod(p.shape)) * 32
                     for p in jax.tree_util.tree_leaves(params))
    for name, rank in (("identity", None), ("powersgd", 2), ("sign_norm", None)):
        comp = make_compressor(name, rank=rank or 2)
        coding = measure_coding_time(comp, params, specs)
        key = jax.random.key(0)
        shapes = jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
        state = comp.init(shapes, specs, key)
        probe = comp.step(jax.tree_util.tree_map(jnp.zeros_like, params),
                          state, specs, key=key)
        for w in (2, 4, 8, 16):
            exch = comm_time(probe.bits_per_worker / 8, w, comp.allreduce)
            decode_scale = 1 if comp.allreduce else w
            rows.append({
                "algorithm": name,
                "workers": w,
                "coding_ms": round(coding * 1e3 * decode_scale, 3),
                "exchange_ms": round(exch * 1e3, 3),
                "bits_per_worker": probe.bits_per_worker,
                "allreduce": comp.allreduce,
            })
    return rows


def table6_other_methods(spec: LMSpec) -> list:
    """Table 6: PowerSGD vs Spectral Atomo vs Signum."""
    rows = [_fmt(train_lm(make_compressor("identity"), spec))]
    rows.append(_fmt(train_lm(make_compressor("spectral_atomo", rank=2), spec), 2))
    rows.append(_signum_row(spec))
    rows.append(_fmt(train_lm(make_compressor("powersgd", rank=2), spec), 2))
    return rows


def _signum_row(spec: LMSpec) -> dict:
    """Signum is an optimizer, not an EF compressor — run it natively."""
    from repro.core.dist import SINGLE
    from repro.data.synthetic import MarkovLM
    from repro.models import model as model_lib
    from repro.optim import signum_apply, signum_init
    from benchmarks.common import _make_cfg

    cfg = _make_cfg(spec)
    key = jax.random.key(spec.seed)
    params = model_lib.init(key, cfg, model_shards=1)
    st = signum_init(params)
    data = MarkovLM(vocab=spec.vocab, seed=spec.seed, order=spec.order,
                    clusters=spec.clusters)
    it = data.batches(spec.batch_per_worker * spec.workers, spec.seq)

    @jax.jit
    def step(params, st, batch):
        def loss_fn(p):
            return model_lib.loss_fn(p, batch, cfg, SINGLE, q_chunk=32,
                                     remat=False)

        grads, m = jax.grad(loss_fn, has_aux=True)(params)
        p2, st2 = signum_apply(params, grads, st, lr=spec.lr * 1e-3)
        return p2, st2, m["lm_loss"]

    for _ in range(spec.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, st, loss = step(params, st, batch)

    @jax.jit
    def eval_loss(params, batch):
        l, _ = model_lib.loss_fn(params, batch, cfg, SINGLE, q_chunk=32,
                                 remat=False)
        return l

    evs = []
    for i in range(8):
        b = data.sample(32, spec.seq, step=10_000 + i)
        evs.append(float(eval_loss(params, {"tokens": jnp.asarray(b[:, :-1]),
                                            "labels": jnp.asarray(b[:, 1:])})))
    nparams = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    bits = nparams  # 1 bit per coordinate
    return {
        "algorithm": "signum",
        "eval_loss": round(float(np.mean(evs)), 4),
        "data_per_epoch_mb": round(bytes_per_epoch_mb(bits, STEPS_PER_EPOCH), 3),
        "allreduce": False,
        "modeled_comm_ms_w16": round(
            comm_time(bits / 8, 16, False) * 1e3, 3),
    }


def table7_lstm(spec_steps: int = 120) -> list:
    """Table 7: language modeling with the paper's LSTM (scaled down)."""
    from repro.core import error_feedback as ef_lib
    from repro.data.synthetic import MarkovLM
    from repro.models import lstm

    cfg = lstm.LSTMConfig(vocab=256, embed=64, hidden=64, layers=3,
                          init_scale=0.15)
    key = jax.random.key(0)
    data = MarkovLM(vocab=cfg.vocab, seed=0, order=1, clusters=8)

    def run(comp_name, rank):
        params = lstm.init(key, cfg)
        specs = lstm.mspecs(params)
        comp = make_compressor(comp_name, rank=rank)
        state = ef_lib.init_state(comp, params, specs, key)
        it = data.batches(16, 48)

        @jax.jit
        def gradf(p, batch):
            return jax.grad(lstm.loss_fn, has_aux=True)(p, batch, cfg)

        for i in range(spec_steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            grads, met = gradf(params, batch)
            params, state, aux = ef_lib.apply_updates(
                comp, params, grads, state, specs, lr=1.0, momentum=0.9,
                key=key)
        evs = []
        for i in range(6):
            b = data.sample(32, 48, step=20_000 + i)
            _, met = lstm.loss_fn(params, {"tokens": jnp.asarray(b[:, :-1]),
                                           "labels": jnp.asarray(b[:, 1:])}, cfg)
            evs.append(float(met["loss"]))
        ev = float(np.mean(evs))
        return {
            "algorithm": f"{comp_name}" + (f"_rank{rank}" if comp_name != "identity" else ""),
            "eval_ppl": round(math.exp(ev), 2),
            "data_per_epoch_mb": round(
                bytes_per_epoch_mb(aux["bits_per_worker"], STEPS_PER_EPOCH), 3),
        }

    return [run("identity", 2), run("powersgd", 1), run("powersgd", 4)]


def adaptive_rank_profile(spec: LMSpec) -> list:
    """Beyond-paper: adaptive rank schedules vs the paper's fixed rank.

    Trains the benchmark LM under (a) fixed ranks 1/2/4, (b) a PowerSGD+-
    style *growth* staircase 1→2→4 — low rank through the noisy early
    phase, full rank only once gradient structure is worth the bits; the
    measured winner: ~42% fewer cumulative compressed floats at equal-or-
    better final loss than fixed rank-4 — (c) the *decay* staircase 4→2→1
    as the honest contrast (a mid-run rank drop injects reconstruction
    error the remaining steps cannot re-absorb at a fixed horizon, so it
    trades loss for bits), (d) the residual-energy-driven policy, and (e)
    a run at the α-β autotuner's per-bucket rank assignment under a
    50%-of-rank-4 bits budget.  The claim the table demonstrates (ISSUE 4
    acceptance): an adaptive schedule sends ≥25% fewer cumulative
    compressed floats than fixed rank-4 at equal-or-better final loss.
    """
    from repro.core import autotune
    from repro.core import powersgd as ps_lib
    from repro.core.compressors import PowerSGDCompressor
    from repro.models import model as model_lib
    from benchmarks.common import _make_cfg

    s = spec.steps

    def row(label, result, extra=None):
        r = {
            "schedule": label,
            "eval_loss": round(result["eval_loss"], 4),
            "compressed_mfloats_total":
                round(result["compressed_floats_total"] / 1e6, 4),
        }
        if "rank_history" in result:
            r["rank_history"] = "|".join(
                f"{rk}@{st}" for st, rk in result["rank_history"])
        r.update(extra or {})
        return r

    rows = []
    fixed = {}
    for r in (1, 2, 4):
        res = train_lm(make_compressor("powersgd", rank=r), spec)
        fixed[r] = res
        rows.append(row(f"fixed_rank{r}", res))
    base_floats = fixed[4]["compressed_floats_total"]

    # (b) growth staircase: 1 for the first third, 2 for the second, 4
    # after — cumulative floats = (1+2+4)/12 ≈ 58% of fixed rank-4
    for label, stair in (
            ("staircase_up_1_2_4", ps_lib.StaircaseRank(
                milestones=((0, 1), (s // 3, 2), (2 * s // 3, 4)))),
            ("staircase_down_4_2_1", ps_lib.StaircaseRank(
                milestones=((0, 4), (s // 3, 2), (2 * s // 3, 1))))):
        comp = PowerSGDCompressor(rank_schedule=stair)
        res = train_lm(comp, spec, controller=comp.controller())
        rows.append(row(label, res, {
            "savings_vs_fixed_rank4": round(
                1 - res["compressed_floats_total"] / base_floats, 4)}))

    # (d) residual-energy-driven: shrinks when the tracked subspace already
    # covers the gradient, grows when too much energy is left behind
    comp = PowerSGDCompressor(
        rank_schedule=f"residual:min=1,max=8,init=4,every={max(s // 8, 1)}")
    res = train_lm(comp, spec, controller=comp.controller())
    rows.append(row("residual_energy", res, {
        "savings_vs_fixed_rank4": round(
            1 - res["compressed_floats_total"] / base_floats, 4)}))

    # (e) α-β autotuned per-bucket ranks under a 50%-of-rank-4 bits budget
    cfg = _make_cfg(spec)
    params = model_lib.init(jax.random.key(spec.seed), cfg, 1)
    shapes = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
    mspecs = model_lib.mspecs(cfg)
    comp4 = ps_lib.compressed_floats_total(shapes, mspecs, 4)
    plan = autotune.autotune(
        shapes, mspecs, bits_budget=comp4 * 32 // 2,
        workers=spec.workers, hw=autotune.HardwareModel.from_backend(
            "nccl_10gbit"))
    comp = autotune.make_tuned_compressor(plan)
    key = jax.random.key(spec.seed)
    res = train_lm(comp, spec, init_comp_transform=lambda cs:
                   autotune.apply_plan(plan, cs, shapes, mspecs, key))
    rows.append(row("autotuned_budget50", res, {
        "savings_vs_fixed_rank4": round(
            1 - res["compressed_floats_total"] / base_floats, 4),
        "bucket_ranks": "|".join(
            f"{d.n}x{d.m}:r{d.rank}" for d in plan.decisions),
        "wire_dtype": plan.wire_dtype,
        "predicted_comm_ms": round(plan.predicted_comm_s * 1e3, 3)}))
    return rows


def resume_overhead(spec: LMSpec, ckpt_every: int = 20) -> list:
    """Beyond-paper: full-state checkpoint cost + resume ablations.

    Systems studies of compressed training treat resumability and its
    accounting as table stakes; this table records what ours costs — the
    envelope size, save/restore wall time and the save overhead at a
    ``ckpt_every`` cadence — and demonstrates the two claims the docs
    quote: a full-state resume is *bit-exact* (identical per-step losses
    through the horizon), while dropping the EF buffers or re-randomizing
    the warm-start factors on restore (the state a params-only checkpoint
    silently loses) measurably costs final loss.  See
    ``benchmarks.common.resume_profile``."""
    import tempfile

    from benchmarks.common import resume_profile

    with tempfile.TemporaryDirectory() as d:
        return resume_profile(spec, d, ckpt_every=ckpt_every)


def comm_profile(params, specs) -> list:
    """Beyond-paper: the bucketed engine's communication profile.

    Counts the data-axis collectives one PowerSGD step issues and the bytes
    each one carries, per-leaf vs bucketed — the latency-vs-bandwidth trade
    the bucketing engine makes (2 flat collectives per step instead of 2 per
    weight matrix)."""
    from repro.core.compressors import PowerSGDCompressor
    from repro.core.dist import CollectiveStats, MeshCtx

    key = jax.random.key(0)
    shapes = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
    grads = jax.tree_util.tree_map(jnp.zeros_like, params)
    rows = []
    for mode, label in (("off", "per_leaf"), ("auto", "bucketed")):
        comp = PowerSGDCompressor(rank=2, bucketing=mode)
        stats = CollectiveStats()
        comp.step(grads, comp.init(shapes, specs, key), specs,
                  ctx=MeshCtx(stats=stats), key=key)
        sizes_b = stats.bytes_per_collective()
        rows.append({
            "engine": label,
            "collectives_per_step": stats.data_collectives,
            "total_mb_per_step": round(sum(sizes_b) / 2**20, 4),
            "mean_bytes_per_collective": int(np.mean(sizes_b)) if sizes_b else 0,
            "max_bytes_per_collective": max(sizes_b) if sizes_b else 0,
            "min_bytes_per_collective": min(sizes_b) if sizes_b else 0,
        })
    return rows


def zoo_transport_profile(params, specs, workers: int = 16) -> list:
    """Beyond-paper: the transport engine's profile for the WHOLE zoo.

    For every compressor in the registry: how many fused data-axis
    collectives one step issues, split reduce vs gather, the wire bytes each
    pattern carries (gather scaled by W — the traffic a worker's NIC
    actually sees), and the modeled exchange time per step.  This is the
    table that shows the paper's §3 argument end-to-end: linear schemes ride
    O(1) flat all-reduces whose cost is flat in W; non-linear schemes pay a
    genuine W-scaled all-gather.

    ISSUE 9 arm: the same trace under quantized wire policies.  For each
    ``wire_dtype`` in float32 / int8 / int4 the byte sums include the
    fractional int4 itemsize and the per-slot f32 scale sidecar
    (``CollectiveStats.overheads``), and the powersgd rows carry a measured
    SimMesh final loss so the bytes-vs-quality trade is pinned by data, not
    asserted: int4 moves ≥4x fewer wire bytes than float32 at a final loss
    within the tolerance tests/test_docs.py pins from this JSON.
    """
    from benchmarks.common import comm_time_from_stats
    from repro.core.compressors import make_compressor
    from repro.core.dist import CollectiveStats, MeshCtx

    zoo = ("identity", "powersgd", "powersgd_per_leaf", "unbiased_rank_k",
           "random_block", "random_k", "sign_norm", "top_k", "spectral_atomo",
           "exact_rank_k")
    key = jax.random.key(0)
    shapes = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.ones_like(p) * 0.01, params)

    def trace_row(name: str, wire_dtype: str) -> dict:
        kw = {} if wire_dtype == "auto" else {"wire_dtype": wire_dtype}
        comp = make_compressor(name, rank=2, **kw)
        stats = CollectiveStats()
        out = comp.step(grads, comp.init(shapes, specs, key), specs,
                        ctx=MeshCtx(stats=stats), key=key)
        overheads = list(getattr(stats, "overheads", ()) or ())
        overheads += [0] * (len(stats.sizes) - len(overheads))
        reduce_b = sum(s * i + o for s, i, k, o in
                       zip(stats.sizes, stats.itemsizes, stats.kinds,
                           overheads) if k == "reduce")
        gather_b = sum(s * i + o for s, i, k, o in
                       zip(stats.sizes, stats.itemsizes, stats.kinds,
                           overheads) if k == "gather")
        return {
            "algorithm": name,
            "wire_dtype": wire_dtype,
            "wire_mode": getattr(comp, "wire_mode", "reduce"),
            "collectives_per_step": stats.data_collectives,
            "reduce_collectives": stats.reduce_collectives,
            "gather_collectives": stats.gather_collectives,
            "reduce_kb_per_step": round(reduce_b / 1024, 2),
            "gather_kb_per_step_w%d" % workers:
                round(gather_b * workers / 1024, 2),
            "payload_bits_per_worker": int(out.bits_per_worker),
            "modeled_comm_ms_w%d" % workers:
                round(comm_time_from_stats(stats, workers) * 1e3, 3),
        }

    rows = [trace_row(name, "auto") for name in zoo]

    # Quantized-wire arm: the acceptance scheme (powersgd) plus one gather
    # scheme per combine path, traced under every wire policy.  float32 is
    # the explicit baseline the compression ratios are quoted against.
    quant_zoo = ("powersgd", "sign_norm", "top_k")
    loss_steps = 60
    for name in quant_zoo:
        base_kb = None
        for wd in ("float32", "int8", "int4"):
            row = trace_row(name, wd)
            wire_kb = (row["reduce_kb_per_step"]
                       + row["gather_kb_per_step_w%d" % workers])
            if wd == "float32":
                base_kb = wire_kb
            row["wire_bytes_ratio_vs_float32"] = round(base_kb / wire_kb, 2)
            if name == "powersgd":
                losses = _wire_loss_run(wd, workers=4, steps=loss_steps)
                row["loss_workers"] = 4
                row["loss_steps"] = loss_steps
                row["final5_loss"] = round(float(np.mean(losses[-5:])), 4)
            rows.append(row)
    return rows


def _wire_loss_run(wire_dtype: str, workers: int, steps: int) -> list:
    """Per-step aggregated lm_loss for the production sim train step under
    ``wire_dtype`` — the measured arm of :func:`zoo_transport_profile`."""
    from repro.configs.base import get_config
    from repro.core.simmesh import SimMesh
    from repro.data.synthetic import MarkovLM
    from repro.launch.train import TrainHyper, make_sim_train_step

    cfg = get_config("llama3-8b", reduced=True)
    hyper = TrainHyper(lr=0.05, q_chunk=32, warmup_steps=5, remat=False,
                       wire_dtype=wire_dtype)
    sim = SimMesh(workers)
    step_fn, init_state = make_sim_train_step(cfg, sim, hyper)
    data = MarkovLM(vocab=cfg.vocab_size, seed=0, order=1, clusters=8)
    it = data.batches(8, 64)
    key = jax.random.key(0)
    params, ef = init_state(key)
    losses = []
    for i in range(steps):
        b = sim.shard({k: jnp.asarray(v) for k, v in next(it).items()})
        params, ef, met = step_fn(params, ef, b, key)
        losses.append(float(met["lm_loss"][0]))
    return losses


_SYNC_MEASURE_SRC = '''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import sys
import time
sys.path.insert(0, @SRC@)
import jax
import jax.numpy as jnp
from repro.configs.base import get_config
from repro.data.synthetic import MarkovLM
from repro.launch.train import TrainHyper, make_train_step
out = {}
for mode in ("allreduce", "broadcast"):
    cfg = get_config("llama3-8b", reduced=True)
    hyper = TrainHyper(lr=0.05, rank=2, q_chunk=64, warmup_steps=20,
                       remat=False, sync_mode=mode)
    mesh = jax.make_mesh((4, 1), ("data", "model"))
    step_fn, _, init_state = make_train_step(cfg, mesh, hyper)
    data = MarkovLM(vocab=cfg.vocab_size, seed=0)
    with jax.set_mesh(mesh):
        params, ef = init_state(jax.random.key(0))
        times = []
        for i in range(10):
            toks = data.sample(8, 64, step=i)
            batch = {"tokens": jnp.asarray(toks[:, :-1]),
                     "labels": jnp.asarray(toks[:, 1:].copy())}
            t0 = time.time()
            params, ef, met = step_fn(params, ef, batch, jax.random.key(1))
            jax.block_until_ready(met["lm_loss"])
            times.append(time.time() - t0)
    out[mode] = sum(times[3:]) / len(times[3:])
print("SYNC_MEASURE_JSON=" + json.dumps(out))
'''


def sync_mode_profile(params, specs, workers: int = 16) -> list:
    """Beyond-paper: what replica-deterministic aggregation costs.

    For each :class:`repro.core.dist.MeshCtx` ``sync_mode``, the fused
    PowerSGD transport trace on a W=4 substrate (reduce vs broadcast
    collectives and their wire bytes), the α-β modeled exchange time at
    ``workers``, and the *measured* train-step time on a real 4-device
    data-parallel ``shard_map`` mesh — the production backend the drift
    suite (tests/sim/test_drift.py) certifies, run in a subprocess with
    faked host devices.  Broadcast mode pays one extra fused rank-0
    broadcast per step: bytes flat in W (``CollectiveStats`` records it
    with fanout 1), ⌈log2 W⌉ extra latency rounds — the overhead column
    quantifies exactly that in the α-β model.
    """
    import json
    import subprocess
    import sys as _sys

    from benchmarks.common import comm_time_from_stats
    from repro.core.compressors import make_compressor
    from repro.core.dist import CollectiveStats
    from repro.core.simmesh import SimMesh

    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run(
        [_sys.executable, "-c",
         _SYNC_MEASURE_SRC.replace("@SRC@", repr(src))],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    measured = {}
    for line in proc.stdout.splitlines():
        if line.startswith("SYNC_MEASURE_JSON="):
            measured = json.loads(line.split("=", 1)[1])
    if not measured:
        print(f"sync_mode_profile: mesh measurement failed\n{proc.stderr}",
              file=_sys.stderr)

    key = jax.random.key(0)
    shapes = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.ones_like(p) * 0.01, params)
    sim = SimMesh(4, axis="dp")
    rows = []
    for mode in ("allreduce", "broadcast"):
        comp = make_compressor("powersgd", rank=2)
        stats = CollectiveStats()
        state = comp.init(shapes, specs, key)

        def step(g, s):
            ctx = sim.ctx(stats=stats, sync_mode=mode)
            return comp.step(g, s, specs, ctx=ctx, key=key).agg

        sim.run(step, in_axes=(0, 0))(sim.replicate(grads),
                                      sim.replicate(state))
        reduce_b = sum(s * i for s, i, k in zip(stats.sizes, stats.itemsizes,
                                                stats.kinds) if k == "reduce")
        bcast_b = sum(s * i for s, i, k in zip(stats.sizes, stats.itemsizes,
                                               stats.kinds)
                      if k == "broadcast")
        rows.append({
            "sync_mode": mode,
            "reduce_collectives": stats.reduce_collectives,
            "broadcast_collectives": stats.broadcast_collectives,
            "reduce_kb_per_step": round(reduce_b / 1024, 2),
            "broadcast_kb_per_step": round(bcast_b / 1024, 2),
            "modeled_comm_ms_w%d" % workers:
                round(comm_time_from_stats(stats, workers) * 1e3, 3),
            "measured_step_ms_mesh4x1":
                round(measured[mode] * 1e3, 2) if mode in measured else None,
        })
    base = rows[0]["modeled_comm_ms_w%d" % workers]
    for row in rows:
        row["modeled_overhead_pct_w%d" % workers] = round(
            100.0 * (row["modeled_comm_ms_w%d" % workers] - base) / base, 2)
    return rows


def _stale_loss_run(staleness: str, workers: int, steps: int,
                    weights_for_step=None) -> list:
    """Per-step aggregated lm_loss for the production sim train step under
    ``staleness`` — the measured arm of :func:`overlap_profile`."""
    from repro.configs.base import get_config
    from repro.core.simmesh import SimMesh
    from repro.data.synthetic import MarkovLM
    from repro.launch.train import TrainHyper, make_sim_train_step

    cfg = get_config("llama3-8b", reduced=True)
    # Shared operating point where BOTH arms are stable: a one-step delay
    # halves the heavy-ball stability region (the update x ← x − γ(Δ'+m)
    # carries an effective (2−λ)/(1−λ)·γ steady-state step, ~11γ at λ=0.9,
    # and delayed feedback at that gain oscillates), so the comparison runs
    # momentum-free at a moderate lr — see docs/tuning.md "staleness".
    hyper = TrainHyper(lr=0.05, momentum=0.0, q_chunk=32, warmup_steps=5,
                       remat=False, weight_decay=0.0, staleness=staleness)
    sim = SimMesh(workers)
    step_fn, init_state = make_sim_train_step(cfg, sim, hyper)
    data = MarkovLM(vocab=cfg.vocab_size, seed=0, order=1, clusters=8)
    it = data.batches(8, 64)
    key = jax.random.key(0)
    params, ef = init_state(key)
    losses = []
    for i in range(steps):
        b = sim.shard({k: jnp.asarray(v) for k, v in next(it).items()})
        w = weights_for_step(i) if weights_for_step is not None else None
        params, ef, met = step_fn(params, ef, b, key, w)
        losses.append(float(met["lm_loss"][0]))
    return losses


def overlap_profile(params, specs, steps: int = 80) -> list:
    """ISSUE 8: what the one-step-stale pipeline buys and what it costs.

    Modeled arm — the fused PowerSGD rank-2 wire trace priced with the α-β
    model per backend and worker count.  The synchronous step serializes
    compute then exchange; the pipelined (``staleness="one_step"``) step
    overlaps the exchange with the *next* step's compute, so only the
    exposed remainder (``comm_time_from_stats(..., overlap_compute_s=...)``)
    lengthens the critical path.  ``hidden_comm_pct`` is the acceptance
    metric: the fraction of modeled comm taken off the critical path at the
    paper's 10 Gbit/s ethernet operating point.

    Measured arm — final SimMesh loss of the production train step, stale
    vs synchronous, on a clean run and under the dropout / straggler
    scenarios of tests/sim/test_scenarios.py: EF absorbs the one-step
    staleness, so quality must match within noise while the wire schedule
    (identical CollectiveStats — tests/test_engine.py) becomes overlappable.
    """
    from benchmarks.common import comm_time_from_stats
    from repro.core.compressors import PowerSGDCompressor
    from repro.core.dist import CollectiveStats, MeshCtx

    key = jax.random.key(0)
    shapes = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.ones_like(p) * 0.01, params)
    comp = PowerSGDCompressor(rank=2, pipeline=True)
    stats = CollectiveStats()
    comp.step(grads, comp.init(shapes, specs, key), specs,
              ctx=MeshCtx(stats=stats), key=key)

    compute_ms = 20.0  # nominal constant fwd+bwd per batch (as fig3_scaling)
    rows = []
    for backend in ("nccl_10gbit", "gloo_10gbit"):
        for w in (1, 4, 8):
            comm_s = comm_time_from_stats(stats, w, backend)
            exposed_s = comm_time_from_stats(
                stats, w, backend, overlap_compute_s=compute_ms / 1e3)
            sync_ms = compute_ms + comm_s * 1e3
            stale_ms = compute_ms + exposed_s * 1e3
            rows.append({
                "arm": "modeled", "backend": backend, "workers": w,
                "modeled_comm_ms": round(comm_s * 1e3, 3),
                "exposed_comm_ms": round(exposed_s * 1e3, 3),
                "sync_step_ms": round(sync_ms, 3),
                "stale_step_ms": round(stale_ms, 3),
                "hidden_comm_pct": round(
                    100.0 * (comm_s - exposed_s) / comm_s, 2)
                    if comm_s > 0 else 100.0,
                "step_speedup_pct": round(
                    100.0 * (sync_ms - stale_ms) / sync_ms, 2),
            })

    W = 4

    def drop_rotating(step):
        w = np.ones((W,), np.float32)
        w[step % W] = 0.0
        return w

    def straggler(step):
        w = np.ones((W,), np.float32)
        if step % 2 == 1:
            w[3] = 0.0
        return w

    for scenario, weights in (("clean", None), ("dropout", drop_rotating),
                              ("straggler", straggler)):
        final = {}
        for staleness in ("none", "one_step"):
            losses = _stale_loss_run(staleness, W, steps, weights)
            final[staleness] = float(np.mean(losses[-5:]))
            rows.append({
                "arm": "measured_simmesh", "scenario": scenario,
                "staleness": staleness, "workers": W, "steps": steps,
                "first5_loss": round(float(np.mean(losses[:5])), 4),
                "final5_loss": round(final[staleness], 4),
            })
        rows[-1]["stale_minus_sync_final_loss"] = round(
            final["one_step"] - final["none"], 4)
    return rows


def fig3_scaling(params, specs) -> list:
    """Fig. 3: modeled epoch time vs workers for both backends.

    fwd/bwd per step is measured once on this host and held constant; the
    communication term uses the α-β model — reproducing the paper's scaling
    *shape* (PowerSGD ≈ flat, gather-based methods degrade)."""
    rows = []
    total_bits = sum(int(np.prod(p.shape)) * 32
                     for p in jax.tree_util.tree_leaves(params))
    compute_ms = 20.0  # nominal constant fwd+bwd per batch
    for backend in ("nccl_10gbit", "gloo_10gbit"):
        for name, rank, bits, allreduce in (
                ("sgd", None, total_bits, True),
                ("powersgd_rank2", 2, None, True),
                ("signum", None, total_bits // 32, False)):
            if bits is None:
                comp = make_compressor("powersgd", rank=2)
                key = jax.random.key(0)
                shapes = jax.tree_util.tree_map(
                    lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
                probe = comp.step(
                    jax.tree_util.tree_map(jnp.zeros_like, params),
                    comp.init(shapes, specs, key), specs, key=key)
                bits = probe.bits_per_worker
            for w in (1, 2, 4, 8, 16, 32):
                t = compute_ms + comm_time(bits / 8, w, allreduce, backend) * 1e3
                rows.append({
                    "backend": backend, "algorithm": name, "workers": w,
                    "modeled_step_ms": round(t, 3),
                    "speedup_vs_1worker": round(w * compute_ms / t, 3),
                })
    return rows


def appendixD_transformer(spec: LMSpec) -> list:
    """Appendix D: language modeling with a *transformer* — PowerSGD rank
    sweep on the benchmark transformer LM (the paper needed rank 32 on
    WikiText-103; at our scale lower ranks already close the gap, but the
    monotone rank→quality trend and the compression ratios are the claim)."""
    rows = [_fmt(train_lm(make_compressor("identity"), spec))]
    for r in (4, 8, 16, 32):
        rows.append(_fmt(train_lm(make_compressor("powersgd", rank=r), spec), r))
    return rows
