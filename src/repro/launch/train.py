"""Distributed training step: one ``shard_map`` over the full mesh with
manual Megatron-style TP collectives and PowerSGD gradient aggregation over
the data axes (the paper's Algorithm 1+2, composed with tensor parallelism).

Also provides a CLI driver (``python -m repro.launch.train``) that trains a
reduced model end-to-end on the host devices, with full-state fault-tolerant
checkpointing: ``--ckpt-every`` writes periodic
:class:`repro.checkpoint.TrainState` envelopes (params, EF buffers,
warm-start factors, rank controller, PRNG stream, data cursor) and
``--resume`` continues a killed run bit-exactly (``docs/checkpoint.md``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import error_feedback, matrixize
from repro.core.compressors import Compressor, PowerSGDCompressor
from repro.core.dist import MeshCtx
from repro.core.error_feedback import EFState
from repro.configs.base import InputShape, ModelConfig
from repro.models import model
from repro.launch import mesh as mesh_lib
from repro.launch import specs as specs_lib


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    lr: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 1e-4
    warmup_steps: int = 200
    rank: int = 2
    q_chunk: int = 512
    window: int = 0                 # sliding-window attention (0 = full)
    remat: bool = True
    unroll: int = 1                 # scan unroll (dry-run cost accounting)
    orthogonalizer: str = "gram_schmidt"
    use_pallas: bool = False
    bucketing: str = "auto"         # "auto"/"on" = batched engine, "off" = per-leaf
    wire_dtype: str = "auto"        # fused-collective wire policy
    #                                 ("auto"|"float32"|"bfloat16"|"int8"|"int4")
    start_compress_step: int = 0    # dense warmup steps before compression kicks in
    rank_schedule: Optional[str] = None  # adaptive-rank spec ("4@0,2@60",
    #   "residual:min=1,max=8", ...; see repro.core.powersgd.parse_schedule).
    #   The schedule is *driven by the host loop* (rank = factor shape, so a
    #   switch retraces the jitted step): build a RankController from the
    #   compressor and transition ef.comp between steps — see main() below.
    track_residual: bool = False    # emit residual_ratio in the step metrics
    staleness: str = "none"         # "one_step" = delayed-parameter-update
    #   pipeline (ISSUE 8): apply step t−1's aggregated update while step t's
    #   gradients are computed, the in-flight aggregate carried in
    #   EFState.inflight and the engine on the double-buffered
    #   PipelinedTransport; error feedback absorbs the one-step delay.
    #   "none" (default) is the synchronous path, bit-identical to pre-ISSUE-8.
    sync_mode: str = "allreduce"    # "broadcast" = replica-deterministic
    #   data-axis aggregation (canonical reduction order + rank-0 broadcast;
    #   see repro.core.dist.MeshCtx.sync_mode) — bit-identical replicas on
    #   substrates whose all-reduce is rank-dependent at ULP level
    track_drift: bool = False       # emit drift_{params,momentum,error,q}
    #   metrics: max abs cross-data-rank divergence of the step's outputs
    tp_grad_sync: bool = True       # model-axis psum on backward cotangents
    #   at replicated→sharded boundaries (common.grad_synced).  False is a
    #   debug switch reproducing the legacy per-rank partial gradients whose
    #   cross-model drift docs/checkpoint.md once misread as all-reduce
    #   nondeterminism — pinned by tests/sim/test_drift.py.


def _schedule(hyper: TrainHyper, step):
    from repro.optim import schedules

    return schedules.linear_warmup(step, hyper.lr, hyper.warmup_steps, 0.1)


def replica_drift(ctx: MeshCtx, tree) -> jax.Array:
    """Max abs divergence of ``tree``'s float leaves across the data ranks.

    The drift probe behind ``TrainHyper.track_drift``: every rank compares
    its copy against rank 0's (delivered by the backend's masked-psum
    broadcast — called on the backend directly, so the probe never perturbs
    :class:`~repro.core.dist.CollectiveStats` budgets) and the worst
    divergence is ``pmax``-reduced back to every rank.  Exactly ``0.0``
    certifies bit-identical replicas for these leaves this step; under
    ``sync_mode="allreduce"`` on rank-dependent substrates it exposes the
    ULP-seeded divergence documented in ``docs/checkpoint.md``.  Works
    unchanged under ``shard_map`` and SimMesh.  Observability only.
    """
    drifts = []
    idx = ctx.data_index()
    for x in jax.tree_util.tree_leaves(tree):
        if not jnp.issubdtype(x.dtype, jnp.floating):
            continue
        x = x.astype(jnp.float32)
        ref = ctx.backend.broadcast0(x, ctx.data_axes, idx)
        drifts.append(jnp.max(jnp.abs(x - ref)))
    if not drifts:
        return jnp.zeros((), jnp.float32)
    return ctx.backend.pmax(jnp.max(jnp.stack(drifts)), ctx.data_axes)


def make_train_step(cfg: ModelConfig, mesh, hyper: TrainHyper,
                    compressor: Optional[Compressor] = None):
    """Returns (jitted_step, abstract_state_fn).

    jitted_step(params, ef_state, batch, key) → (params, ef_state, metrics)
    """
    dp_axes = mesh_lib.data_axes(mesh)
    maxis = mesh_lib.model_axis(mesh)
    model_shards = mesh.shape[maxis]
    ctx = MeshCtx(data_axes=dp_axes, model_axis=maxis,
                  sync_mode=hyper.sync_mode,
                  tp_grad_sync=hyper.tp_grad_sync)
    all_axes = tuple(mesh.axis_names)

    if compressor is None:
        compressor = PowerSGDCompressor(
            rank=hyper.rank, orthogonalizer=hyper.orthogonalizer,
            use_pallas=hyper.use_pallas, bucketing=hyper.bucketing,
            wire_dtype=hyper.wire_dtype, rank_schedule=hyper.rank_schedule,
            track_residual=hyper.track_residual,
            pipeline=hyper.staleness == "one_step")

    param_ps = model.pspecs(cfg)
    mspec_tree = model.mspecs(cfg)
    # per-leaf StatePartition: the dims specs for shard_map, plus the
    # model-relation (replicated / sharded / LOCAL) the engine and the
    # checkpoint layer need (model-LOCAL Q factors must not be treated as
    # replicated — see docs/checkpoint.md "state pspecs")
    state_parts = specs_lib.ef_partition(param_ps, mspec_tree, dp_axes,
                                         compressor=compressor,
                                         stateful=compressor.stateful,
                                         staleness=hyper.staleness)
    # the in-flight aggregate (staleness="one_step") is classified inside
    # the partition tree like any other leaf — params-shaped, data-
    # replicated, model-sharded exactly like the params it is applied to
    ef_ps = specs_lib.partition_specs(state_parts)
    if hasattr(compressor, "bind_state_partition"):
        compressor.bind_state_partition(state_parts.comp)

    def local_step(params, ef_state, batch, key):
        # error buffers arrive with a leading local dp dim of 1 — unwrap
        error_local = jax.tree_util.tree_map(lambda e: e[0], ef_state.error)
        state = EFState(error=error_local, momentum=ef_state.momentum,
                        comp=ef_state.comp, step=ef_state.step,
                        inflight=ef_state.inflight)

        def loss_fn(p):
            return model.loss_fn(p, batch, cfg, ctx, window=hyper.window,
                                 q_chunk=hyper.q_chunk, remat=hyper.remat,
                                 unroll=hyper.unroll)

        grads, metrics = jax.grad(loss_fn, has_aux=True)(params)

        lr = _schedule(hyper, state.step)
        new_params, new_state, aux = error_feedback.apply_updates(
            compressor, params, grads, state, mspec_tree,
            lr=lr, momentum=hyper.momentum, weight_decay=hyper.weight_decay,
            ctx=ctx, key=key, use_pallas_apply=hyper.use_pallas,
            start_compress_step=hyper.start_compress_step,
            staleness=hyper.staleness)

        new_state = EFState(
            error=jax.tree_util.tree_map(lambda e: e[None], new_state.error),
            momentum=new_state.momentum, comp=new_state.comp,
            step=new_state.step, inflight=new_state.inflight)
        if "residual_ratio" in aux:  # host-side RankControllers read this
            metrics["residual_ratio"] = aux["residual_ratio"]
        metrics = {k: lax.pmean(v, all_axes) for k, v in metrics.items()}
        if hyper.track_drift and dp_axes:
            # added after the metrics pmean: already cross-rank reduced
            # (pmax over data, then over all axes so the output replicates)
            for name, tree in (("params", new_params),
                               ("momentum", new_state.momentum),
                               ("error", new_state.error),
                               ("q", new_state.comp)):
                metrics[f"drift_{name}"] = lax.pmax(
                    replica_drift(ctx, tree), all_axes)
        metrics["lr"] = lr
        return new_params, new_state, metrics

    batch_ps = specs_lib.batch_pspecs(
        cfg, InputShape("x", 0, 2, "train"), dp_axes)

    sharded = jax.shard_map(
        local_step,
        mesh=mesh,
        in_specs=(param_ps, _ef_in_specs(ef_ps), batch_ps, P()),
        out_specs=(param_ps, _ef_in_specs(ef_ps), P()),
        check_vma=False,
    )
    step_fn = jax.jit(sharded, donate_argnums=(0, 1))

    def abstract_state(key=None):
        """Abstract (SDS) params + EF state with shardings, for the dry-run."""
        k = jax.random.key(0) if key is None else key
        params_sds = jax.eval_shape(lambda: model.init(k, cfg, model_shards))
        dp_total = specs_lib.axis_sizes(mesh, dp_axes)

        def err_leaf(p):
            return jax.ShapeDtypeStruct((dp_total,) + tuple(p.shape), p.dtype)

        comp_sds = jax.eval_shape(
            lambda: compressor.init(params_sds, mspec_tree, k))
        ef_sds = EFState(
            error=jax.tree_util.tree_map(err_leaf, params_sds),
            momentum=params_sds,
            comp=comp_sds,
            step=jax.ShapeDtypeStruct((), jnp.int32),
            inflight=(params_sds if hyper.staleness == "one_step" else None),
        )
        params_sds = specs_lib.with_sharding(params_sds, param_ps, mesh)
        ef_sds = specs_lib.with_sharding(ef_sds, ef_ps, mesh)
        return params_sds, ef_sds

    def init_state(key):
        """Concrete initialisation (used by the real trainer on host devices)."""
        kp, kc = jax.random.split(key)
        params = model.init(kp, cfg, model_shards)
        dp_total = specs_lib.axis_sizes(mesh, dp_axes)
        comp = compressor.init(
            jax.tree_util.tree_map(
                lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params),
            mspec_tree, kc)
        ef = EFState(
            error=jax.tree_util.tree_map(
                lambda p: jnp.zeros((dp_total,) + tuple(p.shape), p.dtype), params),
            momentum=jax.tree_util.tree_map(jnp.zeros_like, params),
            comp=comp,
            step=jnp.zeros((), jnp.int32),
            inflight=(jax.tree_util.tree_map(jnp.zeros_like, params)
                      if hyper.staleness == "one_step" else None),
        )
        return params, ef

    return step_fn, abstract_state, init_state


def _ef_in_specs(ef_ps: EFState):
    return EFState(error=ef_ps.error, momentum=ef_ps.momentum,
                   comp=ef_ps.comp, step=ef_ps.step, inflight=ef_ps.inflight)


def train_state_partition(cfg: ModelConfig, mesh,
                          compressor: Optional[Compressor] = None,
                          staleness: str = "none") -> EFState:
    """The per-leaf :class:`~repro.core.engine.StatePartition` tree a
    driver hands to ``repro.checkpoint.canonicalize_mesh`` /
    ``replicate_mesh`` / ``stack_model_template`` — the same derivation
    :func:`make_train_step` binds into the engine, recomputed standalone so
    checkpoint tooling (and a restoring process that hasn't built a step
    yet) can classify leaves without tracing anything.  Pass the run's
    ``staleness`` so a one-step-stale state's ``inflight`` leaves are
    classified too (an EFState with more leaves than its partition tree
    fails gradlint's GL401)."""
    if compressor is None:
        compressor = PowerSGDCompressor()
    return specs_lib.ef_partition(
        model.pspecs(cfg), model.mspecs(cfg), mesh_lib.data_axes(mesh),
        compressor=compressor, stateful=compressor.stateful,
        staleness=staleness)


# ---------------------------------------------------------------------------
# SimMesh training step: W logical workers in one process (one device)
# ---------------------------------------------------------------------------

def make_sim_train_step(cfg: ModelConfig, sim, hyper: TrainHyper,
                        compressor: Optional[Compressor] = None,
                        stats=None):
    """W-worker EF-PowerSGD train step on a :class:`repro.core.simmesh.
    SimMesh` — same math as the ``shard_map`` step, no mesh required.

    Returns ``(step_fn, init_state)``:

    ``step_fn(params, ef_state, batch, key, weights=None)`` →
    ``(params, ef_state, metrics)`` where every tree carries a stacked
    leading worker dim of size ``sim.workers`` (``batch`` is per-worker
    shards ``(W, b_local, ...)``, see :meth:`SimMesh.shard`) and ``key`` is
    shared by all workers (compressors rely on shared seeds).  ``weights``
    is an optional ``(W,)`` per-worker contribution-weight vector for
    scenario injection — uniform means when omitted; ``0`` drops a worker
    from this round's aggregation (its per-worker EF memory still updates
    from its own ``Δ_w``, against the round's reconstruction per
    ``error_mode``); for heterogeneous batch sizes pass each worker's
    valid-token count.

    ``init_state(key)`` → ``(params, ef_state)``, replicated/zeroed with the
    worker dim attached.  Workers start bit-identical and — because every
    update is a function of all-reduced quantities only — must *stay*
    bit-identical (``sim.assert_replicated`` checks this invariant).
    """
    if compressor is None:
        compressor = PowerSGDCompressor(
            rank=hyper.rank, orthogonalizer=hyper.orthogonalizer,
            use_pallas=hyper.use_pallas, bucketing=hyper.bucketing,
            wire_dtype=hyper.wire_dtype, rank_schedule=hyper.rank_schedule,
            track_residual=hyper.track_residual,
            pipeline=hyper.staleness == "one_step")
    mspec_tree = model.mspecs(cfg)

    def worker_step(params, ef_state, batch, key, weight):
        # ctx is built inside the mapped function so the traced per-worker
        # weight binds to this trace
        ctx = sim.ctx(weight=weight, stats=stats, sync_mode=hyper.sync_mode)

        def loss_fn(p):
            return model.loss_fn(p, batch, cfg, ctx, window=hyper.window,
                                 q_chunk=hyper.q_chunk, remat=hyper.remat,
                                 unroll=hyper.unroll)

        grads, metrics = jax.grad(loss_fn, has_aux=True)(params)

        lr = _schedule(hyper, ef_state.step)
        new_params, new_state, aux = error_feedback.apply_updates(
            compressor, params, grads, ef_state, mspec_tree,
            lr=lr, momentum=hyper.momentum, weight_decay=hyper.weight_decay,
            ctx=ctx, key=key, use_pallas_apply=hyper.use_pallas,
            start_compress_step=hyper.start_compress_step,
            staleness=hyper.staleness)

        # metrics aggregate through the backend directly: they are
        # observability, not gradient traffic, and must not perturb the
        # CollectiveStats 2-collectives-per-step invariant
        if "residual_ratio" in aux:  # host-side RankControllers read this
            metrics["residual_ratio"] = aux["residual_ratio"]
        metrics = {k: ctx.backend.pmean(v, ctx.data_axes)
                   for k, v in metrics.items()}
        if hyper.track_drift:
            for name, tree in (("params", new_params),
                               ("momentum", new_state.momentum),
                               ("error", new_state.error),
                               ("q", new_state.comp)):
                metrics[f"drift_{name}"] = replica_drift(ctx, tree)
        metrics["lr"] = lr
        return new_params, new_state, metrics

    mapped = sim.run(worker_step, in_axes=(0, 0, 0, None, 0))
    jitted = jax.jit(mapped, donate_argnums=(0, 1))

    def step_fn(params, ef_state, batch, key, weights=None):
        if weights is None:
            weights = jnp.ones((sim.workers,), jnp.float32)
        return jitted(params, ef_state, batch, key,
                      jnp.asarray(weights, jnp.float32))

    def init_state(key):
        kp, kc = jax.random.split(key)
        params = model.init(kp, cfg, model_shards=1)
        comp = compressor.init(
            jax.tree_util.tree_map(
                lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params),
            mspec_tree, kc)
        ef = EFState(
            error=jax.tree_util.tree_map(jnp.zeros_like, params),
            momentum=jax.tree_util.tree_map(jnp.zeros_like, params),
            comp=comp,
            step=jnp.zeros((), jnp.int32),
            inflight=(jax.tree_util.tree_map(jnp.zeros_like, params)
                      if hyper.staleness == "one_step" else None),
        )
        return sim.replicate(params), sim.replicate(ef)

    return step_fn, init_state


def check_wire_dtype_meta(meta: dict, wire_dtype: str) -> None:
    """Resume guard: the checkpoint's recorded wire policy must match.

    The wire dtype shapes the error-feedback trajectory — under a quantized
    wire every step's quantization error lands in the EF buffers, so the
    buffers in the envelope are only meaningful under the policy that
    produced them.  A mismatch is a config error, not something to adapt."""
    saved = meta.get("wire_dtype", "auto")
    if saved != wire_dtype:
        raise SystemExit(
            f"--wire-dtype {wire_dtype!r} does not match the checkpoint's "
            f"{saved!r} — the wire policy shapes the error-feedback "
            f"trajectory (quantization error is part of the algorithm "
            f"state); resume with the wire dtype the run was started with")


# ---------------------------------------------------------------------------
# CLI driver: end-to-end training of a reduced model on host devices
# ---------------------------------------------------------------------------

def main():
    import argparse
    import time

    from repro.checkpoint import (TrainState, canonicalize_mesh,
                                  replicate_mesh, restore_train_state,
                                  save_train_state, stack_model_template)
    from repro.configs.base import get_config
    from repro.data.synthetic import MarkovLM

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--rank", type=int, default=2)
    ap.add_argument("--rank-schedule", default=None,
                    help="adaptive-rank spec, e.g. '4@0,2@60,1@120' or "
                         "'residual:min=1,max=8,init=4' (see "
                         "repro.core.powersgd.parse_schedule)")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--sync-mode", default="allreduce",
                    choices=("allreduce", "broadcast"),
                    help="'broadcast' makes every data-axis aggregate "
                         "replica-deterministic (canonical reduction order "
                         "+ rank-0 broadcast; see docs/checkpoint.md)")
    ap.add_argument("--wire-dtype", default="auto",
                    choices=matrixize.WIRE_DTYPES,
                    help="fused-collective wire policy: 'auto' keeps each "
                         "part's dtype, float32/bfloat16 cast, int8/int4 "
                         "quantize float payloads symmetrically per slot "
                         "(int4 nibble-packed; see docs/tuning.md)")
    ap.add_argument("--staleness", default="none",
                    choices=("none", "one_step"),
                    help="'one_step' turns on the delayed-parameter-update "
                         "pipeline: apply step t-1's aggregated compressed "
                         "update while step t's gradients are computed "
                         "(error feedback absorbs the delay; see "
                         "docs/tuning.md)")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save a full TrainState checkpoint every N steps "
                         "(0 = only at the end; needs --ckpt-dir)")
    ap.add_argument("--ckpt-keep", type=int, default=3,
                    help="retention: keep the newest N checkpoints")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt-dir: "
                         "full algorithm state (EF buffers, warm-start "
                         "factors, rank controller, PRNG stream, data "
                         "cursor), bit-exact at the same worker count")
    args = ap.parse_args()
    if args.ckpt_every and not args.ckpt_dir:
        ap.error("--ckpt-every requires --ckpt-dir (no checkpoint would "
                 "ever be written)")

    cfg = get_config(args.arch, reduced=True)
    n_dev = len(jax.devices())
    if n_dev >= 4:
        m = jax.make_mesh((n_dev // 2, 2), ("data", "model"))
    elif n_dev >= 2:
        m = jax.make_mesh((n_dev, 1), ("data", "model"))
    else:
        m = jax.make_mesh((1, 1), ("data", "model"))

    hyper = TrainHyper(lr=args.lr, rank=args.rank, q_chunk=64,
                       warmup_steps=20, remat=False,
                       rank_schedule=args.rank_schedule,
                       wire_dtype=args.wire_dtype,
                       sync_mode=args.sync_mode, staleness=args.staleness)
    compressor = PowerSGDCompressor(
        rank=args.rank, rank_schedule=args.rank_schedule,
        wire_dtype=args.wire_dtype,
        pipeline=args.staleness == "one_step")
    step_fn, _, init_state = make_train_step(cfg, m, hyper,
                                             compressor=compressor)
    controller = (compressor.controller()
                  if compressor.rank_schedule is not None else None)
    # per-leaf state partition: which checkpoint leaves are model-LOCAL
    # (per-model-rank Q factors) and must be gathered/re-sliced per rank
    parts = train_state_partition(cfg, m, compressor,
                                  staleness=args.staleness)
    model_size = int(m.shape["model"])

    key = jax.random.key(0)   # base key; per-step keys fold in the step index
    with jax.set_mesh(m):
        params, ef = init_state(key)
    data = MarkovLM(vocab=cfg.vocab_size, seed=0)

    start = 0
    residual = None
    if args.resume:
        if not args.ckpt_dir:
            ap.error("--resume requires --ckpt-dir")
        template = TrainState(
            params=params, ef=stack_model_template(ef, parts, model_size),
            key=key, data_step=jnp.zeros((), jnp.int32))
        state, meta = restore_train_state(args.ckpt_dir, template,
                                          model_axis_size=model_size)
        if meta.get("rank_schedule") != args.rank_schedule:
            raise SystemExit(
                f"--rank-schedule {args.rank_schedule!r} does not match the "
                f"checkpoint's {meta.get('rank_schedule')!r} — resume with "
                f"the schedule the run was started with")
        if meta.get("staleness", "none") != args.staleness:
            raise SystemExit(
                f"--staleness {args.staleness!r} does not match the "
                f"checkpoint's {meta.get('staleness', 'none')!r} — the "
                f"envelope does (not) carry an in-flight aggregate; resume "
                f"with the mode the run was started with")
        check_wire_dtype_meta(meta, args.wire_dtype)
        # re-slice stacked model-LOCAL leaves: every model rank gets its
        # own pre-save factors back (not rank-0's copy)
        with jax.set_mesh(m):
            params, ef = replicate_mesh(m, state.params, state.ef, parts)
        key = state.key
        start = int(state.ef.step)
        if int(state.data_step) != start:
            raise SystemExit(
                f"checkpoint data cursor {int(state.data_step)} does not "
                f"match its step counter {start} — this CLI keys batches "
                f"by step, so the envelope was written by a different "
                f"driver; resume it with that driver")
        if controller is not None and meta.get("controller"):
            controller.load_state_dict(meta["controller"])
        residual = meta.get("last_residual")
        print(f"resumed from step {start} in {args.ckpt_dir} "
              f"(saved at {meta.get('workers')} worker(s), rank "
              f"{controller.rank if controller else args.rank})")

    def save_ckpt():
        # params/ef/key/residual are read at call time: the state *after*
        # the step that just completed, i.e. "about to run step ef.step".
        # canonicalize_mesh gathers model-LOCAL leaves host-side into the
        # stacked per-model-rank layout (no collectives)
        p_c, ef_c = canonicalize_mesh(m, params, ef, parts)
        path = save_train_state(
            args.ckpt_dir,
            TrainState(params=p_c, ef=ef_c, key=key,
                       data_step=jnp.asarray(int(ef.step), jnp.int32)),
            controller=controller, keep=args.ckpt_keep,
            model_axis_size=model_size,
            mesh_shape={a: int(m.shape[a]) for a in m.axis_names},
            extra_meta={"rank_schedule": args.rank_schedule,
                        "arch": args.arch, "last_residual": residual,
                        "staleness": args.staleness,
                        "wire_dtype": args.wire_dtype})
        return path

    t0 = time.time()
    metrics = {}
    for i in range(start, args.steps):
        if controller is not None:
            # host-level rank transition: a switch changes the factor
            # shapes, and the jitted step simply retraces
            new_comp, changed = controller.update(ef.comp, i, residual)
            if changed:
                ef = error_feedback.replace_comp(ef, new_comp)
                print(f"step {i:4d} rank -> {controller.rank}")
        # the data cursor IS the step index: batch i is sample(step=i),
        # so a resumed run rejoins the stream exactly where it left off
        toks = data.sample(args.batch, args.seq, step=i)
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:].copy())}
        step_key = jax.random.fold_in(key, i)
        with jax.set_mesh(m):
            params, ef, metrics = step_fn(params, ef, batch, step_key)
        if "residual_ratio" in metrics:
            residual = float(metrics["residual_ratio"])
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(metrics['lm_loss']):.4f} "
                  f"lr={float(metrics['lr']):.4f} ({time.time()-t0:.1f}s)")
        if args.ckpt_dir and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            print(f"step {i:4d} checkpoint -> {save_ckpt()}")
    if args.ckpt_dir and start < args.steps:
        print(f"final checkpoint -> {save_ckpt()}")
    if metrics:
        # full-precision hex so the CI resume smoke can compare bit-for-bit
        print(f"final lm_loss={float(metrics['lm_loss']):.6f} "
              f"hex={float(metrics['lm_loss']).hex()}")


if __name__ == "__main__":
    main()
