"""Distributed serving steps: prefill and decode under shard_map.

Decode layouts (see specs.decode_layout):
  * ``decode_32k``  — batch over (pod, data); cache sequence over (model,)
                      with flash-decode logsumexp merging.
  * ``long_500k``   — batch=1 is unshardable: the cache sequence shards over
                      (pod, data, model) jointly.  Dense archs use their
                      sliding-window variant (ring cache of decode_window);
                      SSM/hybrid decode their O(1) state natively.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.dist import MeshCtx
from repro.configs.base import InputShape, ModelConfig
from repro.models import model
from repro.launch import mesh as mesh_lib
from repro.launch import specs as specs_lib


def make_decode_step(cfg: ModelConfig, mesh, shape: InputShape, *,
                     q_chunk: int = 512, unroll: int = 1):
    """Returns (jitted_decode, abstract_inputs_fn)."""
    dp_axes = mesh_lib.data_axes(mesh)
    maxis = mesh_lib.model_axis(mesh)
    model_shards = mesh.shape[maxis]
    layout = specs_lib.decode_layout(cfg, shape, dp_axes)
    ctx = MeshCtx(data_axes=dp_axes, model_axis=maxis,
                  seq_axes=layout.seq_axes)

    param_ps = model.pspecs(cfg)
    cache_sds, cache_ps = specs_lib.abstract_cache(
        cfg, layout, shape, mesh, model_shards)
    ba = layout.batch_axes if layout.batch_axes else None
    tok_ps = {"tokens": P(ba, None)}

    def local_step(params, cache, batch, pos):
        nxt, logits, new_cache = model.decode_step(
            params, cache, batch["tokens"], pos, cfg, ctx,
            window=layout.window, unroll=unroll)
        return nxt, new_cache

    sharded = jax.shard_map(
        local_step, mesh=mesh,
        in_specs=(param_ps, cache_ps, tok_ps, P()),
        out_specs=(P(ba, None), cache_ps),
        check_vma=False,
    )
    step_fn = jax.jit(sharded, donate_argnums=(1,))

    def abstract_inputs():
        params_sds = jax.eval_shape(
            lambda: model.init(jax.random.key(0), cfg, model_shards))
        params_sds = specs_lib.with_sharding(params_sds, param_ps, mesh)
        cache = specs_lib.with_sharding(cache_sds, cache_ps, mesh)
        toks = specs_lib.with_sharding(
            specs_lib.batch_specs(cfg, shape), tok_ps, mesh)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return params_sds, cache, toks, pos

    return step_fn, abstract_inputs


def make_prefill_step(cfg: ModelConfig, mesh, shape: InputShape, *,
                      q_chunk: int = 512, unroll: int = 1):
    """Prefill: forward over the full prompt, emitting cache slices laid out
    exactly as decode expects (sequence over the model axis)."""
    dp_axes = mesh_lib.data_axes(mesh)
    maxis = mesh_lib.model_axis(mesh)
    model_shards = mesh.shape[maxis]
    # prefill caches are seq-sharded over the model axis (decode_32k layout)
    layout = specs_lib.DecodeLayout(
        batch_axes=tuple(dp_axes), seq_axes=(maxis,),
        cache_len=shape.seq_len, window=0)
    ctx = MeshCtx(data_axes=dp_axes, model_axis=maxis,
                  seq_axes=layout.seq_axes)

    param_ps = model.pspecs(cfg)
    cache_sds, cache_ps = specs_lib.abstract_cache(
        cfg, layout, shape, mesh, model_shards)
    batch_ps = specs_lib.batch_pspecs(cfg, shape, dp_axes)

    # use a sliding window in prefill too when the arch defines one and the
    # prompt exceeds it (keeps dense archs sub-quadratic at long context)
    window = cfg.decode_window if (cfg.decode_window and
                                   shape.seq_len > 4 * cfg.decode_window) else 0

    def local_step(params, batch):
        logits, cache = model.prefill_step(params, batch, cfg, ctx,
                                           window=window, q_chunk=q_chunk,
                                           unroll=unroll)
        return logits, cache

    sharded = jax.shard_map(
        local_step, mesh=mesh,
        in_specs=(param_ps, batch_ps),
        out_specs=(P(tuple(dp_axes), None, None), cache_ps),
        check_vma=False,
    )
    step_fn = jax.jit(sharded)

    def abstract_inputs():
        params_sds = jax.eval_shape(
            lambda: model.init(jax.random.key(0), cfg, model_shards))
        params_sds = specs_lib.with_sharding(params_sds, param_ps, mesh)
        batch = specs_lib.with_sharding(
            specs_lib.batch_specs(cfg, shape), batch_ps, mesh)
        return params_sds, batch

    return step_fn, abstract_inputs


# ---------------------------------------------------------------------------
# CLI driver: serve a reduced model end-to-end on the host devices
# ---------------------------------------------------------------------------

def main():
    import argparse
    import time

    import numpy as np

    from repro.configs.base import get_config

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((max(1, n_dev // 2), min(2, n_dev)),
                         ("data", "model"))
    model_shards = mesh.shape["model"]
    print(f"serving {cfg.name} on mesh "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")

    cache_len = args.prompt_len + args.gen_tokens
    pre_shape = InputShape("cli_prefill", args.prompt_len, args.batch,
                           "prefill")
    dec_shape = InputShape("cli_decode", cache_len, args.batch, "decode")

    prefill_fn, _ = make_prefill_step(cfg, mesh, pre_shape, q_chunk=32)
    decode_fn, abstract = make_decode_step(cfg, mesh, dec_shape)

    key = jax.random.key(0)
    with jax.set_mesh(mesh):
        params = model.init(key, cfg, model_shards)
        toks = jax.random.randint(jax.random.key(1),
                                  (args.batch, args.prompt_len), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks}
        if cfg.frontend == "vision":
            batch["patches"] = jax.random.normal(
                key, (args.batch, 8, cfg.frontend_dim))

        t0 = time.time()
        logits, _ = prefill_fn(params, batch)
        jax.block_until_ready(logits)
        t_pre = time.time() - t0
        # decode against a fresh full-length cache (prompt replayed)
        _, cache_sds, _, _ = abstract()
        cache = jax.tree_util.tree_map(
            lambda s: jax.device_put(jnp.zeros(s.shape, s.dtype), s.sharding),
            cache_sds)
        tok = jnp.zeros((args.batch, 1), jnp.int32)
        t0 = time.time()
        for pos in range(args.prompt_len):
            tok, cache = decode_fn(params, cache,
                                   {"tokens": toks[:, pos:pos + 1]},
                                   jnp.int32(pos))
        out = []
        for k in range(args.gen_tokens):
            tok, cache = decode_fn(params, cache, {"tokens": tok},
                                   jnp.int32(args.prompt_len + k))
            out.append(np.asarray(tok))  # gradlint: disable=host-transfer
        jax.block_until_ready(tok)
        t_dec = time.time() - t0

    total = args.prompt_len + args.gen_tokens
    print(f"prefill {args.batch}x{args.prompt_len}: {t_pre*1e3:.0f} ms; "
          f"decode {total} steps: {t_dec*1e3:.0f} ms "
          f"({args.batch*total/t_dec:.0f} tok/s)")
    print("generated token ids:",
          np.concatenate(out, axis=1)[:, :8].tolist())


if __name__ == "__main__":
    main()
