"""PartitionSpec derivation for optimizer / compressor state, and
ShapeDtypeStruct ``input_specs()`` for every (architecture × input shape).

Nothing in this module allocates device memory — the dry-run lowers
train/serve steps entirely from these abstract values.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import matrixize, powersgd
from repro.core.engine import (MODEL_REPLICATED, MODEL_SHARDED,
                               StatePartition)
from repro.core.error_feedback import EFState
from repro.configs.base import InputShape, ModelConfig
from repro.models import attention, model
from repro.launch import mesh as mesh_lib


def qstate_pspec(param_spec: P, mspec: matrixize.MatrixSpec) -> Optional[P]:
    """PartitionSpec of the PowerSGD Q factor for one parameter.

    Q has shape batch_shape + (m, r): batch dims keep their entries; the m
    dim is model-sharded iff any of the parameter's trailing (m) dims is.
    The canonical derivation (including the model-LOCAL classification of
    row-parallel weights' factors, which this dims-only view cannot
    express) lives in :func:`repro.core.powersgd.factor_partition`."""
    part = powersgd.factor_partition(param_spec, mspec)
    return None if part is None else part.spec


def qstate_pspecs(param_pspecs, mspecs):
    return jax.tree_util.tree_map(
        qstate_pspec, param_pspecs, mspecs,
        is_leaf=lambda x: isinstance(x, P))


def _dims_partition(spec: P, model_axis: str = "model") -> StatePartition:
    """Partition record for a leaf whose spec is *honest* — its content is
    fully described by its dims (params, momentum, error buffers): the leaf
    is model-sharded iff some dim carries the axis, never model-local."""
    sharded = any(powersgd._mentions(e, model_axis) for e in tuple(spec))
    return StatePartition(
        spec=spec, model=MODEL_SHARDED if sharded else MODEL_REPLICATED)


def ef_partition(param_pspecs, mspecs, dp_axes: Tuple[str, ...],
                 compressor=None, stateful: bool = True,
                 staleness: str = "none") -> EFState:
    """Per-leaf :class:`~repro.core.engine.StatePartition` tree for the
    whole EF-SGD state — the single source of truth the shard_map specs
    (:func:`ef_pspecs`) and the mesh-aware checkpoint path
    (``checkpoint/train_state.py::canonicalize_mesh``/``replicate_mesh``)
    both derive from.

    Error buffers gain the leading data-axes dim and inherit the owning
    parameter's model sharding; momentum mirrors the parameter exactly;
    ``comp`` is the compressor's own :meth:`~repro.core.compressors.
    Compressor.state_partition` (PowerSGD classifies row-parallel weights'
    Q factors as model-LOCAL — per-model-rank content behind a
    replicated-shaped spec).

    ``staleness="one_step"`` additionally classifies the params-shaped
    ``inflight`` double buffer, leaf-for-leaf like the parameters it will
    be applied to (data-replicated, model-sharded where the param is).
    This used to be hand-patched at step-build time only, which left
    ``EFState.inflight`` *unclassified* for every partition consumer that
    never built a step — the checkpoint classification path
    (:func:`repro.launch.train.train_state_partition`) returned a tree
    with no record for the in-flight leaves, exactly the PR 7
    unclassified-leaf bug class gradlint's partition pass exists to catch
    (rule GL401, which surfaced this)."""
    is_p = lambda x: isinstance(x, P)
    error = jax.tree_util.tree_map(
        lambda s: _dims_partition(P(*((dp_axes,) + tuple(s)))),
        param_pspecs, is_leaf=is_p)
    momentum = jax.tree_util.tree_map(_dims_partition, param_pspecs,
                                      is_leaf=is_p)
    if compressor is not None:
        comp = compressor.state_partition(param_pspecs, mspecs)
    elif stateful:
        comp = powersgd.state_partition(param_pspecs, mspecs)
    else:
        comp = None
    inflight = None
    if staleness == "one_step":
        inflight = jax.tree_util.tree_map(_dims_partition, param_pspecs,
                                          is_leaf=is_p)
    return EFState(error=error, momentum=momentum, comp=comp,
                   step=StatePartition(spec=P(), model=MODEL_REPLICATED),
                   inflight=inflight)


def partition_specs(partition):
    """Extract the dims-PartitionSpec tree from a partition tree (what
    ``shard_map`` in/out specs consume)."""
    return jax.tree_util.tree_map(
        lambda p: None if p is None else p.spec, partition,
        is_leaf=lambda x: x is None or isinstance(x, StatePartition))


def ef_pspecs(param_pspecs, mspecs, dp_axes: Tuple[str, ...],
              stateful: bool = True) -> EFState:
    """PartitionSpecs for the EF-SGD state tree (dims view of
    :func:`ef_partition`).

    ``stateful=False`` — the compressor carries no per-matrix state
    (identity, sparsifiers): ``comp`` is the empty pytree ``None``."""
    return partition_specs(
        ef_partition(param_pspecs, mspecs, dp_axes, stateful=stateful))


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

VLM_PATCH_TOKENS = 2880  # ≈ 5 anyres tiles × 576 patches


def batch_pspecs(cfg: ModelConfig, shape: InputShape, dp_axes):
    dp = dp_axes if shape.global_batch > 1 else None
    if shape.kind in ("train", "prefill"):
        s = {"tokens": P(dp, None), "labels": P(dp, None)}
        if cfg.frontend == "vision":
            s["patches"] = P(dp, None, None)
        if shape.kind == "prefill":
            s.pop("labels")
        return s
    return {"tokens": P(dp, None)}


def batch_specs(cfg: ModelConfig, shape: InputShape):
    """Global-shape ShapeDtypeStructs for the step inputs."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "vision":
            n_img = VLM_PATCH_TOKENS
            out = {
                "tokens": jax.ShapeDtypeStruct((b, s - n_img), jnp.int32),
                "patches": jax.ShapeDtypeStruct((b, n_img, cfg.frontend_dim), jnp.float32),
            }
            if shape.kind == "train":
                out["labels"] = jax.ShapeDtypeStruct((b, s - n_img), jnp.int32)
            return out
        out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return out
    # decode: one new token per sequence
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def with_sharding(tree_sds, tree_pspecs, mesh):
    def leaf(sds, spec):
        if sds is None:
            return None
        return jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(
        leaf, tree_sds, tree_pspecs, is_leaf=lambda x: x is None)


# ---------------------------------------------------------------------------
# decode layouts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodeLayout:
    """How the KV cache is laid out on the mesh for a decode shape."""

    batch_axes: Tuple[str, ...]   # axes sharding the request batch
    seq_axes: Tuple[str, ...]     # axes sharding the cache sequence
    cache_len: int                # global cache length (window if sliding)
    window: int                   # 0 = full cache


def decode_layout(cfg: ModelConfig, shape: InputShape, dp_axes) -> DecodeLayout:
    uses_window = bool(cfg.decode_window) and shape.seq_len > cfg.decode_window
    cache_len = cfg.decode_window if uses_window else shape.seq_len
    if shape.global_batch == 1:
        # long_500k: batch is unshardable — shard the cache sequence over
        # every axis (flash-decode merge over pod+data+model)
        return DecodeLayout(batch_axes=(), seq_axes=tuple(dp_axes) + ("model",),
                            cache_len=cache_len,
                            window=cfg.decode_window if uses_window else 0)
    return DecodeLayout(batch_axes=tuple(dp_axes), seq_axes=("model",),
                        cache_len=cache_len,
                        window=cfg.decode_window if uses_window else 0)


def axis_sizes(mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def abstract_cache(cfg: ModelConfig, layout: DecodeLayout, shape: InputShape,
                   mesh, model_shards: int):
    """Global-shape SDS tree for the stacked decode cache + its pspecs."""
    from repro.models import blocks

    dtype = cfg.jnp_dtype()
    b = shape.global_batch
    seq = layout.cache_len
    # build the *local* template at global sizes via the init fn signature:
    # init_cache takes local sizes; global tree = local sizes × shard counts,
    # so we call it with the global sizes and shard via pspecs.
    # global template: full batch/seq/head sizes (model_shards=1), sharded
    # down to local slices by the pspecs below
    tmpl = jax.eval_shape(lambda: blocks.init_cache(cfg, 1, b, seq, dtype))
    ps = blocks.cache_pspecs(
        cfg,
        layout.batch_axes if layout.batch_axes else None,
        layout.seq_axes if layout.seq_axes else None,
    )
    return tmpl, ps
