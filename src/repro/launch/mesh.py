"""Production mesh definitions.

Single pod: 16×16 = 256 chips (TPU v5e pod), axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model).

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state.
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small mesh for CI on a host with 8 fake devices."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    assert "model" in mesh.axis_names
    return "model"


def mesh_info(mesh):
    dp = 1
    for a in data_axes(mesh):
        dp *= mesh.shape[a]
    return {
        "data_parallel": dp,
        "model_parallel": mesh.shape["model"],
        "chips": dp * mesh.shape["model"],
        "axis_names": tuple(mesh.axis_names),
    }
