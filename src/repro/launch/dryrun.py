import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent without real
hardware.

For every (architecture × input shape), lower + compile the appropriate step
(train / prefill / decode) on the production mesh — single-pod 16×16 and
multi-pod 2×16×16 — and record memory analysis, cost analysis and the
roofline terms.

The two lines above MUST run before any other import: jax locks the device
count at first initialisation.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as roofline_lib
from repro.launch.train import TrainHyper, make_train_step
from repro.launch.serve import make_decode_step, make_prefill_step


def _compile_combo(cfg, shape, mesh, hyper, unroll: int):
    """Lower + compile one step for ``cfg``; returns (compiled, t_lower, t_compile)."""
    t0 = time.time()
    if shape.kind == "train":
        from repro.launch import specs as specs_lib
        hy = dataclasses.replace(hyper, unroll=unroll)
        step_fn, abstract_state, _ = make_train_step(cfg, mesh, hy)
        params_sds, ef_sds = abstract_state()
        batch = specs_lib.with_sharding(
            specs_lib.batch_specs(cfg, shape),
            specs_lib.batch_pspecs(cfg, shape, mesh_lib.data_axes(mesh)),
            mesh)
        key = jax.eval_shape(lambda: jax.random.key(0))
        lowered = step_fn.lower(params_sds, ef_sds, batch, key)
    elif shape.kind == "prefill":
        step_fn, abstract = make_prefill_step(cfg, mesh, shape,
                                              q_chunk=hyper.q_chunk,
                                              unroll=unroll)
        lowered = step_fn.lower(*abstract())
    else:  # decode
        step_fn, abstract = make_decode_step(cfg, mesh, shape, unroll=unroll)
        lowered = step_fn.lower(*abstract())
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    return compiled, t_lower, time.time() - t0


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                hyper: TrainHyper = None, verbose: bool = True,
                cost_mode: str = "extrapolate",
                cfg_overrides: dict = None) -> dict:
    """Lower + compile one (arch × shape × mesh) and return the report.

    cost_mode:
      "unroll"      — fully unroll the layer scan; exact but slow to compile.
      "extrapolate" — compile the full model with the scan (memory analysis,
                      the deployable artifact) plus 1-period and 2-period
                      variants; per-period cost = cost₂ − cost₁ and
                      total = cost₁ + (P−1)·(cost₂ − cost₁).  XLA's
                      cost_analysis counts a while body once, so this
                      recovers the full-depth cost at a fraction of the
                      compile time (validated against "unroll" in
                      EXPERIMENTS.md §Dry-run).
    """
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = INPUT_SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    info = mesh_lib.mesh_info(mesh)
    hyper = hyper or TrainHyper()

    mf = roofline_lib.model_flops_estimate(cfg, shape)

    if cost_mode == "unroll":
        compiled, t_lower, t_compile = _compile_combo(
            cfg, shape, mesh, hyper, unroll=cfg.num_periods)
        roof = roofline_lib.analyse(compiled, chips=info["chips"],
                                    model_flops=mf)
        mem_compiled = compiled
    else:
        # the deployable artifact: full depth, scan kept (memory analysis)
        mem_compiled, t_lower, t_compile = _compile_combo(
            cfg, shape, mesh, hyper, unroll=1)
        p = cfg.num_periods
        cfg1 = dataclasses.replace(cfg, num_layers=cfg.period)
        cfg2 = dataclasses.replace(cfg, num_layers=2 * cfg.period)
        c1, _, t1 = _compile_combo(cfg1, shape, mesh, hyper, unroll=1)
        c2, _, t2 = _compile_combo(cfg2, shape, mesh, hyper, unroll=2)
        r1 = roofline_lib.analyse(c1, chips=info["chips"])
        r2 = roofline_lib.analyse(c2, chips=info["chips"])
        roof = roofline_lib.Roofline(
            flops=r1.flops + (p - 1) * (r2.flops - r1.flops),
            bytes_accessed=r1.bytes_accessed
            + (p - 1) * (r2.bytes_accessed - r1.bytes_accessed),
            coll_bytes=r1.coll_bytes + (p - 1) * (r2.coll_bytes - r1.coll_bytes),
            chips=info["chips"],
            model_flops=mf,
            coll_detail={k: int(r1.coll_detail[k] + (p - 1) *
                                (r2.coll_detail[k] - r1.coll_detail[k]))
                         for k in r1.coll_detail},
        )
        t_compile += t1 + t2

    mem = mem_compiled.memory_analysis()
    mem_report = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_report[attr] = int(v)

    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": info["chips"],
        "kind": shape.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_report,
        "roofline": roof.to_dict(),
    }
    if verbose:
        bpd = (mem_report.get("argument_size_in_bytes", 0)
               + mem_report.get("temp_size_in_bytes", 0)) / info["chips"]
        print(f"[{arch} × {shape_name} × {report['mesh']}] "
              f"compile={t_compile:.1f}s "
              f"flops={roof.flops:.3e} bytes={roof.bytes_accessed:.3e} "
              f"coll={roof.coll_bytes:.3e} dominant={roof.dominant} "
              f"compute={roof.compute_s*1e3:.2f}ms memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"useful={roof.useful_flops_frac:.2f}")
        print("  memory_analysis:", json.dumps(mem_report))
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (e.g. llama3-8b); default: all")
    ap.add_argument("--shape", default=None,
                    help="input shape (train_4k|prefill_32k|decode_32k|long_500k)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--fail-fast", action="store_true")
    ap.add_argument("--cost-mode", default="extrapolate",
                    choices=["extrapolate", "unroll"])
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}".replace("-", "_").replace(".", "p")
                out_path = os.path.join(args.out, tag + ".json")
                if os.path.exists(out_path):
                    print(f"[skip] {tag} (exists)")
                    continue
                try:
                    report = lower_combo(arch, shape, multi_pod=mp,
                                         cost_mode=args.cost_mode)
                    with open(out_path, "w") as f:
                        json.dump(report, f, indent=2)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mp, str(e)))
                    if args.fail_fast:
                        sys.exit(1)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
