"""Roofline analysis from compiled dry-run artifacts.

Terms (per step, per chip):

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``cost_analysis()`` supplies FLOPs and bytes accessed.  Collective bytes are
not in cost_analysis — we parse the optimized HLO text and sum the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'f32[128,256]'-style shape."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    nbytes = _DTYPE_BYTES.get(dt)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


def _result_shapes(line: str):
    """Shapes on the lhs of an HLO op line (tuple results included)."""
    lhs = line.split("=", 1)[0]
    return _SHAPE_RE.findall(lhs)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-operand sizes per collective kind over the optimized HLO.

    Result sizes are the right accounting for all-gather (output = gathered)
    and all-reduce; for reduce-scatter/all-to-all the result understates by
    the shard factor but matches what actually lands on each chip's links.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # match op kind after the '=' to avoid variable-name false positives
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1].lstrip()
        kind = None
        for c in _COLLECTIVES:
            if rhs.startswith(c) or re.match(rf"\S*\s*{c}\(", rhs) or \
               re.match(rf"{c}-start", rhs):
                kind = c
                break
        # rhs like: "f32[8,16]{1,0} all-reduce(...)" — kind appears after shape
        if kind is None:
            m = re.match(r"(?:\([^)]*\)|\S+)\s+([\w-]+)", rhs)
            if m and any(m.group(1).startswith(c) for c in _COLLECTIVES):
                kind = next(c for c in _COLLECTIVES if m.group(1).startswith(c))
        if kind is None:
            continue
        if re.search(r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)-done", rhs):
            continue  # async completion carries no new bytes
        total = sum(_shape_bytes(f"{dt}[{dims}]")
                    for dt, dims in _SHAPE_RE.findall(rhs.split("(", 1)[0]))
        out[kind] += total
        out["count"] += 1
    return out


@dataclasses.dataclass
class Roofline:
    flops: float               # per-chip HLO flops (SPMD program)
    bytes_accessed: float      # per-chip HLO bytes accessed
    coll_bytes: float          # per-chip collective bytes
    chips: int
    model_flops: float = 0.0   # 6·N·D analytic model flops (whole mesh)
    coll_detail: Optional[Dict[str, int]] = None

    @property
    def compute_s(self) -> float:
        # cost_analysis reports the per-chip SPMD program ⇒ mesh-total
        # flops = flops × chips; the formula HLO_FLOPs/(chips × peak)
        # therefore reduces to flops/peak
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        # HLO is per-chip SPMD: coll_bytes already count one chip's traffic
        return self.coll_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "coll_bytes": self.coll_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_frac": self.useful_flops_frac,
            "coll_detail": self.coll_detail,
        }


def analyse(compiled, chips: int, model_flops: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", cost.get("bytes_accessed", 0.0)))
    text = compiled.as_text()
    coll = collective_bytes(text)
    total_coll = sum(v for k, v in coll.items() if k != "count")
    return Roofline(flops=flops, bytes_accessed=byts, coll_bytes=total_coll,
                    chips=chips, model_flops=model_flops, coll_detail=coll)


def model_flops_estimate(cfg, shape) -> float:
    """6·N_active·D for training, 2·N_active·D for inference forward."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens
