"""Pallas kernels for the int4 wire format: nibble pack/unpack (ISSUE 9).

The fused transport ships int4 payloads as two's-complement nibbles, two
per uint8 byte (``repro.core.matrixize`` quantizes each flat-plan slot with
a symmetric per-slot scale first).  These kernels do the byte-level
combine/split on the VPU: the host strides the flat code vector into its
even/odd halves (a layout change XLA fuses away), pads to the 128-lane
width, and one elementwise grid kernel packs or unpacks a block at a time.

Validated bit-exactly against :mod:`repro.kernels.ref` in interpret mode
(``tests/test_wire_quant.py``); the CPU/test substrates use the reference
path via the :mod:`repro.kernels.ops` dispatcher.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128          # VPU lane width
BLOCK_ROWS = 256    # rows per grid step (multiple of the int8 32-sublane tile)


def _pack_kernel(lo_ref, hi_ref, o_ref):
    """o = (lo & 0xF) | ((hi & 0xF) << 4), elementwise over one block."""
    lo = lo_ref[...].astype(jnp.uint8) & 0xF
    hi = hi_ref[...].astype(jnp.uint8) & 0xF
    o_ref[...] = lo | (hi << 4)


def _unpack_kernel(p_ref, lo_ref, hi_ref):
    """Split each byte into sign-extended low/high int4 codes."""
    p = p_ref[...].astype(jnp.int32)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    lo_ref[...] = jnp.where(lo >= 8, lo - 16, lo).astype(jnp.int8)
    hi_ref[...] = jnp.where(hi >= 8, hi - 16, hi).astype(jnp.int8)


def _to_blocks(flat, rows_pad):
    k = flat.shape[0]
    total = rows_pad * LANE
    return jnp.pad(flat, (0, total - k)).reshape(rows_pad, LANE)


def _grid_rows(k):
    rows = max(1, -(-k // LANE))
    return (-rows) % BLOCK_ROWS + rows if rows > BLOCK_ROWS else rows


def nibble_pack(q, *, interpret=None):
    """Pack flat int4 codes (int8 in [-8, 7], shape ``(n,)``) two-per-byte.

    Same contract as :func:`repro.kernels.ref.nibble_pack`: even indices →
    low nibble, odd → high, odd-length tail zero-padded; returns uint8 of
    length ceil(n/2)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = q.shape[0]
    half = (n + 1) // 2
    qp = jnp.pad(q, (0, 2 * half - n))
    lo, hi = qp[0::2], qp[1::2]
    rows = _grid_rows(half)
    br = min(BLOCK_ROWS, rows)
    out = pl.pallas_call(
        _pack_kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, LANE), lambda i: (i, 0))] * 2,
        out_specs=pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.uint8),
        interpret=interpret,
    )(_to_blocks(lo, rows), _to_blocks(hi, rows))
    return out.reshape(-1)[:half]


def nibble_unpack(packed, n, *, interpret=None):
    """Inverse of :func:`nibble_pack`: ``(ceil(n/2),)`` uint8 → ``(n,)``
    int8 codes in [-8, 7]."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    half = packed.shape[0]
    rows = _grid_rows(half)
    br = min(BLOCK_ROWS, rows)
    lo, hi = pl.pallas_call(
        _unpack_kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, LANE), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, LANE), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.int8)] * 2,
        interpret=interpret,
    )(_to_blocks(packed, rows))
    inter = jnp.stack([lo.reshape(-1)[:half], hi.reshape(-1)[:half]],
                      axis=-1).reshape(2 * half)
    return inter[:n]
