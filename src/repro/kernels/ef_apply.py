"""Fused error-feedback apply kernel (Alg. 2 lines 11-13).

Unfused, the decompress → momentum → parameter update chain makes three
full-size round-trips over HBM per gradient matrix (materialise Δ' = P̂ Qᵀ,
update momentum, update params).  This kernel streams each (bn × bm) tile
once: the low-rank factors live in VMEM, Δ' is reconstructed on the fly in
registers, and momentum/params are read-modify-written in a single pass —
one HBM round-trip instead of three.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.lowrank import LANE

DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_M = 512


def _ef_apply_kernel(x_ref, mom_ref, p_ref, q_ref, lr_ref, lam_ref,
                     x_out, mom_out):
    delta = jnp.dot(p_ref[...], q_ref[...].T,
                    preferred_element_type=jnp.float32)
    lam = lam_ref[0]
    lr = lr_ref[0]
    new_mom = lam * mom_ref[...] + delta
    x_out[...] = x_ref[...] - lr * (delta + new_mom)
    mom_out[...] = new_mom


def _ef_apply_2d(x, mom, p_hat, q, lr, lam, block_n, block_m, interpret):
    n, m = x.shape
    r = q.shape[-1]
    bn, bm = min(block_n, n), min(block_m, m)
    np_, mp_, rp = (-n) % bn + n, (-m) % bm + m, (-r) % LANE + r
    xp = jnp.pad(x, ((0, np_ - n), (0, mp_ - m)))
    momp = jnp.pad(mom, ((0, np_ - n), (0, mp_ - m)))
    pp = jnp.pad(p_hat, ((0, np_ - n), (0, rp - r)))
    qp = jnp.pad(q, ((0, mp_ - m), (0, rp - r)))
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1)
    lam_arr = jnp.asarray(lam, jnp.float32).reshape(1)
    x2, mom2 = pl.pallas_call(
        _ef_apply_kernel,
        grid=(np_ // bn, mp_ // bm),
        in_specs=[
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bn, rp), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, rp), lambda i, j: (j, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, mp_), jnp.float32),
            jax.ShapeDtypeStruct((np_, mp_), jnp.float32),
        ],
        interpret=interpret,
    )(xp, momp, pp, qp, lr_arr, lam_arr)
    return x2[:n, :m].astype(x.dtype), mom2[:n, :m].astype(mom.dtype)


def ef_apply(x, mom, p_hat, q, lr, lam, *, block_n=DEFAULT_BLOCK_N,
             block_m=DEFAULT_BLOCK_M, interpret=None):
    """Batched fused apply; leading dims of x/mom/p_hat/q are batch dims."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    f = functools.partial(_ef_apply_2d, lr=lr, lam=lam, block_n=block_n,
                          block_m=block_m, interpret=interpret)
    if x.ndim == 2:
        return f(x, mom, p_hat, q)
    batch = x.shape[:-2]
    out = jax.vmap(lambda a, b, c, d: f(a, b, c, d))(
        x.reshape((-1,) + x.shape[-2:]),
        mom.reshape((-1,) + mom.shape[-2:]),
        p_hat.reshape((-1,) + p_hat.shape[-2:]),
        q.reshape((-1,) + q.shape[-2:]),
    )
    return out[0].reshape(batch + x.shape[-2:]), out[1].reshape(batch + x.shape[-2:])
