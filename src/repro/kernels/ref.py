"""Pure-jnp oracles for the Pallas kernels (the allclose reference)."""

from __future__ import annotations

import jax.numpy as jnp


def lowrank_project(m, q):
    """P = M Q.   m: (..., n, k), q: (..., k, r) → (..., n, r)."""
    return jnp.einsum("...nk,...kr->...nr", m, q)


def lowrank_backproject(m, p_hat):
    """Q = Mᵀ P̂.  m: (..., n, k), p_hat: (..., n, r) → (..., k, r)."""
    return jnp.einsum("...nk,...nr->...kr", m, p_hat)


def ef_apply(x, mom, p_hat, q, lr, lam):
    """Fused decompress + momentum + parameter update (Alg. 2 lines 11-13).

        Δ'   = P̂ Qᵀ
        mom' = λ·mom + Δ'
        x'   = x − lr·(Δ' + mom')

    Returns (x', mom')."""
    delta = jnp.einsum("...nr,...mr->...nm", p_hat, q)
    new_mom = lam * mom + delta
    new_x = x - lr * (delta + new_mom)
    return new_x, new_mom
