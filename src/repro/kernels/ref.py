"""Pure-jnp oracles for the Pallas kernels (the allclose reference).

Every oracle is batched over arbitrary leading dims via einsum ellipsis —
the same contract as the kernels, so a ``(B, n, k)`` bucket slab can be
checked against the batch-grid kernel with one call.
"""

from __future__ import annotations

import jax.numpy as jnp


def lowrank_project(m, q):
    """P = M Q.   m: (..., n, k), q: (..., k, r) → (..., n, r)."""
    return jnp.einsum("...nk,...kr->...nr", m, q)


def lowrank_backproject(m, p_hat):
    """Q = Mᵀ P̂.  m: (..., n, k), p_hat: (..., n, r) → (..., k, r)."""
    return jnp.einsum("...nk,...nr->...kr", m, p_hat)


def decompress(p_hat, q):
    """Δ' = P̂ Qᵀ.  p_hat: (..., n, r), q: (..., m, r) → (..., n, m)."""
    return jnp.einsum("...nr,...mr->...nm", p_hat, q)


def ef_apply(x, mom, p_hat, q, lr, lam):
    """Fused decompress + momentum + parameter update (Alg. 2 lines 11-13).

        Δ'   = P̂ Qᵀ
        mom' = λ·mom + Δ'
        x'   = x − lr·(Δ' + mom')

    Returns (x', mom')."""
    delta = jnp.einsum("...nr,...mr->...nm", p_hat, q)
    new_mom = lam * mom + delta
    new_x = x - lr * (delta + new_mom)
    return new_x, new_mom
