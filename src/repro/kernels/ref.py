"""Pure-jnp oracles for the Pallas kernels (the allclose reference).

Every oracle is batched over arbitrary leading dims via einsum ellipsis —
the same contract as the kernels, so a ``(B, n, k)`` bucket slab can be
checked against the batch-grid kernel with one call.
"""

from __future__ import annotations

import jax.numpy as jnp


def lowrank_project(m, q):
    """P = M Q.   m: (..., n, k), q: (..., k, r) → (..., n, r)."""
    return jnp.einsum("...nk,...kr->...nr", m, q)


def lowrank_backproject(m, p_hat):
    """Q = Mᵀ P̂.  m: (..., n, k), p_hat: (..., n, r) → (..., k, r)."""
    return jnp.einsum("...nk,...nr->...kr", m, p_hat)


def decompress(p_hat, q):
    """Δ' = P̂ Qᵀ.  p_hat: (..., n, r), q: (..., m, r) → (..., n, m)."""
    return jnp.einsum("...nr,...mr->...nm", p_hat, q)


def ef_apply(x, mom, p_hat, q, lr, lam):
    """Fused decompress + momentum + parameter update (Alg. 2 lines 11-13).

        Δ'   = P̂ Qᵀ
        mom' = λ·mom + Δ'
        x'   = x − lr·(Δ' + mom')

    Returns (x', mom')."""
    delta = jnp.einsum("...nr,...mr->...nm", p_hat, q)
    new_mom = lam * mom + delta
    new_x = x - lr * (delta + new_mom)
    return new_x, new_mom


# ---------------------------------------------------------------------------
# quantized wire formats (ISSUE 9): symmetric scale + int4 nibble packing
# ---------------------------------------------------------------------------

def quant_scale(x, qmax):
    """Symmetric per-array quantization scale: max|x| / qmax.

    Zero-guarded: an all-zero array gets scale 1.0 so quantize/dequantize
    stay finite (the payload is all zeros either way)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    return jnp.where(absmax > 0, absmax / qmax, jnp.float32(1.0))


def quantize(x, scale, qmax):
    """round-to-nearest symmetric quantization → int8 codes in [-qmax, qmax].

    With ``scale = max|x|/qmax`` no input lands outside the code range, so
    the clip is a guard, not a bias source, and the elementwise error is
    bounded by scale/2."""
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, -qmax, qmax).astype(jnp.int8)


def dequantize(q, scale, dtype=jnp.float32):
    """Inverse of :func:`quantize`: codes × scale."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def nibble_pack(q):
    """Pack int4 codes (int8 values in [-8, 7], flat) two-per-byte.

    Even indices go to the low nibble, odd indices to the high nibble; an
    odd-length tail is padded with one zero code.  Returns uint8 of length
    ceil(n/2)."""
    n = q.shape[-1]
    half = (n + 1) // 2
    qp = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, 2 * half - n)])
    u = qp.astype(jnp.uint8) & 0xF            # two's-complement nibble
    lo = u[..., 0::2]
    hi = u[..., 1::2]
    return lo | (hi << 4)


def nibble_unpack(packed, n):
    """Inverse of :func:`nibble_pack`: uint8 bytes → n int4 codes (int8).

    Sign-extends each nibble (codes ≥ 8 map to code − 16) and drops the
    padding code when ``n`` is odd."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    inter = jnp.stack([lo, hi], axis=-1).reshape(
        packed.shape[:-1] + (2 * packed.shape[-1],))
    return inter[..., :n].astype(jnp.int8)
