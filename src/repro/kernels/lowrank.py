"""Pallas TPU kernels for the PowerSGD hot loop: the two tall-skinny
matmuls P = M Q and Q = Mᵀ P̂ over every gradient matrix, every step.

TPU adaptation: the gradient matrix M streams HBM→VMEM in (block_n ×
block_k) tiles; the skinny factor (rank r ≤ 32) is padded to the 128-lane
MXU width and kept resident in VMEM across the reduction dimension of the
grid.  fp32 accumulation in the output block.

Batched operation (the bucketed compression engine's hot path): 3-D inputs
``(B, n, k)`` run through kernels with a *leading batch grid dimension* —
grid ``(B, n/bn, k/bk)`` with block size 1 on the batch axis — so one
``pallas_call`` covers a whole shape bucket instead of dispatching one
kernel per matrix (vmap would trace B copies; the batch grid dim is a
single program).  Higher-rank inputs are flattened into the batch dim.

Validated in interpret mode against :mod:`repro.kernels.ref` (the CPU
container cannot execute Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128          # MXU/VPU lane width: pad the rank dim up to this
DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_K = 512


def _project_kernel(m_ref, q_ref, o_ref):
    """Grid (n/bn, k/bk): o[i] += m[i,j] @ q[j]."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(m_ref[...], q_ref[...],
                          preferred_element_type=jnp.float32)


def _project_2d(m, q, block_n, block_k, interpret):
    n, k = m.shape
    _, r = q.shape
    bn = min(block_n, n)
    bk = min(block_k, k)
    # pad every dim to its block/lane multiple (zero rows/cols are exact)
    np_, kp, rp = (-n) % bn + n, (-k) % bk + k, (-r) % LANE + r
    mp = jnp.pad(m, ((0, np_ - n), (0, kp - k)))
    qp = jnp.pad(q, ((0, kp - k), (0, rp - r)))
    out = pl.pallas_call(
        _project_kernel,
        grid=(np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bk, rp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, rp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, rp), jnp.float32),
        interpret=interpret,
    )(mp, qp)
    return out[:n, :r].astype(m.dtype)


def _backproject_kernel(m_ref, p_ref, o_ref):
    """Grid (k/bk, n/bn): o[i] += m[j,i]ᵀ @ p[j]."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(m_ref[...].T, p_ref[...],
                          preferred_element_type=jnp.float32)


def _backproject_2d(m, p_hat, block_n, block_k, interpret):
    n, k = m.shape
    _, r = p_hat.shape
    bk = min(block_k, k)
    bn = min(block_n, n)
    np_, kp, rp = (-n) % bn + n, (-k) % bk + k, (-r) % LANE + r
    mp = jnp.pad(m, ((0, np_ - n), (0, kp - k)))
    pp = jnp.pad(p_hat, ((0, np_ - n), (0, rp - r)))
    out = pl.pallas_call(
        _backproject_kernel,
        grid=(kp // bk, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j: (j, i)),
            pl.BlockSpec((bn, rp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bk, rp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, rp), jnp.float32),
        interpret=interpret,
    )(mp, pp)
    return out[:k, :r].astype(m.dtype)


def _project_kernel_batched(m_ref, q_ref, o_ref):
    """Grid (B, n/bn, k/bk): o[b, i] += m[b, i, j] @ q[b, j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(m_ref[0], q_ref[0],
                          preferred_element_type=jnp.float32)[None]


def _project_3d(m, q, block_n, block_k, interpret):
    b, n, k = m.shape
    _, _, r = q.shape
    bn = min(block_n, n)
    bk = min(block_k, k)
    np_, kp, rp = (-n) % bn + n, (-k) % bk + k, (-r) % LANE + r
    mp = jnp.pad(m, ((0, 0), (0, np_ - n), (0, kp - k)))
    qp = jnp.pad(q, ((0, 0), (0, kp - k), (0, rp - r)))
    out = pl.pallas_call(
        _project_kernel_batched,
        grid=(b, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((1, bn, bk), lambda b_, i, j: (b_, i, j)),
            pl.BlockSpec((1, bk, rp), lambda b_, i, j: (b_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, rp), lambda b_, i, j: (b_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, np_, rp), jnp.float32),
        interpret=interpret,
    )(mp, qp)
    return out[:, :n, :r].astype(m.dtype)


def _backproject_kernel_batched(m_ref, p_ref, o_ref):
    """Grid (B, k/bk, n/bn): o[b, i] += m[b, j, i]ᵀ @ p[b, j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(m_ref[0].T, p_ref[0],
                          preferred_element_type=jnp.float32)[None]


def _backproject_3d(m, p_hat, block_n, block_k, interpret):
    b, n, k = m.shape
    _, _, r = p_hat.shape
    bk = min(block_k, k)
    bn = min(block_n, n)
    np_, kp, rp = (-n) % bn + n, (-k) % bk + k, (-r) % LANE + r
    mp = jnp.pad(m, ((0, 0), (0, np_ - n), (0, kp - k)))
    pp = jnp.pad(p_hat, ((0, 0), (0, np_ - n), (0, rp - r)))
    out = pl.pallas_call(
        _backproject_kernel_batched,
        grid=(b, kp // bk, np_ // bn),
        in_specs=[
            pl.BlockSpec((1, bn, bk), lambda b_, i, j: (b_, j, i)),
            pl.BlockSpec((1, bn, rp), lambda b_, i, j: (b_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bk, rp), lambda b_, i, j: (b_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kp, rp), jnp.float32),
        interpret=interpret,
    )(mp, pp)
    return out[:, :k, :r].astype(m.dtype)


def _batched(fn2d, fn3d):
    """Route by rank: 2-D → single-matrix kernel; ≥3-D → flatten the leading
    dims into the kernels' batch grid dimension (one pallas_call per call,
    however many matrices the bucket holds)."""

    @functools.wraps(fn2d)
    def wrapped(m, other, *, block_n=DEFAULT_BLOCK_N, block_k=DEFAULT_BLOCK_K,
                interpret=None):
        if interpret is None:
            interpret = jax.default_backend() == "cpu"
        if m.ndim == 2:
            return fn2d(m, other, block_n=block_n, block_k=block_k,
                        interpret=interpret)
        batch = m.shape[:-2]
        mf = m.reshape((-1,) + m.shape[-2:])
        of = other.reshape((-1,) + other.shape[-2:])
        out = fn3d(mf, of, block_n=block_n, block_k=block_k,
                   interpret=interpret)
        return out.reshape(batch + out.shape[-2:])

    return wrapped


lowrank_project = _batched(_project_2d, _project_3d)
lowrank_backproject = _batched(_backproject_2d, _backproject_3d)
