"""Pallas TPU kernels for the PowerSGD hot loop.

  * lowrank.py  — P = M Q and Q = Mᵀ P̂ tall-skinny matmuls (VMEM tiled)
  * ef_apply.py — fused decompress + momentum + parameter update
  * ops.py      — jit'd public wrappers
  * ref.py      — pure-jnp oracles for the allclose tests
"""
