"""Pallas TPU kernels for the PowerSGD hot loop.

  * lowrank.py  — P = M Q and Q = Mᵀ P̂ tall-skinny matmuls (VMEM tiled).
                  2-D inputs use a (n/bn, k/bk) grid; 3-D inputs — the
                  bucketed engine's (B, n, m) shape-bucket slabs — add a
                  leading batch grid dimension so one ``pallas_call``
                  covers the whole bucket.
  * ef_apply.py — fused decompress + momentum + parameter update
  * ops.py      — jit'd public wrappers (`lowrank_project`,
                  `lowrank_backproject`, `ef_apply`); rank-polymorphic over
                  leading batch dims
  * ref.py      — pure-jnp oracles for the allclose tests; every oracle is
                  batched over leading dims exactly like the kernels

All kernels accumulate in fp32 and are validated in interpret mode against
``ref.py`` on CPU (the container cannot execute Mosaic); on TPU the same
code path compiles to MXU matmuls with the rank dim padded to the 128 lane
width.
"""
