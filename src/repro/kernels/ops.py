"""jit'd public wrappers around the Pallas kernels, plus the tree-level
fused EF apply used by the error-feedback optimizer."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ef_apply as _ef
from repro.kernels import lowrank as _lr


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def lowrank_project(m, q, block_n=_lr.DEFAULT_BLOCK_N,
                    block_k=_lr.DEFAULT_BLOCK_K, interpret=None):
    """P = M Q (batched)."""
    return _lr.lowrank_project(m, q, block_n=block_n, block_k=block_k,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def lowrank_backproject(m, p_hat, block_n=_lr.DEFAULT_BLOCK_N,
                        block_k=_lr.DEFAULT_BLOCK_K, interpret=None):
    """Q = Mᵀ P̂ (batched)."""
    return _lr.lowrank_backproject(m, p_hat, block_n=block_n,
                                   block_k=block_k, interpret=interpret)


def ef_apply(x, mom, p_hat, q, lr, lam, **kw):
    """Fused decompress + momentum + param update for one matrix."""
    return _ef.ef_apply(x, mom, p_hat, q, lr, lam, **kw)


def ef_apply_tree(params, agg, momentum_state, *, lr, momentum):
    """Tree-level EF apply: the per-matrix fused kernel needs the (P̂, Q)
    factors; when only the dense aggregate is available (as at the generic
    compressor interface), apply the unfused update."""
    new_momentum = jax.tree_util.tree_map(
        lambda m, d: momentum * m + d, momentum_state, agg)
    new_params = jax.tree_util.tree_map(
        lambda x, d, m: x - lr * (d + m), params, agg, new_momentum)
    return new_params, new_momentum
