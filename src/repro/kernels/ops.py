"""jit'd public wrappers around the Pallas kernels, plus the tree-level
fused EF apply used by the error-feedback optimizer."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ef_apply as _ef
from repro.kernels import lowrank as _lr
from repro.kernels import quant as _quant
from repro.kernels import ref as _ref


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def lowrank_project(m, q, block_n=_lr.DEFAULT_BLOCK_N,
                    block_k=_lr.DEFAULT_BLOCK_K, interpret=None):
    """P = M Q (batched)."""
    return _lr.lowrank_project(m, q, block_n=block_n, block_k=block_k,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def lowrank_backproject(m, p_hat, block_n=_lr.DEFAULT_BLOCK_N,
                        block_k=_lr.DEFAULT_BLOCK_K, interpret=None):
    """Q = Mᵀ P̂ (batched)."""
    return _lr.lowrank_backproject(m, p_hat, block_n=block_n,
                                   block_k=block_k, interpret=interpret)


def ef_apply(x, mom, p_hat, q, lr, lam, **kw):
    """Fused decompress + momentum + param update for one matrix."""
    return _ef.ef_apply(x, mom, p_hat, q, lr, lam, **kw)


def nibble_pack(q, *, use_pallas=None, interpret=None):
    """Pack flat int4 codes two-per-byte (ISSUE 9 wire format).

    Routes to the Pallas kernel on accelerators and to the pure-jnp
    reference on CPU/test substrates (the reference is also vmap-safe, which
    the SimMesh W-worker substrate relies on).  The two paths are pinned
    bit-identical by ``tests/test_wire_quant.py``."""
    if use_pallas is None:
        use_pallas = jax.default_backend() != "cpu"
    if use_pallas:
        return _quant.nibble_pack(q, interpret=interpret)
    return _ref.nibble_pack(q)


def nibble_unpack(packed, n, *, use_pallas=None, interpret=None):
    """Inverse of :func:`nibble_pack` — same Pallas/reference routing."""
    if use_pallas is None:
        use_pallas = jax.default_backend() != "cpu"
    if use_pallas:
        return _quant.nibble_unpack(packed, n, interpret=interpret)
    return _ref.nibble_unpack(packed, n)


def ef_apply_tree(params, agg, momentum_state, *, lr, momentum):
    """Tree-level EF apply: the per-matrix fused kernel needs the (P̂, Q)
    factors; when only the dense aggregate is available (as at the generic
    compressor interface), apply the unfused update."""
    new_momentum = jax.tree_util.tree_map(
        lambda m, d: momentum * m + d, momentum_state, agg)
    new_params = jax.tree_util.tree_map(
        lambda x, d, m: x - lr * (d + m), params, agg, new_momentum)
    return new_params, new_momentum
