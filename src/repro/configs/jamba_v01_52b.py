"""Jamba-v0.1 (52B total) — hybrid Mamba+attention 1:7 interleave with MoE
on every other FFN, 16 experts top-2 [arXiv:2403.19887].

Note: Jamba uses Mamba-1 internally (ssm_state=16); we model the mixer with
our SSD layer at the same state size (DESIGN.md §Arch-applicability).
"""

from repro.configs.base import LayerSlot, ModelConfig


def _slots(period: int, attn_at: int):
    return tuple(
        LayerSlot("attn" if i == attn_at else "mamba",
                  "moe" if i % 2 == 1 else "dense")
        for i in range(period)
    )


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        arch_type="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        moe_num_experts=16,
        moe_top_k=2,
        ssm_state=16,
        ssm_head_dim=64,
        ssm_expand=2,
        slots=_slots(8, attn_at=4),
        source="arXiv:2403.19887",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-reduced",
        arch_type="hybrid",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=1024,
        moe_num_experts=4,
        moe_top_k=2,
        ssm_state=16,
        ssm_head_dim=32,
        ssm_expand=2,
        slots=(LayerSlot("mamba", "dense"), LayerSlot("attn", "moe")),
        source="arXiv:2403.19887",
    )
