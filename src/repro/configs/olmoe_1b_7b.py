"""OLMoE-1B-7B — MoE with 64 experts top-8 [arXiv:2409.02060]."""

from repro.configs.base import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        arch_type="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab_size=50304,
        rope_theta=10000.0,
        decode_window=16384,
        moe_num_experts=64,
        moe_top_k=8,
        slots=(LayerSlot("attn", "moe"),),
        source="arXiv:2409.02060",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-reduced",
        arch_type="moe",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=128,
        vocab_size=1024,
        rope_theta=10000.0,
        decode_window=64,
        moe_num_experts=4,
        moe_top_k=2,
        slots=(LayerSlot("attn", "moe"),),
        source="arXiv:2409.02060",
    )
