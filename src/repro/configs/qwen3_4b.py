"""Qwen3-4B — dense GQA with qk-norm [hf:Qwen/Qwen3-8B family]."""

from repro.configs.base import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        arch_type="dense",
        num_layers=36,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1000000.0,
        decode_window=16384,
        slots=(LayerSlot("attn", "dense"),),
        source="hf:Qwen/Qwen3-8B",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b-reduced",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=1024,
        qk_norm=True,
        rope_theta=1000000.0,
        decode_window=64,
        slots=(LayerSlot("attn", "dense"),),
        source="hf:Qwen/Qwen3-8B",
    )
