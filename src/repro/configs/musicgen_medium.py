"""MusicGen-medium — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284].  The EnCodec codec is the (stubbed) frontend: the
decoder consumes discrete audio tokens (vocab 2048); the codebook delay
pattern is a data-layout detail outside the backbone."""

from repro.configs.base import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        arch_type="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        rope_theta=10000.0,
        decode_window=16384,
        slots=(LayerSlot("attn", "dense"),),
        source="arXiv:2306.05284",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-reduced",
        arch_type="audio",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        rope_theta=10000.0,
        decode_window=64,
        slots=(LayerSlot("attn", "dense"),),
        source="arXiv:2306.05284",
    )
