"""Yi-6B — llama-arch dense GQA [arXiv:2403.04652]."""

from repro.configs.base import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b",
        arch_type="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64000,
        rope_theta=5000000.0,
        decode_window=16384,
        slots=(LayerSlot("attn", "dense"),),
        source="arXiv:2403.04652",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b-reduced",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=1024,
        rope_theta=5000000.0,
        decode_window=64,
        slots=(LayerSlot("attn", "dense"),),
        source="arXiv:2403.04652",
    )
