"""Model / run configuration dataclasses and the architecture registry."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerSlot:
    """One layer inside a period group: a sequence mixer + a feed-forward."""

    mixer: str  # "attn" | "mamba"
    ffn: str    # "dense" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | ssm | hybrid | moe | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    source: str = ""                    # citation for the config

    # period structure: the model is num_layers/len(slots) repetitions of slots
    slots: Tuple[LayerSlot, ...] = (LayerSlot("attn", "dense"),)

    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_aux_weight: float = 0.01

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4

    # attention details
    qk_norm: bool = False
    rope_theta: float = 500000.0
    sliding_window: int = 0             # 0 = full attention (training/prefill)
    decode_window: int = 0              # 0 = full KV cache in decode
    # perf (beyond-paper): skip the K/V all-gather when kv heads shard
    # evenly over the model axis (each shard's q heads only read its own
    # kv heads).  Requires num_heads % shards == num_kv_heads % shards == 0.
    tp_local_kv: bool = False
    # perf (beyond-paper): GQA-aware decode attention — group q heads by kv
    # head in the einsum instead of materializing the kv cache expanded to
    # every q head.  Requires num_heads % num_kv_heads == 0 and no head
    # padding on the mesh in use.
    gqa_grouped_decode: bool = False

    # modality frontend stub (audio/vlm): precomputed embeddings in
    frontend: Optional[str] = None      # None | "audio" | "vision"
    frontend_dim: int = 0

    dtype: str = "float32"

    # ------------------------------------------------------------------
    @property
    def period(self) -> int:
        return len(self.slots)

    @property
    def num_periods(self) -> int:
        assert self.num_layers % self.period == 0, (self.name, self.num_layers, self.period)
        return self.num_layers // self.period

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def jnp_dtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = 2 * self.vocab_size * d  # embed + head
        per_period = 0
        for s in self.slots:
            if s.mixer == "attn":
                per_period += d * self.num_heads * hd            # wq
                per_period += 2 * d * self.num_kv_heads * hd      # wk, wv
                per_period += self.num_heads * hd * d             # wo
            elif s.mixer == "mamba":
                di, n = self.ssm_d_inner, self.ssm_state
                g = 1
                per_period += d * (2 * di + 2 * g * n + self.ssm_heads)  # in_proj
                per_period += di * d                                      # out_proj
            if s.ffn == "dense":
                per_period += 3 * d * self.d_ff
            elif s.ffn == "moe":
                per_period += 3 * d * self.d_ff * self.moe_num_experts
                per_period += d * self.moe_num_experts
        return total + per_period * self.num_periods

    def active_param_count(self) -> int:
        """Parameters active per token (MoE: top-k experts only)."""
        if not self.moe_num_experts:
            return self.param_count()
        d = self.d_model
        dense_moe_delta = 3 * d * self.d_ff * (self.moe_num_experts - self.moe_top_k)
        n_moe_layers = sum(1 for s in self.slots if s.ffn == "moe") * self.num_periods
        return self.param_count() - dense_moe_delta * n_moe_layers


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


ARCH_IDS = [
    "llama3_8b",
    "mamba2_1p3b",
    "jamba_v01_52b",
    "musicgen_medium",
    "llava_next_34b",
    "qwen3_moe_30b_a3b",
    "codeqwen15_7b",
    "olmoe_1b_7b",
    "qwen3_4b",
    "yi_6b",
]

# CLI aliases with the assignment's original ids
ARCH_ALIASES = {
    "llama3-8b": "llama3_8b",
    "mamba2-1.3b": "mamba2_1p3b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "musicgen-medium": "musicgen_medium",
    "llava-next-34b": "llava_next_34b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen3-4b": "qwen3_4b",
    "yi-6b": "yi_6b",
}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    arch = ARCH_ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.reduced_config() if reduced else mod.config()
