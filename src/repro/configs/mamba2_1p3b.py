"""Mamba2-1.3B — attention-free SSM with SSD [arXiv:2405.21060]."""

from repro.configs.base import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        arch_type="ssm",
        num_layers=48,
        d_model=2048,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        slots=(LayerSlot("mamba", "none"),),
        source="arXiv:2405.21060",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b-reduced",
        arch_type="ssm",
        num_layers=2,
        d_model=256,
        d_ff=0,
        vocab_size=1024,
        ssm_state=16,
        ssm_head_dim=32,
        ssm_expand=2,
        slots=(LayerSlot("mamba", "none"),),
        source="arXiv:2405.21060",
    )
