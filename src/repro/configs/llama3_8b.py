"""Llama-3-8B — dense, GQA, 128k vocab [arXiv:2407.21783]."""

from repro.configs.base import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b",
        arch_type="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=128256,
        rope_theta=500000.0,
        decode_window=16384,   # sliding-window variant for long_500k decode
        slots=(LayerSlot("attn", "dense"),),
        source="arXiv:2407.21783",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b-reduced",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=1024,
        rope_theta=500000.0,
        decode_window=64,
        slots=(LayerSlot("attn", "dense"),),
        source="arXiv:2407.21783",
    )
