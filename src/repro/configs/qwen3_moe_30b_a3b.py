"""Qwen3-30B-A3B — fine-grained MoE, 128 experts top-8, qk-norm
[hf:Qwen/Qwen3-30B-A3B]."""

from repro.configs.base import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        arch_type="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1000000.0,
        decode_window=16384,
        moe_num_experts=128,
        moe_top_k=8,
        slots=(LayerSlot("attn", "moe"),),
        source="hf:Qwen/Qwen3-30B-A3B",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b-reduced",
        arch_type="moe",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=128,
        vocab_size=1024,
        qk_norm=True,
        rope_theta=1000000.0,
        decode_window=64,
        moe_num_experts=4,
        moe_top_k=2,
        slots=(LayerSlot("attn", "moe"),),
        source="hf:Qwen/Qwen3-30B-A3B",
    )
