"""CodeQwen1.5-7B — dense, MHA-style GQA (kv=heads) [hf:Qwen/CodeQwen1.5-7B]."""

from repro.configs.base import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b",
        arch_type="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        head_dim=128,
        d_ff=13440,
        vocab_size=92416,
        rope_theta=1000000.0,
        decode_window=16384,
        slots=(LayerSlot("attn", "dense"),),
        source="hf:Qwen/CodeQwen1.5-7B",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b-reduced",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=1024,
        rope_theta=1000000.0,
        decode_window=64,
        slots=(LayerSlot("attn", "dense"),),
        source="hf:Qwen/CodeQwen1.5-7B",
    )
