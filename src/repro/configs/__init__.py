from repro.configs.base import (ARCH_ALIASES, ARCH_IDS, INPUT_SHAPES,
                                InputShape, LayerSlot, ModelConfig, get_config)
