"""LLaVA-NeXT-34B — VLM: anyres-tiled vision frontend (stub) + dense GQA
decoder backbone [hf:llava-hf/llava-v1.6-mistral-7b-hf].

The SigLIP/ViT tower and projector input are stubbed: ``input_specs()``
supplies precomputed patch embeddings (frontend_dim=1152); the backbone
projects and consumes them as the sequence prefix."""

from repro.configs.base import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        arch_type="vlm",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab_size=64000,
        rope_theta=5000000.0,
        decode_window=16384,
        frontend="vision",
        frontend_dim=1152,
        slots=(LayerSlot("attn", "dense"),),
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b-reduced",
        arch_type="vlm",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=1024,
        rope_theta=5000000.0,
        decode_window=64,
        frontend="vision",
        frontend_dim=96,
        slots=(LayerSlot("attn", "dense"),),
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    )
