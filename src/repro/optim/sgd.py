"""Plain SGD with momentum (the paper's full-precision baseline), and
Signum (Bernstein et al., 2019) — sign-of-momentum with majority vote —
which the paper benchmarks against (§5.2, Appendix G.5).

These are standalone optimizers (not EF compressors): Signum aggregates
1-bit gradients by majority vote instead of averaging.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.dist import MeshCtx, SINGLE


@dataclasses.dataclass
class SGDState:
    momentum: Any
    step: jax.Array


jax.tree_util.register_dataclass(
    SGDState, data_fields=["momentum", "step"], meta_fields=[])


def sgd_init(params) -> SGDState:
    return SGDState(momentum=jax.tree_util.tree_map(jnp.zeros_like, params),
                    step=jnp.zeros((), jnp.int32))


def sgd_apply(params, grads, state: SGDState, *, lr, momentum=0.9,
              weight_decay=0.0, ctx: MeshCtx = SINGLE):
    """Synchronous data-parallel SGD: all-reduce mean of raw gradients."""
    grads = jax.tree_util.tree_map(ctx.pmean_data, grads)
    if weight_decay:
        grads = jax.tree_util.tree_map(
            lambda g, p: g + weight_decay * p, grads, params)
    new_m = jax.tree_util.tree_map(
        lambda m, g: momentum * m + g, state.momentum, grads)
    new_p = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, new_m)
    return new_p, SGDState(momentum=new_m, step=state.step + 1)


@dataclasses.dataclass
class SignumState:
    momentum: Any
    step: jax.Array


jax.tree_util.register_dataclass(
    SignumState, data_fields=["momentum", "step"], meta_fields=[])


def signum_init(params) -> SignumState:
    return SignumState(momentum=jax.tree_util.tree_map(jnp.zeros_like, params),
                       step=jnp.zeros((), jnp.int32))


def signum_apply(params, grads, state: SignumState, *, lr, momentum=0.9,
                 ctx: MeshCtx = SINGLE):
    """Signum: per-worker momentum, sign compression, majority-vote
    aggregation (psum of ±1, then sign).  Not linear ⇒ all-gather in the
    paper; on TPU the vote maps onto a psum of int8 signs."""
    new_m = jax.tree_util.tree_map(
        lambda m, g: momentum * m + (1 - momentum) * g, state.momentum, grads)
    votes = jax.tree_util.tree_map(lambda m: ctx.psum_data(jnp.sign(m)), new_m)
    new_p = jax.tree_util.tree_map(
        lambda p, v: p - lr * jnp.sign(v), params, votes)
    return new_p, SignumState(momentum=new_m, step=state.step + 1)
