from repro.optim.sgd import (SGDState, sgd_init, sgd_apply,
                             SignumState, signum_init, signum_apply)
from repro.optim import schedules
