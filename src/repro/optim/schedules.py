"""Learning-rate schedules (paper §5: linear warmup from the 1-worker rate,
/10 step decay; plus cosine for the Appendix-D transformer recipe)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, base_lr, warmup_steps, start_frac):
    """Linear warmup from start_frac·base_lr to base_lr (paper: 1/W → 1)."""
    frac = jnp.clip(step / jnp.maximum(warmup_steps, 1), 0.0, 1.0)
    return base_lr * (start_frac + (1.0 - start_frac) * frac)


def step_decay(step, lr, milestones, factor=0.1):
    """Divide by 1/factor at each milestone (paper: /10 at epochs 150, 250)."""
    for m in milestones:
        lr = jnp.where(step >= m, lr * factor, lr)
    return lr


def cosine(step, base_lr, total_steps, min_frac=0.0):
    t = jnp.clip(step / jnp.maximum(total_steps, 1), 0.0, 1.0)
    return base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))


def paper_cifar_schedule(step, base_lr, num_workers, steps_per_epoch):
    """The paper's full CIFAR10 recipe: 5-epoch linear warmup from the
    single-worker LR to W× that, then /10 at epochs 150 and 250."""
    lr = linear_warmup(step, base_lr * num_workers,
                       5 * steps_per_epoch, 1.0 / num_workers)
    return step_decay(step, lr, (150 * steps_per_epoch, 250 * steps_per_epoch))
