"""The gradlint jaxpr passes: collective-budget, wire-dtype, determinism.

Each pass is a function ``(artifact: TraceArtifact, ...) -> List[Finding]``
over one traced step (:func:`repro.analysis.tracing.trace_compress_step`).
They never execute anything — all evidence comes from the closed jaxpr, the
equation source provenance, and the trace-time ``CollectiveStats`` records.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding
from repro.analysis.tracing import (CollectiveSite, TraceArtifact, iter_eqns)

# pack-path primitives: ops that merely move/reshape payload bytes between a
# producer and the wire.  The wire-dtype pass slices backwards from each
# collective operand through exactly these (plus convert_element_type,
# which it inspects) — anything else ends the slice.
_PACK_OPS = frozenset({
    "concatenate", "reshape", "broadcast_in_dim", "squeeze", "transpose",
    "pad", "slice", "dynamic_slice", "rev", "copy", "expand_dims",
    "convert_element_type", "pjit",
})

_FLOAT_WIDTHS = {"bfloat16": 2, "float16": 2, "float32": 4, "float64": 8}


# ---------------------------------------------------------------------------
# 1. collective-budget
# ---------------------------------------------------------------------------


def check_budget(artifact: TraceArtifact,
                 budget: Tuple[int, int, int],
                 scheme: str = "") -> List[Finding]:
    """Statically verify the documented per-scheme collective budget and
    cross-check the jaxpr ledger against the CollectiveStats ledger.

    ``budget`` is the scheme's declared ``(total, reduce, gather)``
    (:meth:`repro.core.compressors.Compressor.declared_budget`).  Neither
    accounting path is trusted alone: the jaxpr count proves what the
    compiled program will actually execute; the stats count is what the
    byte/bandwidth models and the runtime budget guards consume — if either
    rots, GL102 fires.
    """
    findings: List[Finding] = []
    label = artifact.label or scheme

    # -- attribution: every data-axis collective must come from dist.py ----
    logical: List[CollectiveSite] = []
    for site in artifact.sites:
        if site.entry is None:
            findings.append(Finding(
                rule="GL103", pass_name="budget",
                message=f"{label}: data-axis {site.primitive} issued outside "
                        "the repro.core.dist entry points — hand-rolled "
                        "collectives escape budget and byte accounting",
                provenance=site.provenance()))
        elif not site.is_scale_sidecar:
            logical.append(site)

    n_reduce = sum(1 for s in logical if s.kind == "reduce")
    n_gather = sum(1 for s in logical if s.kind == "gather")
    n_bcast = sum(1 for s in logical if s.kind == "broadcast")
    total, max_reduce, max_gather = budget

    # -- the documented budget (the paper's O(1) claim, statically) --------
    # Under sync_mode="broadcast" every reduce records one extra broadcast
    # accounting leg (or one fused end-of-step broadcast) that is not part
    # of the scheme's algorithmic budget; the budget is checked on the
    # allreduce trace where collectives and budget are 1:1.
    if artifact.sync_mode == "allreduce":
        if n_reduce + n_gather > total or n_reduce > max_reduce \
                or n_gather > max_gather:
            findings.append(Finding(
                rule="GL101", pass_name="budget",
                message=f"{label}: traced step issues {n_reduce} reduce + "
                        f"{n_gather} gather fused collectives, documented "
                        f"budget is {max_reduce}+{max_gather} "
                        f"(total {total})",
                provenance="; ".join(s.provenance() for s in logical)))
        elif n_reduce + n_gather < total:
            findings.append(Finding(
                rule="GL104", pass_name="budget",
                message=f"{label}: traced step issues only "
                        f"{n_reduce}+{n_gather} collectives against a "
                        f"documented budget of {max_reduce}+{max_gather} — "
                        "scheme and budget table have diverged",
                provenance="; ".join(s.provenance() for s in logical)))

    # -- static-vs-stats cross-check ---------------------------------------
    stats = artifact.stats
    stat_reduce = sum(1 for k in stats.kinds if k == "reduce")
    stat_gather = sum(1 for k in stats.kinds if k == "gather")
    stat_bcast = sum(1 for k in stats.kinds if k == "broadcast")
    # Under sync_mode="broadcast" a reduce's broadcast *accounting* leg
    # (recorded so wire-cost models price the one-to-all delivery) shares
    # the canonical reduce's single all_gather primitive — the jaxpr holds
    # no extra collective for it.  Standalone broadcast_flat legs do lower
    # to a masked psum each, and those the jaxpr must show.
    expect_bcast = stat_bcast if artifact.sync_mode == "allreduce" else \
        sum(1 for s in logical if s.kind == "broadcast")
    if (n_reduce, n_gather, n_bcast) != (stat_reduce, stat_gather,
                                         expect_bcast):
        findings.append(Finding(
            rule="GL102", pass_name="budget",
            message=f"{label}: jaxpr ledger (reduce={n_reduce}, "
                    f"gather={n_gather}, broadcast={n_bcast}) disagrees "
                    f"with CollectiveStats (reduce={stat_reduce}, "
                    f"gather={stat_gather}, broadcast={stat_bcast}, "
                    f"sync_mode={artifact.sync_mode})",
            provenance="; ".join(s.provenance() for s in logical)))
    return findings


# ---------------------------------------------------------------------------
# 2. wire-dtype discipline
# ---------------------------------------------------------------------------


def _collect_pack_slice(jaxpr, wire_vars: Set) -> Tuple[List, Set]:
    """Backward slice from collective operands through the pack whitelist.

    Returns the equations on the pack path (producers of payload bytes)
    and the set of variables on it.  The walk is over the flat equation
    list of each (sub)jaxpr in reverse program order — cheap and exact
    enough for straight-line pack/quantize code.
    """
    eqns = list(iter_eqns(jaxpr))
    on_path = set(wire_vars)
    sliced = []
    for eqn in reversed(eqns):
        if not any(v in on_path for v in eqn.outvars):
            continue
        if eqn.primitive.name not in _PACK_OPS:
            continue
        sliced.append(eqn)
        for v in eqn.invars:
            if not isinstance(v, jax.core.Literal):
                on_path.add(v)
    return sliced, on_path


def check_wire_dtypes(artifact: TraceArtifact,
                      scheme: str = "") -> List[Finding]:
    """Wire-dtype discipline on the payload pack paths.

    * **GL201** — a float→wider-float ``convert_element_type`` on the pack
      path feeding a collective: the PR 3 bug class, where one float32
      straggler silently promoted a whole bfloat16 payload to a 4-byte
      wire.  Integer→float converts are exempt: that is the *sanctioned*
      widened accumulator of the quantized reduce path
      (``MeshCtx.pmean_flat``: quantize → dequantize to float32 → plain
      all-reduce).
    * **GL202** — an integer-dtype buffer as a data-axis ``psum`` operand:
      int8/int4 slots must never reach a reduce unwidened (integer
      overflow wraps silently at W ≥ 2).
    """
    findings: List[Finding] = []
    label = artifact.label or scheme

    psum_wire_vars = set()
    gather_wire_vars = set()
    for eqn in iter_eqns(artifact.closed_jaxpr.jaxpr):
        name = eqn.primitive.name
        if name not in ("psum", "all_gather"):
            continue
        for v in eqn.invars:
            if isinstance(v, jax.core.Literal):
                continue
            aval = v.aval
            if name == "psum":
                psum_wire_vars.add(v)
                if jnp.issubdtype(aval.dtype, jnp.integer) or \
                        jnp.issubdtype(aval.dtype, jnp.bool_):
                    findings.append(Finding(
                        rule="GL202", pass_name="wire-dtype",
                        message=f"{label}: {aval.dtype} buffer reaches a "
                                "data-axis psum unwidened — quantized "
                                "payloads must dequantize into a float "
                                "accumulator before any reduce",
                        provenance=CollectiveSite(
                            primitive=name, axes=(), dtype=str(aval.dtype),
                            size=int(aval.size),
                            chain=_chain_of(eqn)).provenance()))
            else:
                gather_wire_vars.add(v)

    sliced, _ = _collect_pack_slice(
        artifact.closed_jaxpr.jaxpr, psum_wire_vars | gather_wire_vars)
    for eqn in sliced:
        if eqn.primitive.name != "convert_element_type":
            continue
        src = eqn.invars[0].aval.dtype
        dst = eqn.outvars[0].aval.dtype
        src_w = _FLOAT_WIDTHS.get(str(src))
        dst_w = _FLOAT_WIDTHS.get(str(dst))
        if src_w is not None and dst_w is not None and dst_w > src_w:
            findings.append(Finding(
                rule="GL201", pass_name="wire-dtype",
                message=f"{label}: {src} payload widened to {dst} on the "
                        "pack path before a collective — a narrower part "
                        "is riding a wider wire (the mixed-dtype upcast "
                        "footgun)",
                provenance=CollectiveSite(
                    primitive="convert_element_type", axes=(),
                    dtype=f"{src}->{dst}", size=int(eqn.outvars[0].aval.size),
                    chain=_chain_of(eqn)).provenance()))
    return findings


def _chain_of(eqn):
    from repro.analysis.tracing import provenance_chain
    return provenance_chain(eqn)


# ---------------------------------------------------------------------------
# 3. determinism
# ---------------------------------------------------------------------------

_SEED_PRIMS = frozenset({"random_seed", "threefry2x32_seed", "rng_bit_generator"})


def check_determinism(artifact: TraceArtifact,
                      scheme: str = "") -> List[Finding]:
    """Replica-determinism discipline in the traced step.

    * **GL301** — a PRNG key constructed from a constant inside the trace
      (``random_seed`` on a literal/constant operand).  Keys must enter as
      step arguments and derive via ``fold_in`` (``random_fold_in``) — an
      in-trace constant seed makes every step draw the same stream, and a
      rank-dependent one desynchronizes replicas on retrace.
    * **GL302** — under ``sync_mode="broadcast"`` a data-axis ``psum``
      whose call chain is not the masked ``broadcast0`` delivery.  The PR 6
      drift class: a raw psum's reduction order is substrate-defined, so
      replicas (and SimMesh-vs-shard_map reruns) may disagree in the last
      ULP; certified reductions lower to the canonical all_gather +
      pairwise-tree replay (``_canonical_reduce``) instead.
    """
    findings: List[Finding] = []
    label = artifact.label or scheme

    # variables produced from the jaxpr's own arguments (a key that *enters*
    # the trace is fine; one seeded inside it is not)
    for eqn in iter_eqns(artifact.closed_jaxpr.jaxpr):
        if eqn.primitive.name in _SEED_PRIMS:
            chain = _chain_of(eqn)
            findings.append(Finding(
                rule="GL301", pass_name="determinism",
                message=f"{label}: PRNG key seeded inside the traced step "
                        f"({eqn.primitive.name}) — pass keys in as "
                        "arguments and derive per-step keys with fold_in",
                provenance=CollectiveSite(
                    primitive=eqn.primitive.name, axes=(), dtype="key",
                    size=0, chain=chain).provenance()))

    if artifact.sync_mode == "broadcast":
        for site in artifact.sites:
            if site.primitive != "psum":
                continue
            in_broadcast0 = any(
                func == "broadcast0" for _f, func, _l in site.chain)
            if not in_broadcast0:
                findings.append(Finding(
                    rule="GL302", pass_name="determinism",
                    message=f"{label}: raw data-axis psum under "
                            "sync_mode='broadcast' — reduction order is "
                            "substrate-defined; use the canonical "
                            "gather+tree-sum reduce or the masked "
                            "broadcast0 delivery",
                    provenance=site.provenance()))
    return findings


# ---------------------------------------------------------------------------
# convenience: the full jaxpr-pass pipeline over one artifact
# ---------------------------------------------------------------------------


def run_jaxpr_passes(artifact: TraceArtifact,
                     budget: Optional[Tuple[int, int, int]] = None,
                     scheme: str = "") -> List[Finding]:
    findings: List[Finding] = []
    if budget is not None:
        findings.extend(check_budget(artifact, budget, scheme))
    findings.extend(check_wire_dtypes(artifact, scheme))
    findings.extend(check_determinism(artifact, scheme))
    return findings
