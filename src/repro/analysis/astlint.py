"""gradlint source-AST rules (GLA0x) — importable and runnable without jax.

Three rules over the ``src/repro`` tree, each with a per-line escape hatch:
a trailing ``# gradlint: disable=<rule>`` comment (rule id or kebab name,
comma-separated for several) suppresses any rule on that line.

* **GLA01 host-transfer** — ``np.asarray(...)`` / ``jax.device_get(...)``
  anywhere outside ``checkpoint/``.  On a sharded array these read device
  0's shard and silently drop every other rank's content (the PR 7 bug
  class); the mesh-aware canonicalize path in ``checkpoint/`` is the one
  sanctioned home.  Deliberate host-side sites (serving output, host-only
  state dicts) carry an explicit disable comment — the escape hatch *is*
  the documentation that a transfer is intentional.
* **GLA02 prng-key-in-step** — ``jax.random.PRNGKey(...)`` or
  ``jax.random.key(...)`` inside a step function (any enclosing ``def``
  whose name contains a ``step`` component).  In-step key construction
  from a constant makes every step (and every rank that retraces) reuse
  the same stream; per-step keys must be derived with ``fold_in`` from a
  key argument.
* **GLA03 implicit-dtype-reduction** — ``jnp.sum/mean/prod`` without an
  explicit ``dtype=`` in the wire-path modules (``core/matrixize.py``,
  ``core/dist.py``), where accumulator widths decide what bytes cross the
  wire and must never be an implicit-promotion accident (the PR 3 bug
  class).
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Iterable, List, Optional, Sequence

from repro.analysis.findings import Finding, get_rule

# modules where implicit-accumulator reductions are forbidden (GLA03)
WIRE_PATH_MODULES = ("core/matrixize.py", "core/dist.py")
# directory whose canonicalize paths are the sanctioned home for host
# transfers (GLA01 does not apply there)
HOST_TRANSFER_SANCTUARY = "checkpoint/"

_DISABLE_RE = re.compile(r"#\s*gradlint:\s*disable=([\w\-,\s]+)")
_STEP_NAME_RE = re.compile(r"(^|_)step(_|$|\d)")

_REDUCTIONS = {"sum", "mean", "prod"}


def _disabled_rules(line: str) -> set:
    m = _DISABLE_RE.search(line)
    if not m:
        return set()
    out = set()
    for tok in m.group(1).split(","):
        tok = tok.strip()
        if not tok:
            continue
        try:
            rule = get_rule(tok)
            out.update({rule.id, rule.name})
        except KeyError:
            out.add(tok)
    return out


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Visitor(ast.NodeVisitor):
    def __init__(self, rel_path: str, lines: Sequence[str]):
        self.rel_path = rel_path
        self.lines = lines
        self.findings: List[Finding] = []
        self.func_stack: List[str] = []
        self.is_wire_path = any(rel_path.endswith(m)
                                for m in WIRE_PATH_MODULES)
        self.in_sanctuary = HOST_TRANSFER_SANCTUARY in rel_path

    # -- helpers -----------------------------------------------------------
    def _emit(self, rule_key: str, node: ast.AST, message: str) -> None:
        rule = get_rule(rule_key)
        line_no = getattr(node, "lineno", 0)
        src_line = self.lines[line_no - 1] if 0 < line_no <= len(self.lines) \
            else ""
        disabled = _disabled_rules(src_line)
        if rule.id in disabled or rule.name in disabled:
            return
        self.findings.append(Finding(
            rule=rule.id, message=message, file=self.rel_path, line=line_no,
            pass_name="ast", provenance=f"{self.rel_path}:{line_no}"))

    def _in_step_function(self) -> bool:
        # a factory that *builds* a step (make_train_step, build_step) or a
        # tracer that *inspects* one (trace_compress_step) is host-side
        # setup code, not the traced step body itself
        return any(_STEP_NAME_RE.search(name)
                   and not name.startswith(("make_", "build_", "trace_"))
                   for name in self.func_stack)

    # -- visitors ----------------------------------------------------------
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.func_stack.append(node.name)
        self.generic_visit(node)
        self.func_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func)
        if dotted is not None:
            self._check_host_transfer(node, dotted)
            self._check_prng_in_step(node, dotted)
            self._check_implicit_reduction(node, dotted)
        self.generic_visit(node)

    # -- rules -------------------------------------------------------------
    def _check_host_transfer(self, node: ast.Call, dotted: str) -> None:
        if self.in_sanctuary:
            return
        if dotted in ("np.asarray", "numpy.asarray", "jax.device_get",
                      "onp.asarray"):
            self._emit(
                "host-transfer", node,
                f"{dotted} outside {HOST_TRANSFER_SANCTUARY}: host "
                "transfers read device 0's shard; use the checkpoint "
                "canonicalize path, or mark a deliberate host-side site "
                "with '# gradlint: disable=host-transfer'")

    def _check_prng_in_step(self, node: ast.Call, dotted: str) -> None:
        if dotted not in ("jax.random.PRNGKey", "jax.random.key",
                          "random.PRNGKey"):
            return
        if not self._in_step_function():
            return
        self._emit(
            "prng-key-in-step", node,
            f"{dotted} inside step function "
            f"'{'.'.join(self.func_stack)}': construct keys outside the "
            "step and derive per-step keys with jax.random.fold_in")

    def _check_implicit_reduction(self, node: ast.Call, dotted: str) -> None:
        if not self.is_wire_path:
            return
        parts = dotted.split(".")
        if len(parts) != 2 or parts[0] not in ("jnp", "jny", "jax_numpy"):
            return
        if parts[1] not in _REDUCTIONS:
            return
        if any(kw.arg == "dtype" for kw in node.keywords):
            return
        self._emit(
            "implicit-dtype-reduction", node,
            f"{dotted} without explicit dtype= on a wire-path module: "
            "the accumulator width prices wire bytes — spell it out")


def lint_source(source: str, rel_path: str) -> List[Finding]:
    """Run the AST rules over one file's source text."""
    try:
        tree = ast.parse(source, filename=rel_path)
    except SyntaxError as e:  # a syntax error is its own kind of finding
        return [Finding(rule="GLA01", message=f"unparseable file: {e}",
                        file=rel_path, line=e.lineno or 0, pass_name="ast")]
    visitor = _Visitor(rel_path, source.splitlines())
    visitor.visit(tree)
    return visitor.findings


def lint_file(path: Path, root: Optional[Path] = None) -> List[Finding]:
    rel = str(path.relative_to(root)) if root else str(path)
    return lint_source(path.read_text(), rel)


def lint_tree(root: Path,
              exclude: Iterable[str] = ()) -> List[Finding]:
    """Run the AST rules over every ``.py`` file under ``root``.

    ``exclude`` holds path substrings to skip (relative to ``root``).
    """
    findings: List[Finding] = []
    for path in sorted(root.rglob("*.py")):
        rel = str(path.relative_to(root))
        if any(pat in rel for pat in exclude):
            continue
        findings.extend(lint_file(path, root))
    return findings
