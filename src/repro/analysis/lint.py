"""gradlint CLI — ``python -m repro.analysis.lint``.

Modes (composable; default = ``--matrix --ast``):

* ``--matrix`` — statically verify the documented per-scheme collective
  budgets for every zoo scheme × wire dtype × staleness mode on the
  canonical mixed gradient tree, plus wire-dtype and determinism passes on
  each trace and a broadcast-mode determinism trace per scheme.  No step
  is ever executed; everything comes from ``jax.make_jaxpr`` under an
  ``axis_env``.
* ``--config ARCH`` — run the partition-consistency pass on ARCH's full
  EF-SGD state (eval_shape only), the jaxpr passes on its traced
  compress step, and the retrace-stability pass across a rank staircase.
  Repeatable; ``--config all`` covers the whole registry.
* ``--ast`` / ``--ast-only`` — the source-AST rules over ``src/repro``
  (``--ast-only`` never imports jax, so it runs in the jax-free docs CI
  job).

``--json`` emits machine-readable findings; exit status is 1 iff any
error-severity finding was produced.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.findings import Report

# the zoo × wire × staleness budget matrix (ISSUE acceptance criteria)
ZOO_SCHEMES = (
    "identity", "powersgd", "powersgd_cold", "powersgd_best_approx",
    "unbiased_rank_k", "random_block", "random_k", "sign_norm", "top_k",
    "spectral_atomo", "exact_rank_k",
)
WIRE_DTYPES = ("float32", "bfloat16", "int8", "int4")
STALENESS_MODES = ("none", "one_step")


def _mixed_tree():
    """The canonical mixed gradient tree the budget table is documented on
    (matrices incl. a stacked one + conv + uncompressed vectors) — the same
    shape family as the zoo conformance suite."""
    import jax.numpy as jnp
    from repro.core import matrixize

    grads = {
        "w1": jnp.zeros((24, 16)),
        "conv": jnp.zeros((8, 4, 3, 3)),
        "stack": jnp.zeros((3, 12, 6)),
        "bias": jnp.zeros((7,)),
        "scale": jnp.zeros((5,)),
    }
    specs = {
        "w1": matrixize.MatrixSpec("matrix", 0),
        "conv": matrixize.MatrixSpec("conv", 0),
        "stack": matrixize.MatrixSpec("matrix", 1),
        "bias": matrixize.NONE,
        "scale": matrixize.NONE,
    }
    return grads, specs


def make_zoo_compressor(scheme: str, wire_dtype: str, staleness: str,
                        rank: int = 2):
    from repro.core.compressors import make_compressor

    kw = {"wire_dtype": wire_dtype}
    if scheme.startswith("powersgd"):
        kw["pipeline"] = staleness == "one_step"
    return make_compressor(scheme, rank=rank, **kw)


def run_matrix(report: Report, *, schemes=ZOO_SCHEMES,
               wire_dtypes=WIRE_DTYPES, staleness_modes=STALENESS_MODES,
               verbose: bool = False) -> int:
    """The full static budget matrix.  Returns the number of traces run."""
    from repro.analysis import passes, tracing

    grads, specs = _mixed_tree()
    n = 0
    for scheme in schemes:
        for wd in wire_dtypes:
            for stale in staleness_modes:
                comp = make_zoo_compressor(scheme, wd, stale)
                label = f"{scheme}/{wd}/{stale}"
                art = tracing.trace_compress_step(
                    comp, grads, specs, staleness=stale, label=label)
                report.extend(passes.run_jaxpr_passes(
                    art, budget=comp.declared_budget(), scheme=label))
                n += 1
                if verbose:
                    print(f"  traced {label}: "
                          f"{len(art.logical())} logical collectives")
        # one broadcast-mode determinism trace per scheme (float32 wire):
        # certifies the PR 6 reduce-order contract statically
        comp = make_zoo_compressor(scheme, "float32", "none")
        art = tracing.trace_compress_step(
            comp, grads, specs, sync_mode="broadcast",
            label=f"{scheme}/broadcast")
        report.extend(passes.run_jaxpr_passes(
            art, budget=comp.declared_budget(), scheme=f"{scheme}/broadcast"))
        n += 1
    return n


def run_config(report: Report, arch: str, *, scheme: str = "powersgd",
               wire_dtype: str = "auto", staleness: str = "none",
               verbose: bool = False) -> None:
    """Partition + jaxpr + retrace passes for one architecture config.

    Everything is shape-level: ``jax.eval_shape`` for the model/EF state,
    ``jax.make_jaxpr`` for the compress step — no devices, no arrays.
    """
    import jax
    from repro.analysis import partition as partition_pass
    from repro.analysis import passes, tracing
    from repro.configs.base import get_config
    from repro.launch import specs as specs_lib
    from repro.models import model

    cfg = get_config(arch, reduced=True)
    params_sds = jax.eval_shape(
        lambda: model.init(jax.random.key(0), cfg, 1))
    param_ps = model.pspecs(cfg)
    mspecs = model.mspecs(cfg)
    dp_axes = ("data",)
    mesh_axes = ("data", "model")

    comp = make_zoo_compressor(scheme, wire_dtype, staleness)

    # -- partition-consistency on the full EF state ------------------------
    parts = specs_lib.ef_partition(param_ps, mspecs, dp_axes,
                                   compressor=comp,
                                   stateful=comp.stateful,
                                   staleness=staleness)
    comp_sds = jax.eval_shape(
        lambda: comp.init(params_sds, mspecs, jax.random.key(0)))
    from repro.core.error_feedback import EFState
    import jax.numpy as jnp
    ef_sds = EFState(
        error=jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct((1,) + tuple(p.shape), p.dtype),
            params_sds),
        momentum=params_sds,
        comp=comp_sds,
        step=jax.ShapeDtypeStruct((), jnp.int32),
        inflight=(params_sds if staleness == "one_step" else None))
    report.extend(partition_pass.check_partition(
        ef_sds, parts, mesh_axes=mesh_axes, label=f"{arch}:"))
    if comp.stateful:
        report.extend(partition_pass.check_factor_partition(
            param_ps, mspecs, parts.comp, label=f"{arch}:"))

    # -- jaxpr passes on the traced compress step --------------------------
    label = f"{arch}/{scheme}/{wire_dtype}/{staleness}"
    art = tracing.trace_compress_step(comp, params_sds, mspecs,
                                      staleness=staleness, label=label)
    report.extend(passes.run_jaxpr_passes(
        art, budget=comp.declared_budget(), scheme=label))
    if verbose:
        print(f"  {label}: {len(art.logical())} logical collectives over "
              f"{len(jax.tree_util.tree_leaves(params_sds))} leaves")

    # -- retrace-stability across a rank staircase -------------------------
    if scheme.startswith("powersgd"):
        def build(rank):
            c = make_zoo_compressor(scheme, wire_dtype, staleness, rank=rank)
            return tracing.trace_compress_step(
                c, params_sds, mspecs, staleness=staleness,
                label=f"{arch}/rank{rank}")
        report.extend(partition_pass.check_retrace(
            build, [(1,), (2,), (4,)], label=f"{arch}:rank-staircase:"))


def run_ast(report: Report, src_root: Path) -> None:
    from repro.analysis import astlint

    report.extend(astlint.lint_tree(src_root))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="gradlint: static invariant analysis for the "
                    "PowerSGD transport stack")
    ap.add_argument("--matrix", action="store_true",
                    help="zoo × wire-dtype × staleness budget matrix")
    ap.add_argument("--config", action="append", default=[],
                    metavar="ARCH", help="analyze one architecture config "
                    "('all' = whole registry); repeatable")
    ap.add_argument("--scheme", default="powersgd")
    ap.add_argument("--wire-dtype", default="auto")
    ap.add_argument("--staleness", default="none",
                    choices=("none", "one_step"))
    ap.add_argument("--ast", action="store_true",
                    help="source-AST rules over src/repro")
    ap.add_argument("--ast-only", action="store_true",
                    help="AST rules only — never imports jax")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    src_root = Path(__file__).resolve().parents[2]  # .../src
    report = Report()

    if args.ast_only:
        run_ast(report, src_root / "repro")
    else:
        do_default = not (args.matrix or args.config or args.ast)
        if args.matrix or do_default:
            n = run_matrix(report, verbose=args.verbose)
            if not args.json:
                print(f"gradlint: budget matrix — {n} traced steps "
                      f"({len(ZOO_SCHEMES)} schemes x {len(WIRE_DTYPES)} "
                      f"wire dtypes x {len(STALENESS_MODES)} staleness "
                      "modes + broadcast determinism)")
        configs = args.config
        if configs == ["all"]:
            from repro.configs.base import ARCH_IDS
            configs = list(ARCH_IDS)
        for arch in configs:
            if not args.json:
                print(f"gradlint: config {arch}")
            run_config(report, arch, scheme=args.scheme,
                       wire_dtype=args.wire_dtype, staleness=args.staleness,
                       verbose=args.verbose)
        if args.ast or do_default:
            run_ast(report, src_root / "repro")

    if args.json:
        print(report.to_json())
    else:
        for f in report.findings:
            print(f)
        print(f"gradlint: {report.summary()}")
    return 1 if report.errors() else 0


if __name__ == "__main__":
    sys.exit(main())
