"""gradlint partition-consistency (GL4xx) and retrace-stability (GL5xx).

Both passes are device-free: state trees come from ``jax.eval_shape``,
partitions from the same :func:`repro.launch.specs.ef_partition` derivation
the train step and the checkpoint layer share, and retrace checks hash
jaxprs from :mod:`repro.analysis.tracing`.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.analysis.findings import Finding
from repro.analysis import tracing


# ---------------------------------------------------------------------------
# partition-consistency (the PR 7 bug class)
# ---------------------------------------------------------------------------


def check_partition(state, partition, *, model_axis: str = "model",
                    mesh_axes: Optional[Sequence[str]] = None,
                    label: str = "") -> List[Finding]:
    """Audit a state tree against its StatePartition classification.

    Wraps :func:`repro.core.engine.partition_mismatches` (the structural
    rules live in ``core/engine.py`` next to :class:`StatePartition`
    itself) and renders its triples as findings: GL401 for unclassified
    leaves, GL403 for specs that contradict their own classification or
    the mesh.
    """
    from repro.core import engine

    rule_for = {"unclassified": "GL401", "spec-rank": "GL403",
                "unknown-axis": "GL403", "model-mismatch": "GL403"}
    findings = []
    for path, problem, detail in engine.partition_mismatches(
            state, partition, model_axis=model_axis, mesh_axes=mesh_axes):
        findings.append(Finding(
            rule=rule_for[problem], pass_name="partition",
            message=f"{label}{path}: {detail}",
            provenance=f"{label}{path}"))
    return findings


def check_factor_partition(param_pspecs, mspecs, comp_partition,
                           *, model_axis: str = "model",
                           label: str = "") -> List[Finding]:
    """Re-derive every compressor-state leaf's classification from the
    canonical :func:`repro.core.powersgd.factor_partition` and compare
    (GL402).  A row-parallel weight's Q factor classified as anything but
    MODEL_LOCAL is exactly the rank-0-copy checkpoint corruption of PR 7.
    """
    from jax.sharding import PartitionSpec as P
    from repro.core import powersgd

    findings: List[Finding] = []
    is_p = lambda x: isinstance(x, P)
    expected = jax.tree_util.tree_map(
        lambda spec, ms: powersgd.factor_partition(spec, ms, model_axis),
        param_pspecs, mspecs, is_leaf=is_p)

    exp_flat = {
        jax.tree_util.keystr(path): part
        for path, part in jax.tree_util.tree_flatten_with_path(
            expected, is_leaf=lambda x: x is None)[0]}
    got_flat = {
        jax.tree_util.keystr(path): part
        for path, part in jax.tree_util.tree_flatten_with_path(
            comp_partition, is_leaf=lambda x: x is None)[0]}

    for path, exp in sorted(exp_flat.items()):
        got = got_flat.get(path)
        if exp is None and got is None:
            continue
        if got is None:
            findings.append(Finding(
                rule="GL401", pass_name="partition",
                message=f"{label}{path}: compressed leaf has no "
                        "StatePartition in the compressor-state tree",
                provenance=f"{label}{path}"))
            continue
        if exp is None:
            continue  # extra classification is harmless
        if got.model != exp.model or tuple(got.spec or ()) != \
                tuple(exp.spec or ()):
            findings.append(Finding(
                rule="GL402", pass_name="partition",
                message=f"{label}{path}: classified ({got.model}, "
                        f"{got.spec}) but factor_partition derives "
                        f"({exp.model}, {exp.spec}) — a misclassified "
                        "factor checkpoints the wrong ranks' bytes",
                provenance=f"{label}{path}"))
    return findings


# ---------------------------------------------------------------------------
# retrace-stability (GL5xx)
# ---------------------------------------------------------------------------


def check_retrace(trace_builder, configs: Sequence[Tuple],
                  label: str = "") -> List[Finding]:
    """Prove only declared boundaries retrace.

    ``trace_builder(*config)`` must return a
    :class:`~repro.analysis.tracing.TraceArtifact`; ``configs`` is the list
    of declared configuration tuples (e.g. ``(scheme, rank)`` across a
    RankController staircase).  Checks:

    * **GL501** — tracing the same config twice yields different jaxpr
      hashes: trace construction is nondeterministic (set-ordered buckets,
      id-keyed dicts, ...), which breaks jit-cache reuse and makes every
      "identical" step a silent retrace.
    * **GL502** — two *different* declared configs collide on one hash.
      The declared boundary (a rank transition, a staleness switch) did
      not actually change the program — the transition is a no-op and the
      declaration table has rotted.
    """
    findings: List[Finding] = []
    seen: Dict[str, Tuple] = {}
    for config in configs:
        h1 = tracing.jaxpr_hash(trace_builder(*config).closed_jaxpr)
        h2 = tracing.jaxpr_hash(trace_builder(*config).closed_jaxpr)
        if h1 != h2:
            findings.append(Finding(
                rule="GL501", pass_name="retrace",
                message=f"{label}{config}: two traces of the same declared "
                        "config hash differently — trace construction is "
                        "nondeterministic",
                provenance=f"{label}{config}"))
            continue
        if h1 in seen and seen[h1] != config:
            findings.append(Finding(
                rule="GL502", pass_name="retrace",
                message=f"{label}{config}: hashes identically to declared "
                        f"boundary {seen[h1]} — the boundary does not "
                        "retrace",
                provenance=f"{label}{config}"))
        seen.setdefault(h1, config)
    return findings
