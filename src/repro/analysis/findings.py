"""gradlint rule catalog and machine-readable findings (jax-free).

A :class:`Finding` is one rule violation with enough provenance to act on:
the rule id, severity, a human message, and where it came from — a source
location for AST rules, a jaxpr call-chain for trace rules.  A
:class:`Report` is an ordered collection with JSON serialization for CI.
"""

from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str          # stable machine id, e.g. "GL101"
    name: str        # kebab-case slug usable in disable comments
    severity: str    # "error" | "warning"
    summary: str


# The catalog.  Ids are stable; never renumber — retire instead.
RULES: Tuple[Rule, ...] = (
    # -- collective-budget pass (GL1xx) ------------------------------------
    Rule("GL101", "collective-budget-exceeded", "error",
         "more fused data-axis collectives in the traced step than the "
         "scheme's documented budget"),
    Rule("GL102", "static-stats-mismatch", "error",
         "the jaxpr collective count disagrees with the CollectiveStats "
         "trace-time records (one of the two accounting paths rotted)"),
    Rule("GL103", "unattributed-collective", "error",
         "a data-axis collective primitive whose call chain does not pass "
         "through a repro.core.dist entry point (hand-rolled collective)"),
    Rule("GL104", "budget-shortfall", "warning",
         "fewer collectives than the documented budget — the budget table "
         "or the scheme changed without the other"),
    # -- wire-dtype pass (GL2xx) -------------------------------------------
    Rule("GL201", "wire-upcast-before-collective", "error",
         "a float payload is widened (convert_element_type to a wider "
         "float) on the pack path feeding a collective — the PR 3 "
         "mixed-dtype upcast bug class"),
    Rule("GL202", "unwidened-int-reduce", "error",
         "an integer-dtype buffer reaches a data-axis psum: quantized "
         "slots must be dequantized into a widened float accumulator "
         "before any reduce"),
    # -- determinism pass (GL3xx) ------------------------------------------
    Rule("GL301", "in-trace-prng-seed", "error",
         "a PRNG key is constructed from a constant inside the traced "
         "step (random_seed primitive): keys must enter as arguments and "
         "derive via fold_in"),
    Rule("GL302", "uncertified-reduce-order", "error",
         "under sync_mode='broadcast' a data-axis psum that is not the "
         "masked broadcast0 delivery: reductions must use the canonical "
         "gather + pairwise-tree order (the PR 6 drift bug class)"),
    # -- partition-consistency pass (GL4xx) --------------------------------
    Rule("GL401", "unclassified-state-leaf", "error",
         "an EFState leaf with no StatePartition classification: the "
         "checkpoint layer cannot gather/re-slice what it cannot classify "
         "(the PR 7 bug class)"),
    Rule("GL402", "partition-classification-mismatch", "error",
         "a compressor-state leaf whose StatePartition disagrees with the "
         "canonical factor_partition re-derivation"),
    Rule("GL403", "invalid-partition-spec", "error",
         "a StatePartition whose dims spec is inconsistent with the leaf "
         "shape or with its model-relation classification"),
    # -- retrace-stability pass (GL5xx) ------------------------------------
    Rule("GL501", "retrace-instability", "error",
         "tracing the same declared configuration twice produced different "
         "jaxprs — trace construction is nondeterministic"),
    Rule("GL502", "undeclared-retrace-boundary", "error",
         "two distinct declared configurations produced the same jaxpr "
         "hash, or a declared boundary failed to retrace"),
    # -- AST rules (GLA0x) — runnable without jax --------------------------
    Rule("GLA01", "host-transfer", "error",
         "np.asarray / jax.device_get outside checkpoint/ canonicalize "
         "paths: host transfers silently read device 0's shard (annotate "
         "deliberate host-side sites with '# gradlint: disable=host-transfer')"),
    Rule("GLA02", "prng-key-in-step", "error",
         "jax.random.PRNGKey/key construction inside a step function: "
         "derive per-step keys with fold_in from a key argument"),
    Rule("GLA03", "implicit-dtype-reduction", "error",
         "jnp.sum/mean/prod without an explicit dtype= on a wire-path "
         "module: accumulator dtype must be deliberate where payload "
         "bytes are priced"),
)

RULES_BY_ID = {r.id: r for r in RULES}
RULES_BY_NAME = {r.name: r for r in RULES}


def get_rule(key: str) -> Rule:
    try:
        return RULES_BY_ID.get(key) or RULES_BY_NAME[key]
    except KeyError:
        raise KeyError(f"unknown gradlint rule {key!r}") from None


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.

    ``provenance`` is the best machine-usable origin available: for AST
    rules ``file:line``; for jaxpr rules the innermost-to-outermost
    repro call chain of the offending equation (``dist.py:all_gather <-
    dist.py:allgather_flat <- ...``) plus the primitive name.
    """

    rule: str                 # rule id ("GL101")
    message: str
    provenance: str = ""
    file: Optional[str] = None
    line: Optional[int] = None
    pass_name: str = ""

    @property
    def rule_name(self) -> str:
        return RULES_BY_ID[self.rule].name

    @property
    def severity(self) -> str:
        return RULES_BY_ID[self.rule].severity

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "name": self.rule_name,
            "severity": self.severity,
            "message": self.message,
            "provenance": self.provenance,
            "file": self.file,
            "line": self.line,
            "pass": self.pass_name,
        }

    def __str__(self) -> str:
        loc = f"{self.file}:{self.line}: " if self.file else ""
        prov = f" [{self.provenance}]" if self.provenance and not self.file \
            else ""
        return f"{loc}{self.rule} ({self.rule_name}): {self.message}{prov}"


@dataclasses.dataclass
class Report:
    findings: List[Finding] = dataclasses.field(default_factory=list)

    def extend(self, findings: Sequence[Finding]) -> "Report":
        self.findings.extend(findings)
        return self

    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def by_rule(self, key: str) -> List[Finding]:
        rule = get_rule(key)
        return [f for f in self.findings if f.rule == rule.id]

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps([f.to_dict() for f in self.findings],
                          indent=indent)

    def summary(self) -> str:
        n_err = len(self.errors())
        n_warn = len(self.findings) - n_err
        return f"{n_err} error(s), {n_warn} warning(s)"
