"""Device-free step tracing and jaxpr inspection for gradlint.

Everything here runs with ``jax.make_jaxpr`` under an ``axis_env`` — no
devices, no executions, no shard_map.  The named-axis collectives the
transport engine emits (:class:`repro.core.dist.AxisBackend`) trace exactly
as they would inside shard_map, and :class:`repro.core.dist.CollectiveStats`
records at *Python trace time*, so one ``make_jaxpr`` call yields both
accounting paths (the jaxpr and the stats trace) for free.

Attribution: every collective equation carries a source-info traceback; the
innermost frames inside ``src/repro`` identify which ``dist.py`` entry point
emitted it (``pmean_flat``, ``allgather_flat``, ``broadcast0``,
``_canonical_reduce``, ...).  That chain is the finding provenance and the
key for classifying each primitive into the *logical* collective ledger
(e.g. a quantized gather's float32 scale sidecar is a second ``all_gather``
primitive but the same logical collective — see
:meth:`repro.core.dist.MeshCtx.allgather_flat`).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import dist
from repro.core.dist import (COLLECTIVE_PRIMITIVES, COLLECTIVE_SITES,
                             CollectiveStats, MeshCtx)

DATA_AXIS = "data"
DEFAULT_WORKERS = 4


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def iter_eqns(jaxpr):
    """Yield every equation of ``jaxpr`` and of all sub-jaxprs (pjit, scan,
    while, cond branches, custom_jvp/vjp calls, remat, ...) recursively."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in jax.core.jaxprs_in_params(eqn.params):
            yield from iter_eqns(sub)


def _eqn_axes(eqn) -> Tuple[str, ...]:
    axes = eqn.params.get("axes") or eqn.params.get("axis_name") or ()
    if isinstance(axes, (str, int)):
        axes = (axes,)
    return tuple(a for a in axes if isinstance(a, str))


def provenance_chain(eqn, package: str = "/repro/") -> Tuple[Tuple[str, str, int], ...]:
    """(file, function, line) frames of the eqn's traceback that live inside
    ``package``, innermost first.  Empty when the collective was issued
    outside the repro tree (a hand-rolled collective — GL103)."""
    src = getattr(eqn, "source_info", None)
    tb = getattr(src, "traceback", None)
    if tb is None:
        return ()
    chain = []
    for fr in tb.frames:
        if package in fr.file_name.replace("\\", "/"):
            name = fr.file_name.replace("\\", "/").rsplit(package, 1)[-1]
            chain.append((name, fr.function_name, fr.line_num))
    return tuple(chain)


@dataclasses.dataclass(frozen=True)
class CollectiveSite:
    """One collective primitive in a traced step, with attribution."""

    primitive: str                 # "psum" | "all_gather" | "ppermute" | ...
    axes: Tuple[str, ...]
    dtype: str                     # operand dtype on the wire
    size: int                      # operand element count
    chain: Tuple[Tuple[str, str, int], ...]  # repro frames, innermost first

    @property
    def entry(self) -> Optional[str]:
        """The dist.py entry-point function this collective belongs to, or
        None when the call chain never passes through core/dist.py."""
        for _file, func, _line in self.chain:
            if _file.endswith("core/dist.py") and func in COLLECTIVE_SITES:
                return func
        return None

    @property
    def kind(self) -> Optional[str]:
        """'reduce' | 'gather' | 'broadcast' per the dist entry point."""
        entry = self.entry
        return None if entry is None else COLLECTIVE_SITES[entry]

    @property
    def is_scale_sidecar(self) -> bool:
        """True for the float32 scale all_gather that rides a quantized
        payload gather — the same *logical* collective (its bytes are the
        stats record's overhead, not a new record)."""
        if self.primitive != "all_gather" or self.entry != "allgather_flat":
            return False
        sidecar_line = dist.quant_sidecar_line()
        return any(_file.endswith("core/dist.py")
                   and func == "allgather_flat" and line == sidecar_line
                   for _file, func, line in self.chain)

    def provenance(self) -> str:
        inner = " <- ".join(f"{f}:{fn}:{ln}" for f, fn, ln in self.chain[:4])
        return f"{self.primitive}[{','.join(self.axes)}] {inner or '<outside repro>'}"


def collect_collectives(closed_jaxpr,
                        data_axes: Sequence[str] = (DATA_AXIS,)) -> List[CollectiveSite]:
    """All data-axis collective primitives in trace order."""
    sites = []
    for eqn in iter_eqns(closed_jaxpr.jaxpr):
        if eqn.primitive.name not in COLLECTIVE_PRIMITIVES:
            continue
        axes = _eqn_axes(eqn)
        if not any(a in data_axes for a in axes):
            continue
        aval = eqn.invars[0].aval
        sites.append(CollectiveSite(
            primitive=eqn.primitive.name,
            axes=axes,
            dtype=str(aval.dtype),
            size=int(aval.size),
            chain=provenance_chain(eqn)))
    return sites


def logical_collectives(sites: Sequence[CollectiveSite]) -> List[CollectiveSite]:
    """The logical ledger: scale sidecars fold into their payload gather."""
    return [s for s in sites if not s.is_scale_sidecar]


# ---------------------------------------------------------------------------
# tracing entry points
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceArtifact:
    """One traced step: the closed jaxpr, the trace-time stats, the
    extracted collective sites, and the declared config that produced it."""

    closed_jaxpr: Any
    stats: CollectiveStats
    sites: Tuple[CollectiveSite, ...]
    label: str = ""
    sync_mode: str = "allreduce"

    def logical(self) -> List[CollectiveSite]:
        return logical_collectives(self.sites)


def trace_fn(fn: Callable, example_args: Sequence[Any], *,
             workers: int = DEFAULT_WORKERS,
             data_axis: str = DATA_AXIS, label: str = "",
             sync_mode: str = "allreduce",
             stats: Optional[CollectiveStats] = None) -> TraceArtifact:
    """Trace ``fn(*example_args)`` under a ``(data_axis, workers)`` axis env.

    ``example_args`` may be ShapeDtypeStructs or concrete arrays — tracing
    never executes either way.  ``stats`` should be the CollectiveStats the
    ctx inside ``fn`` records into, so the artifact carries both ledgers.
    """
    avals = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x))
        if not isinstance(x, jax.ShapeDtypeStruct) else x, tuple(example_args))
    if stats is None:
        stats = CollectiveStats()
    closed = jax.make_jaxpr(fn, axis_env=[(data_axis, workers)])(*avals)
    sites = tuple(collect_collectives(closed, (data_axis,)))
    return TraceArtifact(closed_jaxpr=closed, stats=stats, sites=sites,
                         label=label, sync_mode=sync_mode)


def trace_compress_step(compressor, grads, specs, *,
                        staleness: str = "none",
                        sync_mode: str = "allreduce",
                        workers: int = DEFAULT_WORKERS,
                        with_error_feedback: bool = True,
                        label: str = "") -> TraceArtifact:
    """Trace one error-feedback compress+aggregate step, device-free.

    This is the same path ``launch/train.py`` runs inside shard_map —
    :func:`repro.core.error_feedback.apply_updates` over the compressor —
    with the data axis supplied by ``axis_env`` instead of a mesh.
    ``staleness="one_step"`` carries the params-shaped in-flight buffer
    exactly like the pipeline (the collectives must be identical — PR 8's
    trace-identity contract, which the budget pass re-proves statically).
    """
    from repro.core import error_feedback

    stats = CollectiveStats()
    ctx = MeshCtx(data_axes=(DATA_AXIS,), stats=stats, sync_mode=sync_mode)
    grads_sds = jax.tree_util.tree_map(
        lambda g: jax.ShapeDtypeStruct(jnp.shape(g), jnp.result_type(g)),
        grads)
    key = jax.ShapeDtypeStruct((), jax.random.key(0).dtype)
    comp_state = jax.eval_shape(
        lambda: compressor.init(grads_sds, specs, jax.random.key(0)))

    if not with_error_feedback:
        def fn(g, state, key):
            out = compressor.step(g, state, specs, ctx=ctx, key=key)
            return out.agg
        return trace_fn(fn, (grads_sds, comp_state, key), workers=workers,
                        label=label, sync_mode=sync_mode, stats=stats)

    state = error_feedback.EFState(
        error=grads_sds,
        momentum=grads_sds,
        comp=comp_state,
        step=jax.ShapeDtypeStruct((), jnp.int32),
        inflight=(grads_sds if staleness == "one_step" else None))

    def fn(params, g, state, key):
        new_params, new_state, _aux = error_feedback.apply_updates(
            compressor, params, g, state, specs, lr=0.1, ctx=ctx, key=key,
            staleness=staleness)
        return new_params, new_state

    return trace_fn(fn, (grads_sds, grads_sds, state, key), workers=workers,
                    label=label, sync_mode=sync_mode, stats=stats)


# ---------------------------------------------------------------------------
# stable jaxpr hashing (retrace-stability pass)
# ---------------------------------------------------------------------------


def jaxpr_hash(closed_jaxpr) -> str:
    """Stable content hash of a closed jaxpr.

    The pretty-printer assigns canonical single-letter names in program
    order, so two structurally identical traces print identically; source
    line info is not part of the rendering.  Constants are hashed by
    shape/dtype (not value) — a retrace with different constant *values*
    but identical structure is the same program shape, which is what
    retrace-stability is about.
    """
    text = str(closed_jaxpr.jaxpr)
    consts = ",".join(
        f"{jnp.shape(c)}:{jnp.result_type(c)}" for c in closed_jaxpr.consts)
    return hashlib.sha256(f"{text}||{consts}".encode()).hexdigest()
