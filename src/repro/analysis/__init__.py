"""gradlint — jaxpr-level static invariant analysis for the transport stack.

Every load-bearing invariant of the fused-collective engine (the O(1)
per-step collective budget that is the paper's headline property, wire-dtype
discipline, replica determinism, per-leaf partition classification, retrace
stability) is visible in the traced jaxpr or the source AST without
executing a single step.  This package checks them there:

* :mod:`repro.analysis.findings` — rule catalog, :class:`Finding` /
  :class:`Report` (machine-readable, jax-free),
* :mod:`repro.analysis.tracing` — device-free step tracing
  (``jax.make_jaxpr`` + ``axis_env``), collective extraction with
  source provenance, stable jaxpr hashing,
* :mod:`repro.analysis.passes` — the jaxpr passes: collective-budget,
  wire-dtype discipline, determinism,
* :mod:`repro.analysis.partition` — partition-consistency and
  retrace-stability passes,
* :mod:`repro.analysis.astlint` — the source-AST rules (importable and
  runnable without jax installed),
* :mod:`repro.analysis.lint` — the CLI:
  ``python -m repro.analysis.lint [--config ARCH | --ast-only | ...]``.

Import note: this ``__init__`` must stay importable without jax so the
jax-free docs CI job can run ``lint --ast-only`` — anything that needs jax
is imported lazily by the modules that use it.
"""

from repro.analysis.findings import Finding, Report, RULES

__all__ = ["Finding", "Report", "RULES"]
