from repro.data.synthetic import MarkovLM, GaussianClusters, shard_batch
