"""Deterministic synthetic data pipelines.

The benchmarks need *learnable* tasks (the paper's claims are about reaching
target quality, not just throughput), so the LM stream is a fixed-seed
order-2 Markov chain over the vocabulary — a task with real structure whose
achievable perplexity is far below uniform — and the classification stream
is a Gaussian-cluster task.  Everything is reproducible from integer seeds
and supports per-worker sharding by slicing the global batch.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class MarkovLM:
    """Order-k Markov chain token stream with a peaked transition table.

    ``order=2`` (default) keys the transition on the last *two* tokens —
    a hash-lookup task with vocab² contexts and no partial credit, so small
    models need many epochs before the loss moves.  ``order=1`` keys on the
    previous token only (vocab contexts): learnable within tens of steps,
    which is what the convergence tests and quick benchmarks use."""

    vocab: int
    seed: int = 0
    branching: int = 4  # plausible next-tokens per context
    order: int = 2
    clusters: int = 0   # >0: transitions depend on token%clusters only —
                        # token roles share ~`clusters` rows, so gradients
                        # are genuinely low-rank (the paper's premise §2)

    def __post_init__(self):
        assert self.order in (1, 2), self.order
        rng = np.random.RandomState(self.seed)
        # hash-based sparse transition: next ∈ {h(context, j) : j < branching}
        self._mix = rng.randint(1, 2**31 - 1, size=3)

    def _ctx(self, c):
        return c % self.clusters if self.clusters else c

    def _nexts(self, c1, c2):
        a, b, c = self._mix
        base = (self._ctx(c1) * a * (self.order > 1)
                + self._ctx(c2) * b) % (2**31 - 1)
        return [(base + j * c) % self.vocab for j in range(self.branching)]

    def sample(self, batch: int, seq: int, step: int) -> np.ndarray:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % 2**31)
        out = np.empty((batch, seq + 1), dtype=np.int32)
        c1 = rng.randint(0, self.vocab, size=batch)
        c2 = rng.randint(0, self.vocab, size=batch)
        out[:, 0] = c1
        out[:, 1] = c2
        choices = rng.randint(0, self.branching, size=(batch, seq - 1))
        noise = rng.rand(batch, seq - 1) < 0.05  # 5% uniform noise
        noise_tok = rng.randint(0, self.vocab, size=(batch, seq - 1))
        a, b, c = self._mix
        for t in range(seq - 1):
            base = (self._ctx(c1) * a * (self.order > 1)
                    + self._ctx(c2) * b) % (2**31 - 1)
            nxt = (base + choices[:, t] * c) % self.vocab
            nxt = np.where(noise[:, t], noise_tok[:, t], nxt)
            out[:, t + 2] = nxt
            c1, c2 = c2, nxt
        return out

    def batches(self, batch: int, seq: int) -> Iterator[dict]:
        step = 0
        while True:
            toks = self.sample(batch, seq, step)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
            step += 1


@dataclasses.dataclass
class GaussianClusters:
    """k-class Gaussian blobs rendered as small 'images' (for the ResNet)."""

    num_classes: int = 10
    image_size: int = 16
    channels: int = 3
    seed: int = 0
    noise: float = 0.8

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        d = self.image_size * self.image_size * self.channels
        self._centers = rng.randn(self.num_classes, d).astype(np.float32)

    def sample(self, batch: int, step: int) -> dict:
        rng = np.random.RandomState((self.seed * 7_368_787 + step) % 2**31)
        labels = rng.randint(0, self.num_classes, size=batch)
        d = self._centers.shape[1]
        x = self._centers[labels] + self.noise * rng.randn(batch, d).astype(np.float32)
        images = x.reshape(batch, self.image_size, self.image_size, self.channels)
        return {"images": images, "labels": labels.astype(np.int32)}

    def batches(self, batch: int) -> Iterator[dict]:
        step = 0
        while True:
            yield self.sample(batch, step)
            step += 1


def shard_batch(batch: dict, worker: int, num_workers: int) -> dict:
    """Slice a global batch into this worker's shard (paper's W-worker setup)."""
    out = {}
    for k, v in batch.items():
        n = v.shape[0]
        assert n % num_workers == 0, (k, n, num_workers)
        per = n // num_workers
        out[k] = v[worker * per:(worker + 1) * per]
    return out
