"""Pytree checkpointing: msgpack envelope + raw numpy buffers.

Atomic (write to tmp, fsync, rename, fsync dir), step-indexed, with a
retention policy.  No flax/orbax dependency — arrays are serialised as
(dtype, shape, bytes) triples and the tree structure via jax.tree_util key
paths.

Envelope format (``version`` field; see ``docs/checkpoint.md``):

* **v1** (legacy): ``{"step", "treedef", "leaves"}`` — leaves in flatten
  order only, dtypes as numpy ``.str`` tokens (lossy for extension dtypes:
  bfloat16 encoded as the void token ``'<V2'``).
* **v2** (current): adds ``"version"``, ``"meta"`` (a msgpack-native dict of
  host-side scalars — step counters, worker count, controller state),
  per-leaf ``"path"`` strings (so mismatches are reported by name, and
  structure drift is caught even when shapes coincide) and a ``"crc32"``
  over the concatenated leaf bytes (bit-flips inside the raw buffers parse
  as valid msgpack; the checksum catches them).  Dtypes use the
  round-trippable ``.name`` token for extension dtypes.

Restores of both versions are supported; writes always produce v2.

Durability contract: one writer per directory.  ``save_checkpoint`` fsyncs
the tmp file before the atomic ``os.replace`` (a rename alone can land
before the data on a crash) and fsyncs the directory afterwards so the
rename itself is durable; orphaned ``*.tmp`` files from a crashed writer
are swept on the next save.  ``restore_checkpoint`` raises
:class:`CheckpointError` — never returns garbage — on truncated, corrupted
or structurally mismatched envelopes.
"""

from __future__ import annotations

import os
import re
import tempfile
import zlib
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

FORMAT_VERSION = 2

# meta key recording the model-parallel degree the envelope was saved at
# (alongside "mesh_shape", the full axis-name → size dict).  Model-LOCAL
# state leaves (per-model-rank Q factors; see repro.core.engine.
# StatePartition) are stored stacked along a leading (model_axis_size,)
# dim, so an envelope only re-slices correctly onto a mesh with the same
# model degree — check_model_axis() enforces that.  Envelopes without the
# key predate the stacked layout (or were saved by a single-axis driver)
# and are treated as model_axis_size=1.
MODEL_AXIS_KEY = "model_axis_size"

_CKPT_RE = re.compile(r"ckpt_(\d+)\.msgpack")


class CheckpointError(RuntimeError):
    """A checkpoint could not be read back: truncated or corrupted file,
    or an envelope that does not match the restore template (wrong leaf
    count, shape or dtype — reported by tree path)."""


def _is_none(x):
    return x is None


def _dtype_token(dt) -> str:
    """Round-trippable dtype token.

    numpy's ``.str`` is lossy for extension dtypes (ml_dtypes bfloat16 →
    the void token ``'<V2'``, which silently decodes to raw structs);
    ``.name`` round-trips both standard and extension dtypes."""
    dt = np.dtype(dt)
    return dt.name if dt.kind == "V" else dt.str


def _encode_leaf(x):
    if x is None:
        return {"kind": "none"}
    arr = np.asarray(x)
    return {
        "kind": "array",
        "dtype": _dtype_token(arr.dtype),
        "shape": list(arr.shape),
        "data": arr.tobytes(),
    }


def _decode_leaf(d):
    if d["kind"] == "none":
        return None
    arr = np.frombuffer(d["data"], dtype=np.dtype(d["dtype"]))
    return jnp.asarray(arr.reshape(d["shape"]))


def _leaves_crc(encoded) -> int:
    crc = 0
    for d in encoded:
        if d["kind"] == "array":
            crc = zlib.crc32(d["data"], crc)
    return crc


def _ckpt_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:010d}.msgpack")


def _sweep_orphaned_tmp(directory: str):
    """Remove ``*.tmp`` files left by a crashed writer.

    mkstemp names never collide with a live writer *in this process*; the
    single-writer-per-directory contract makes the sweep safe globally."""
    for name in os.listdir(directory):
        if name.endswith(".tmp"):
            try:
                os.remove(os.path.join(directory, name))
            except FileNotFoundError:
                pass


def _fsync_dir(directory: str):
    """Make a completed rename durable (POSIX: the directory entry lives in
    the directory inode, which has its own write-back)."""
    try:
        fd = os.open(directory, os.O_RDONLY)
    except OSError:  # pragma: no cover - exotic fs without dir open
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(directory: str, step: int, tree: Any, *, keep: int = 3,
                    meta: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    _sweep_orphaned_tmp(directory)
    pairs, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=_is_none)
    encoded = []
    for p, l in pairs:
        d = _encode_leaf(l)
        d["path"] = jax.tree_util.keystr(p)
        encoded.append(d)
    payload = {
        "version": FORMAT_VERSION,
        "step": step,
        "treedef": str(treedef),
        "meta": meta or {},
        "leaves": encoded,
        "crc32": _leaves_crc(encoded),
    }
    path = _ckpt_path(directory, step)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.remove(tmp)
        except FileNotFoundError:
            pass
        raise
    _fsync_dir(directory)
    _retain(directory, keep)
    return path


def load_envelope(directory: str, step: Optional[int] = None) -> dict:
    """Read and integrity-check one envelope without a template.

    Returns the raw payload dict (v1 payloads gain ``version=1``,
    ``meta={}``).  Raises :class:`CheckpointError` on truncated/corrupted
    files and ``FileNotFoundError`` when there is nothing to load."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = _ckpt_path(directory, step)
    with open(path, "rb") as f:
        raw = f.read()
    try:
        payload = msgpack.unpackb(raw, raw=False)
    except Exception as e:
        raise CheckpointError(
            f"{path}: not a valid checkpoint envelope (truncated or "
            f"corrupted): {e}") from e
    if (not isinstance(payload, dict) or "leaves" not in payload
            or "step" not in payload):
        raise CheckpointError(f"{path}: envelope missing required fields")
    payload.setdefault("version", 1)
    payload.setdefault("meta", {})
    if payload["version"] >= 2:
        got = _leaves_crc(payload["leaves"])
        if got != payload.get("crc32"):
            raise CheckpointError(
                f"{path}: leaf-data checksum mismatch "
                f"(crc32 {got:#010x} != recorded "
                f"{payload.get('crc32', 0):#010x}) — corrupted buffers")
    return payload


def checkpoint_meta(directory: str, step: Optional[int] = None) -> dict:
    """The ``meta`` dict saved alongside a checkpoint (``{}`` for v1)."""
    return load_envelope(directory, step)["meta"]


def check_model_axis(meta: dict, model_axis_size: int):
    """Refuse to restore an envelope into a different model-parallel degree.

    Model-local leaves are stored stacked per model rank; re-slicing a
    degree-S stack onto a degree-S' mesh would hand every rank the wrong
    (or rank-0's) factors — shape-coincident leaves would even load without
    an error.  Raises :class:`CheckpointError` naming both sizes."""
    saved = int(meta.get(MODEL_AXIS_KEY, 1) or 1)
    if saved != int(model_axis_size):
        raise CheckpointError(
            f"model-parallel degree mismatch: checkpoint was saved at "
            f"{MODEL_AXIS_KEY}={saved}, this run restores at "
            f"{MODEL_AXIS_KEY}={int(model_axis_size)} — model-local state "
            f"(per-rank warm-start factors) cannot be re-sliced across "
            f"model degrees; restore on a mesh with {saved} model shard(s)")


def restore_tree(payload: dict, template: Any, shape_ok=None) -> Any:
    """Decode an envelope's leaves into the structure of ``template``.

    Structure (leaf count + stored paths), None/array-ness and **dtype**
    are checked strictly — a bfloat16/float32 swap would otherwise restore
    silently and retrace every downstream jit at the wrong precision.
    Shapes must match exactly unless ``shape_ok(path, got_shape,
    want_shape)`` approves the mismatch (how :mod:`~repro.checkpoint.
    train_state` admits rank/worker-count changes).  Mismatches raise
    :class:`CheckpointError` naming the offending tree path."""
    t_pairs, treedef = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=_is_none)
    encoded = payload["leaves"]
    if len(encoded) != len(t_pairs):
        raise CheckpointError(
            f"checkpoint/template structure mismatch: {len(encoded)} leaves "
            f"in checkpoint, {len(t_pairs)} in template")
    leaves = []
    for d, (pathkeys, want) in zip(encoded, t_pairs):
        tpath = jax.tree_util.keystr(pathkeys)
        path = d.get("path", tpath)  # v1 has no stored paths
        if path != tpath:
            raise CheckpointError(
                f"checkpoint/template structure mismatch at {tpath}: "
                f"checkpoint leaf is {path}")
        got = _decode_leaf(d)
        if (got is None) != (want is None):
            raise CheckpointError(
                f"leaf {tpath}: checkpoint has "
                f"{'None' if got is None else 'an array'}, template has "
                f"{'None' if want is None else 'an array'}")
        if got is not None:
            if np.dtype(got.dtype) != np.dtype(want.dtype):
                raise CheckpointError(
                    f"leaf {tpath}: dtype mismatch — checkpoint "
                    f"{np.dtype(got.dtype).name}, template "
                    f"{np.dtype(want.dtype).name}")
            gs, ws = tuple(got.shape), tuple(want.shape)
            if gs != ws and not (shape_ok and shape_ok(tpath, gs, ws)):
                raise CheckpointError(
                    f"leaf {tpath}: shape mismatch — checkpoint {gs}, "
                    f"template {ws}")
        leaves.append(got)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_checkpoint(directory: str, template: Any,
                       step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``template`` (strict shape + dtype
    matching per leaf — see :func:`restore_tree`)."""
    payload = load_envelope(directory, step)
    return restore_tree(payload, template), payload["step"]


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = _CKPT_RE.fullmatch(name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def all_steps(directory: str) -> list:
    """Sorted steps of every checkpoint currently in ``directory``."""
    if not os.path.isdir(directory):
        return []
    return sorted(int(m.group(1)) for m in
                  (_CKPT_RE.fullmatch(n) for n in os.listdir(directory)) if m)


def _retain(directory: str, keep: int):
    for s in all_steps(directory)[:-keep]:
        try:
            os.remove(_ckpt_path(directory, s))
        except FileNotFoundError:
            pass  # a concurrent cleaner (or operator) already removed it
