"""Pytree checkpointing: msgpack envelope + raw numpy buffers.

Atomic (write to tmp, rename), step-indexed, with a retention policy.
No flax/orbax dependency — arrays are serialised as (dtype, shape, bytes)
triples and the tree structure via jax.tree_util key paths.
"""

from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _encode_leaf(x):
    if x is None:
        return {"kind": "none"}
    arr = np.asarray(x)
    return {
        "kind": "array",
        "dtype": arr.dtype.str,
        "shape": list(arr.shape),
        "data": arr.tobytes(),
    }


def _decode_leaf(d):
    if d["kind"] == "none":
        return None
    arr = np.frombuffer(d["data"], dtype=np.dtype(d["dtype"]))
    return jnp.asarray(arr.reshape(d["shape"]))


def save_checkpoint(directory: str, step: int, tree: Any, *, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(
        tree, is_leaf=lambda x: x is None)
    payload = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [_encode_leaf(l) for l in leaves],
    }
    path = os.path.join(directory, f"ckpt_{step:010d}.msgpack")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)
    _retain(directory, keep)
    return path


def restore_checkpoint(directory: str, template: Any, step: Optional[int] = None):
    """Restore into the structure of ``template`` (shapes must match)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:010d}.msgpack")
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves = [_decode_leaf(d) for d in payload["leaves"]]
    t_leaves, treedef = jax.tree_util.tree_flatten(
        template, is_leaf=lambda x: x is None)
    assert len(leaves) == len(t_leaves), "checkpoint/template structure mismatch"
    for got, want in zip(leaves, t_leaves):
        if want is not None and got is not None:
            assert tuple(got.shape) == tuple(want.shape), (got.shape, want.shape)
    return jax.tree_util.tree_unflatten(treedef, leaves), payload["step"]


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)\.msgpack", name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def _retain(directory: str, keep: int):
    steps = sorted(
        int(re.fullmatch(r"ckpt_(\d+)\.msgpack", n).group(1))
        for n in os.listdir(directory)
        if re.fullmatch(r"ckpt_(\d+)\.msgpack", n)
    )
    for s in steps[:-keep]:
        os.remove(os.path.join(directory, f"ckpt_{s:010d}.msgpack"))
