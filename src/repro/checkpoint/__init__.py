from repro.checkpoint.msgpack_ckpt import (
    MODEL_AXIS_KEY, CheckpointError, all_steps, check_model_axis,
    checkpoint_meta, latest_step, load_envelope, restore_checkpoint,
    save_checkpoint)
from repro.checkpoint.train_state import (
    TrainState, canonicalize_mesh, canonicalize_sim, replicate_mesh,
    replicate_sim, restore_train_state, save_train_state,
    stack_model_template)
