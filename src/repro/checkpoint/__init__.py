from repro.checkpoint.msgpack_ckpt import (
    CheckpointError, all_steps, checkpoint_meta, latest_step, load_envelope,
    restore_checkpoint, save_checkpoint)
from repro.checkpoint.train_state import (
    TrainState, canonicalize_sim, replicate_sim, restore_train_state,
    save_train_state)
