"""Versioned full-algorithm-state checkpoints for fault-tolerant resume.

PowerSGD's trajectory is a function of more than the parameters: the
error-feedback buffers (Alg. 1 line "e_w ← Δ_w − recon"), the warm-started
Q factors (§3 warm-start ablation), the rank-schedule position, the PRNG
stream and the data cursor all carry across steps.  A checkpoint that saves
only ``{"params", "ef"}`` with no resume path silently restarts all of the
non-parameter state from zero — :class:`TrainState` is the envelope that
makes "resume" mean *bit-exact continuation*:

* ``params`` and the full :class:`~repro.core.error_feedback.EFState`
  (per-worker error buffers, momentum, warm-start factors, step counter),
* ``key`` — the run's *base* PRNG key; per-step keys are derived as
  ``fold_in(key, step)``, so (key, step) reproduces the stream,
* ``data_step`` — the cursor into the deterministic batch stream
  (:class:`repro.data.synthetic.MarkovLM` samples are keyed by step),
* host-side scalars in the envelope's ``meta`` dict: worker count, the
  :class:`~repro.core.powersgd.RankController` state (rank, residual EMA,
  switch history, transition PRNG key) and any caller extras (schedule
  spec, last residual).

Canonical worker layout: everything *replicated* across data-parallel
workers (params, momentum, compressor factors, step) is stored once,
without a worker dim; only the genuinely per-worker error buffers keep
their stacked leading ``(W, ...)`` dim.  :func:`canonicalize_sim` /
:func:`replicate_sim` convert a :class:`~repro.core.simmesh.SimMesh` run's
stacked trees to/from this layout; the distributed train step's state is
already canonical (its error buffers are the global ``(dp_total, ...)``
stack).

Elastic resume: :func:`restore_train_state` restores into a template whose
error buffers may carry a *different* worker count — the buffers are
re-sharded by :func:`repro.core.error_feedback.rescale_error_buffers`
(worker-**mean**-preserving; see its docstring for the exact grow / shrink
/ coprime semantics).  Same-W restores are bit-exact; rescaled restores are
trajectory-preserving in the Lemma-3 sense.  Likewise the template's
compressor factors may sit at a different *rank* than the checkpoint (the
template is built from config, the checkpoint may be mid-staircase): the
checkpoint's factors win, and the jitted step simply retraces at the
checkpointed rank.  Every other leaf must match the template in shape and
dtype exactly (:class:`~repro.checkpoint.msgpack_ckpt.CheckpointError`
names the offending leaf otherwise).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.msgpack_ckpt import (
    MODEL_AXIS_KEY, CheckpointError, check_model_axis, load_envelope,
    restore_tree, save_checkpoint)
from repro.core import error_feedback
from repro.core.engine import MODEL_LOCAL, StatePartition
from repro.core.error_feedback import EFState

# v2 (ISSUE 8): the envelope may carry EFState.inflight — the one-step-stale
# pipeline's in-flight aggregate.  v1 envelopes (no inflight records at all)
# restore into both pipeline modes: a missing buffer zero-fills (one extra
# pipeline-bubble step), a surplus one is dropped — see restore_train_state.
TRAIN_STATE_VERSION = 2

# envelope-leaf path prefixes with relaxed shape matching (see module doc)
_COMP_PREFIX = "['ef'].comp"
_ERROR_PREFIX = "['ef'].error"
_INFLIGHT_PREFIX = "['ef'].inflight"


@dataclasses.dataclass
class TrainState:
    """The whole resumable algorithm state (see module docstring)."""

    params: Any
    ef: EFState
    key: jax.Array        # base PRNG key (typed key array or raw uint32)
    data_step: jax.Array  # int32 batch-stream cursor


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "ef", "key", "data_step"],
    meta_fields=[])


# ---------------------------------------------------------------------------
# PRNG keys: msgpack only sees raw uint32 key data + a dtype tag in meta
# ---------------------------------------------------------------------------

def key_to_data(key: jax.Array) -> Tuple[jax.Array, str]:
    """(serializable uint32 data, dtype tag) for a typed or raw PRNG key."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(key), str(key.dtype)
    return key, "raw"


def key_from_data(data: jax.Array, tag: str) -> jax.Array:
    if tag == "raw":
        return data
    key = jax.random.wrap_key_data(data)
    if str(key.dtype) != tag:
        raise CheckpointError(
            f"PRNG key impl mismatch: checkpoint was saved with {tag}, "
            f"this process wraps key data as {key.dtype} — resume under "
            f"the same jax_default_prng_impl")
    return key


# ---------------------------------------------------------------------------
# save / restore
# ---------------------------------------------------------------------------

def _as_tree(state: TrainState, key_data) -> dict:
    return {"params": state.params,
            "ef": state.ef,
            "key_data": key_data,
            "data_step": state.data_step}


def _error_workers(ef: EFState) -> Optional[int]:
    leaves = jax.tree_util.tree_leaves(ef.error)
    return leaves[0].shape[0] if leaves else None


def save_train_state(directory: str, state: TrainState, *,
                     controller=None, keep: int = 3,
                     extra_meta: Optional[dict] = None,
                     model_axis_size: int = 1,
                     mesh_shape: Optional[dict] = None) -> str:
    """Write one full-state checkpoint at ``state.ef.step``.

    ``state`` must be in the canonical worker layout (see module
    docstring; SimMesh runs go through :func:`canonicalize_sim` first,
    model-parallel shard_map runs through :func:`canonicalize_mesh`).
    ``controller`` — the run's
    :class:`~repro.core.powersgd.RankController`, serialized into ``meta``
    so a resume continues the schedule (and its transition PRNG stream)
    from the exact position.  ``model_axis_size`` / ``mesh_shape`` record
    the model-parallel degree the state was gathered at — the restore-side
    degree guard (:func:`repro.checkpoint.msgpack_ckpt.check_model_axis`)
    reads the former.
    """
    key_data, key_tag = key_to_data(state.key)
    meta = {
        "train_state_version": TRAIN_STATE_VERSION,
        "workers": _error_workers(state.ef),
        "key_dtype": key_tag,
        "controller": None if controller is None else controller.state_dict(),
        MODEL_AXIS_KEY: int(model_axis_size),
        "mesh_shape": mesh_shape,
    }
    meta.update(extra_meta or {})
    return save_checkpoint(directory, int(state.ef.step),
                           _as_tree(state, key_data), keep=keep, meta=meta)


def _splice_inflight(payload: dict, template_tree) -> Tuple[dict, Optional[str]]:
    """Align the envelope's leaf records with the template around the
    ``EFState.inflight`` leaves, so envelopes cross the pipeline-mode (and
    version) boundary instead of failing the strict structure check:

    * template expects an in-flight buffer the envelope lacks (legacy/v1 or
      ``staleness="none"`` save restored into ``"one_step"``) — synthesize
      zero records; the resumed run pays exactly one extra pipeline-bubble
      step, the honest semantics of "nothing was in flight".
    * envelope carries a buffer the template has no slot for (``one_step``
      save restored into ``"none"``) — drop it; the synchronous path never
      applies it.

    Returns ``(payload, note)`` — ``note`` is a provenance string for
    ``meta["inflight"]`` (``None`` when the structures already agree and the
    records pass through untouched for bit-exact restore)."""
    t_pairs, _ = jax.tree_util.tree_flatten_with_path(
        template_tree, is_leaf=lambda x: x is None)
    t_paths = [jax.tree_util.keystr(p) for p, _ in t_pairs]
    enc = payload["leaves"]

    def is_inflight(path):
        return (path or "").startswith(_INFLIGHT_PREFIX)

    enc_inflight = {d.get("path"): d for d in enc if is_inflight(d.get("path"))}
    if set(enc_inflight) == {p for p in t_paths if is_inflight(p)}:
        return payload, None
    others_list = [d for d in enc if not is_inflight(d.get("path"))]
    if len(others_list) != sum(1 for p in t_paths if not is_inflight(p)):
        return payload, None  # non-inflight mismatch: restore_tree reports it
    others = iter(others_list)
    spliced, zero_filled = [], False
    for path, (_, want) in zip(t_paths, t_pairs):
        if not is_inflight(path):
            spliced.append(next(others))
        elif path in enc_inflight:
            spliced.append(enc_inflight[path])
        elif want is None:
            spliced.append({"kind": "none", "path": path})
        else:
            zero_filled = True
            spliced.append({
                "kind": "array",
                "dtype": np.dtype(want.dtype).str,
                "shape": list(want.shape),
                "data": np.zeros(tuple(want.shape), want.dtype).tobytes(),
                "path": path,
            })
    t_path_set = set(t_paths)
    dropped = any(p not in t_path_set for p in enc_inflight)
    note = ("zero_filled" if zero_filled
            else "dropped" if dropped else "absent")
    return {**payload, "leaves": spliced}, note


def restore_train_state(directory: str, template: TrainState,
                        step: Optional[int] = None, *,
                        model_axis_size: Optional[int] = None
                        ) -> Tuple[TrainState, dict]:
    """Restore a :class:`TrainState`, adapting rank and worker count.

    ``template`` supplies structure and dtypes (typically a freshly
    initialized state at the *configured* rank and the *current* worker
    count).  Returns ``(state, meta)``; ``state`` carries the checkpoint's
    factor ranks (possibly ≠ template's — the jitted step retraces) and
    the template's worker count (error buffers rescaled when it differs
    from ``meta["workers"]``; ``meta["ef_rescale"]`` records which
    :func:`~repro.core.error_feedback.rescale_path` ran).  Pass
    ``model_axis_size`` (the restoring mesh's model degree) to enforce the
    model-parallel degree guard — model-local leaves are stored stacked
    per model rank and cannot be re-sliced across degrees.  Raises
    :class:`CheckpointError` on truncation/corruption, degree mismatch, or
    any other structure/shape/dtype mismatch.
    """
    payload = load_envelope(directory, step)
    meta = dict(payload["meta"])
    if "train_state_version" not in meta:
        raise CheckpointError(
            f"checkpoint in {directory} is not a TrainState envelope "
            f"(plain save_checkpoint tree?) — no train_state_version in "
            f"meta")
    if model_axis_size is not None:
        check_model_axis(meta, model_axis_size)

    def shape_ok(tpath, gs, ws):
        if tpath.startswith(_COMP_PREFIX):
            return gs[:-1] == ws[:-1]    # rank (last dim) may move
        if tpath.startswith(_ERROR_PREFIX):
            return gs[1:] == ws[1:]      # worker count (dim 0) may move
        return False

    key_data, _ = key_to_data(template.key)
    t_tree = _as_tree(template, key_data)
    payload, inflight_note = _splice_inflight(payload, t_tree)
    if inflight_note:
        meta["inflight"] = inflight_note
    tree = restore_tree(payload, t_tree, shape_ok=shape_ok)
    ef: EFState = tree["ef"]
    w_new = _error_workers(template.ef)
    w_old = _error_workers(ef)
    if w_new is not None:
        meta["ef_rescale"] = {
            "from": w_old, "to": w_new,
            "path": error_feedback.rescale_path(w_old, w_new)}
        if w_old != w_new:
            ef = EFState(
                error=error_feedback.rescale_error_buffers(ef.error, w_new),
                momentum=ef.momentum, comp=ef.comp, step=ef.step,
                inflight=ef.inflight)
    state = TrainState(
        params=tree["params"], ef=ef,
        key=key_from_data(tree["key_data"], meta.get("key_dtype", "raw")),
        data_step=tree["data_step"])
    return state, meta


# ---------------------------------------------------------------------------
# model-parallel mesh ⇄ canonical layout
# ---------------------------------------------------------------------------

def _is_local(part) -> bool:
    return isinstance(part, StatePartition) and part.model == MODEL_LOCAL


def _local_map(fn, tree, partition):
    """Map ``fn(leaf, part)`` over ``tree`` zipped with its partition tree
    (whose leaves are StatePartition records or None for uncompressed
    positions)."""
    return jax.tree_util.tree_map(
        fn, tree, partition,
        is_leaf=lambda x: x is None or isinstance(x, StatePartition))


def _shard_model_coord(shard, mesh, model_axis: str):
    """(model coordinate, is-data-rank-zero) of one addressable shard,
    read off the shard's device position in the mesh array."""
    pos = np.argwhere(mesh.devices == shard.device)
    assert pos.shape[0] == 1, (shard.device, mesh.devices)
    coords = dict(zip(mesh.axis_names, pos[0]))
    mcoord = int(coords.pop(model_axis, 0))
    return mcoord, all(int(c) == 0 for c in coords.values())


def canonicalize_mesh(mesh, params, ef: EFState, partition: EFState,
                      model_axis: str = "model") -> Tuple[Any, EFState]:
    """Gather model-LOCAL compressor leaves into the stacked canonical
    layout before :func:`save_train_state`.

    Model-local leaves (row-parallel weights' Q factors — see
    :func:`repro.core.powersgd.factor_partition`) carry *distinct
    per-model-rank content behind a replicated-shaped spec*; a plain
    ``np.asarray`` would silently serialize device 0's (model rank 0's)
    replica and a restore would hand every rank that copy.  Here each model
    rank's copy is read host-side from the array's addressable shards (the
    data-rank-0 replica per model coordinate — no collectives, so compile-
    time collective budgets are untouched) and stacked along a leading
    ``(model_axis_size,)`` dim.  Degree-1 meshes pass through unchanged, so
    single-axis and SimMesh envelopes keep their pre-existing layout.
    """
    size = int(mesh.shape.get(model_axis, 1))
    if size <= 1:
        return params, ef

    def gather(x, part):
        if not _is_local(part):
            return x
        per = {}
        for shard in x.addressable_shards:
            mcoord, data_zero = _shard_model_coord(shard, mesh, model_axis)
            if data_zero:
                per[mcoord] = np.asarray(shard.data)
        assert sorted(per) == list(range(size)), sorted(per)
        return np.stack([per[c] for c in range(size)])

    # the in-flight aggregate is sharded like params (never model-LOCAL),
    # so it serializes correctly without a gather
    return params, EFState(
        error=ef.error, momentum=ef.momentum,
        comp=_local_map(gather, ef.comp, partition.comp), step=ef.step,
        inflight=ef.inflight)


def replicate_mesh(mesh, params, ef: EFState, partition: EFState,
                   model_axis: str = "model") -> Tuple[Any, EFState]:
    """Inverse of :func:`canonicalize_mesh`: re-slice stacked model-LOCAL
    leaves onto ``mesh`` so every model rank gets *its own* pre-save copy
    back.

    Each device receives the slice for its model coordinate via
    ``jax.make_array_from_single_device_arrays`` under the leaf's declared
    (replicated-shaped) sharding — exactly the layout the live train step
    produces, so the jitted step consumes it without a resharding copy.
    The stack's leading dim must equal the mesh's model degree
    (:func:`restore_train_state`'s ``model_axis_size`` guard enforces this
    before the slicing is ever reached)."""
    from jax.sharding import NamedSharding, PartitionSpec

    size = int(mesh.shape.get(model_axis, 1))
    if size <= 1:
        return params, ef

    def scatter(x, part):
        if not _is_local(part):
            return x
        x = np.asarray(x)
        assert x.shape[0] == size, (x.shape, size)
        sharding = NamedSharding(mesh, part.spec or PartitionSpec())
        arrays = []
        for d in mesh.devices.flat:
            pos = np.argwhere(mesh.devices == d)[0]
            mcoord = int(pos[mesh.axis_names.index(model_axis)])
            arrays.append(jax.device_put(x[mcoord], d))
        return jax.make_array_from_single_device_arrays(
            x.shape[1:], sharding, arrays)

    return params, EFState(
        error=ef.error, momentum=ef.momentum,
        comp=_local_map(scatter, ef.comp, partition.comp), step=ef.step,
        inflight=ef.inflight)


def stack_model_template(ef: EFState, partition: EFState,
                         model_axis_size: int) -> EFState:
    """Restore template in the stacked canonical layout: model-LOCAL comp
    leaves gain the leading ``(model_axis_size,)`` dim the envelope stores
    them with.  Degree 1 is the identity (matching degree-1 and legacy
    envelopes)."""
    size = int(model_axis_size)
    if size <= 1:
        return ef

    def stack(x, part):
        if not _is_local(part):
            return x
        return jax.ShapeDtypeStruct((size,) + tuple(x.shape), x.dtype)

    return EFState(error=ef.error, momentum=ef.momentum,
                   comp=_local_map(stack, ef.comp, partition.comp),
                   step=ef.step, inflight=ef.inflight)


# ---------------------------------------------------------------------------
# SimMesh ⇄ canonical layout
# ---------------------------------------------------------------------------

def canonicalize_sim(sim, params, ef: EFState) -> Tuple[Any, EFState]:
    """Strip a SimMesh run's stacked worker dim from every replicated tree
    (params, momentum, compressor factors, step), keeping the genuinely
    per-worker error-buffer stack — the canonical checkpoint layout."""
    return sim.unreplicate(params), EFState(
        error=ef.error,
        momentum=sim.unreplicate(ef.momentum),
        comp=sim.unreplicate(ef.comp),
        step=sim.unreplicate(ef.step),
        inflight=sim.unreplicate(ef.inflight))


def replicate_sim(sim, params, ef: EFState) -> Tuple[Any, EFState]:
    """Inverse of :func:`canonicalize_sim` onto ``sim`` — which may have a
    *different* worker count than the canonical state was saved from:
    replicated trees re-broadcast, error buffers re-shard through
    :func:`repro.core.error_feedback.rescale_error_buffers`."""
    return sim.replicate(params), EFState(
        error=error_feedback.rescale_error_buffers(ef.error, sim.workers),
        momentum=sim.replicate(ef.momentum),
        comp=sim.replicate(ef.comp),
        step=sim.replicate(ef.step),
        inflight=sim.replicate(ef.inflight))
