"""Versioned full-algorithm-state checkpoints for fault-tolerant resume.

PowerSGD's trajectory is a function of more than the parameters: the
error-feedback buffers (Alg. 1 line "e_w ← Δ_w − recon"), the warm-started
Q factors (§3 warm-start ablation), the rank-schedule position, the PRNG
stream and the data cursor all carry across steps.  A checkpoint that saves
only ``{"params", "ef"}`` with no resume path silently restarts all of the
non-parameter state from zero — :class:`TrainState` is the envelope that
makes "resume" mean *bit-exact continuation*:

* ``params`` and the full :class:`~repro.core.error_feedback.EFState`
  (per-worker error buffers, momentum, warm-start factors, step counter),
* ``key`` — the run's *base* PRNG key; per-step keys are derived as
  ``fold_in(key, step)``, so (key, step) reproduces the stream,
* ``data_step`` — the cursor into the deterministic batch stream
  (:class:`repro.data.synthetic.MarkovLM` samples are keyed by step),
* host-side scalars in the envelope's ``meta`` dict: worker count, the
  :class:`~repro.core.powersgd.RankController` state (rank, residual EMA,
  switch history, transition PRNG key) and any caller extras (schedule
  spec, last residual).

Canonical worker layout: everything *replicated* across data-parallel
workers (params, momentum, compressor factors, step) is stored once,
without a worker dim; only the genuinely per-worker error buffers keep
their stacked leading ``(W, ...)`` dim.  :func:`canonicalize_sim` /
:func:`replicate_sim` convert a :class:`~repro.core.simmesh.SimMesh` run's
stacked trees to/from this layout; the distributed train step's state is
already canonical (its error buffers are the global ``(dp_total, ...)``
stack).

Elastic resume: :func:`restore_train_state` restores into a template whose
error buffers may carry a *different* worker count — the buffers are
re-sharded by :func:`repro.core.error_feedback.rescale_error_buffers`
(worker-**mean**-preserving; see its docstring for the exact grow / shrink
/ coprime semantics).  Same-W restores are bit-exact; rescaled restores are
trajectory-preserving in the Lemma-3 sense.  Likewise the template's
compressor factors may sit at a different *rank* than the checkpoint (the
template is built from config, the checkpoint may be mid-staircase): the
checkpoint's factors win, and the jitted step simply retraces at the
checkpointed rank.  Every other leaf must match the template in shape and
dtype exactly (:class:`~repro.checkpoint.msgpack_ckpt.CheckpointError`
names the offending leaf otherwise).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint.msgpack_ckpt import (
    CheckpointError, load_envelope, restore_tree, save_checkpoint)
from repro.core import error_feedback
from repro.core.error_feedback import EFState

TRAIN_STATE_VERSION = 1

# envelope-leaf path prefixes with relaxed shape matching (see module doc)
_COMP_PREFIX = "['ef'].comp"
_ERROR_PREFIX = "['ef'].error"


@dataclasses.dataclass
class TrainState:
    """The whole resumable algorithm state (see module docstring)."""

    params: Any
    ef: EFState
    key: jax.Array        # base PRNG key (typed key array or raw uint32)
    data_step: jax.Array  # int32 batch-stream cursor


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "ef", "key", "data_step"],
    meta_fields=[])


# ---------------------------------------------------------------------------
# PRNG keys: msgpack only sees raw uint32 key data + a dtype tag in meta
# ---------------------------------------------------------------------------

def key_to_data(key: jax.Array) -> Tuple[jax.Array, str]:
    """(serializable uint32 data, dtype tag) for a typed or raw PRNG key."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(key), str(key.dtype)
    return key, "raw"


def key_from_data(data: jax.Array, tag: str) -> jax.Array:
    if tag == "raw":
        return data
    key = jax.random.wrap_key_data(data)
    if str(key.dtype) != tag:
        raise CheckpointError(
            f"PRNG key impl mismatch: checkpoint was saved with {tag}, "
            f"this process wraps key data as {key.dtype} — resume under "
            f"the same jax_default_prng_impl")
    return key


# ---------------------------------------------------------------------------
# save / restore
# ---------------------------------------------------------------------------

def _as_tree(state: TrainState, key_data) -> dict:
    return {"params": state.params,
            "ef": state.ef,
            "key_data": key_data,
            "data_step": state.data_step}


def _error_workers(ef: EFState) -> Optional[int]:
    leaves = jax.tree_util.tree_leaves(ef.error)
    return leaves[0].shape[0] if leaves else None


def save_train_state(directory: str, state: TrainState, *,
                     controller=None, keep: int = 3,
                     extra_meta: Optional[dict] = None) -> str:
    """Write one full-state checkpoint at ``state.ef.step``.

    ``state`` must be in the canonical worker layout (see module
    docstring; SimMesh runs go through :func:`canonicalize_sim` first).
    ``controller`` — the run's
    :class:`~repro.core.powersgd.RankController`, serialized into ``meta``
    so a resume continues the schedule (and its transition PRNG stream)
    from the exact position.
    """
    key_data, key_tag = key_to_data(state.key)
    meta = {
        "train_state_version": TRAIN_STATE_VERSION,
        "workers": _error_workers(state.ef),
        "key_dtype": key_tag,
        "controller": None if controller is None else controller.state_dict(),
    }
    meta.update(extra_meta or {})
    return save_checkpoint(directory, int(state.ef.step),
                           _as_tree(state, key_data), keep=keep, meta=meta)


def restore_train_state(directory: str, template: TrainState,
                        step: Optional[int] = None
                        ) -> Tuple[TrainState, dict]:
    """Restore a :class:`TrainState`, adapting rank and worker count.

    ``template`` supplies structure and dtypes (typically a freshly
    initialized state at the *configured* rank and the *current* worker
    count).  Returns ``(state, meta)``; ``state`` carries the checkpoint's
    factor ranks (possibly ≠ template's — the jitted step retraces) and
    the template's worker count (error buffers rescaled when it differs
    from ``meta["workers"]``).  Raises :class:`CheckpointError` on
    truncation/corruption or any other structure/shape/dtype mismatch.
    """
    payload = load_envelope(directory, step)
    meta = payload["meta"]
    if "train_state_version" not in meta:
        raise CheckpointError(
            f"checkpoint in {directory} is not a TrainState envelope "
            f"(plain save_checkpoint tree?) — no train_state_version in "
            f"meta")

    def shape_ok(tpath, gs, ws):
        if tpath.startswith(_COMP_PREFIX):
            return gs[:-1] == ws[:-1]    # rank (last dim) may move
        if tpath.startswith(_ERROR_PREFIX):
            return gs[1:] == ws[1:]      # worker count (dim 0) may move
        return False

    key_data, _ = key_to_data(template.key)
    tree = restore_tree(payload, _as_tree(template, key_data),
                        shape_ok=shape_ok)
    ef: EFState = tree["ef"]
    w_new = _error_workers(template.ef)
    if w_new is not None and _error_workers(ef) != w_new:
        ef = EFState(
            error=error_feedback.rescale_error_buffers(ef.error, w_new),
            momentum=ef.momentum, comp=ef.comp, step=ef.step)
    state = TrainState(
        params=tree["params"], ef=ef,
        key=key_from_data(tree["key_data"], meta.get("key_dtype", "raw")),
        data_step=tree["data_step"])
    return state, meta


# ---------------------------------------------------------------------------
# SimMesh ⇄ canonical layout
# ---------------------------------------------------------------------------

def canonicalize_sim(sim, params, ef: EFState) -> Tuple[Any, EFState]:
    """Strip a SimMesh run's stacked worker dim from every replicated tree
    (params, momentum, compressor factors, step), keeping the genuinely
    per-worker error-buffer stack — the canonical checkpoint layout."""
    return sim.unreplicate(params), EFState(
        error=ef.error,
        momentum=sim.unreplicate(ef.momentum),
        comp=sim.unreplicate(ef.comp),
        step=sim.unreplicate(ef.step))


def replicate_sim(sim, params, ef: EFState) -> Tuple[Any, EFState]:
    """Inverse of :func:`canonicalize_sim` onto ``sim`` — which may have a
    *different* worker count than the canonical state was saved from:
    replicated trees re-broadcast, error buffers re-shard through
    :func:`repro.core.error_feedback.rescale_error_buffers`."""
    return sim.replicate(params), EFState(
        error=error_feedback.rescale_error_buffers(ef.error, sim.workers),
        momentum=sim.replicate(ef.momentum),
        comp=sim.replicate(ef.comp),
        step=sim.replicate(ef.step))
