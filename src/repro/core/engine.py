"""Unified compressed-collective transport engine.

The paper's central systems claim (§3) is about *how compressed payloads
travel*: linear schemes can all-reduce their compressed representation
directly, non-linear schemes must all-gather every worker's payload and pay
W-scaled decode + traffic.  This module is the one place that logic lives —
every compressor in the zoo rides it, so each scheme costs O(1) fused
data-axis collectives per step instead of one latency-bound collective per
parameter leaf.

Three layers, bottom-up:

* :class:`Transport` — fused data-axis collectives bound to a
  :class:`~repro.core.dist.MeshCtx` and a wire policy (``wire_dtype``,
  ``max_chunk_bytes``; see :func:`repro.core.matrixize.plan_flat`).
  ``reduce_mean`` is the all-reduce path (linear payloads), ``gather`` the
  all-gather path (non-linear payloads; payloads come back with a leading
  worker dim), and ``combine_mean`` the receiver-side weighted average over
  gathered decodes — exactly a weighted ``pmean``, including the
  guarded-denominator semantics of :class:`~repro.core.dist.SimBackend`.

* :class:`MatrixPayloads` — the batched *compute* plan for matrix-shaped
  schemes (PowerSGD): collect a tree's leaves, matrixize the compressed
  ones, bucket them into zero-padded ``(B, n, m)`` slabs
  (:func:`repro.core.matrixize.plan_buckets`), and scatter results back to
  the tree.  ``core/powersgd.py`` is pure math (project / orthogonalize /
  backproject) against this plan plus a :class:`Transport`.

* :func:`run_step` — the generic driver for single-round payload schemes.
  A compressor declares *what travels* through the protocol below; the
  engine decides *how it travels* from ``wire_mode``:

  ==============  =====================================================
  ``wire_mode``   transport
  ==============  =====================================================
  ``"reduce"``    payloads fused + all-reduced; ``decode_leaf`` runs once
                  on the aggregated payload (linearity: decode∘mean =
                  mean∘decode)
  ``"gather"``    payloads fused + all-gathered; ``decode_leaf`` runs per
                  worker payload and the W reconstructions are
                  weight-averaged on the receiver
  ==============  =====================================================

  Uncompressed leaves (biases, norms) always ride a fused all-reduce.

Compressor protocol (duck-typed; see ``core/compressors.py``)::

    encode_leaf(path, g, q, spec, key) -> Encoded | None   # None = uncompressed
    decode_leaf(enc, payload)          -> reconstruction (full tensor shape)
    wire_mode:    "reduce" | "gather"
    recon_is_agg: bool  # error-feedback recon = aggregated decode (oracles)

``Encoded.payload`` is the tuple of arrays that cross the wire; ``aux``
stays on-device (shared-seed offsets, sampling matrices, shape/spec
breadcrumbs for decode); ``bits`` is the scheme's analytic payload size.

Worked end-to-end example — ``TopK(rank=2)`` over a 2-leaf tree on a
W=4 data-parallel mesh, one ``run_step`` call::

    tree:   {"w": f32[64, 32]  (spec kind="matrix"),
             "b": f32[32]      (spec kind="none")}

    1. encode  — "w": budget b = r·(n+m) = 192 coordinates;
                 encode_leaf → Encoded(payload=(values f32[192],
                                                indices i32[192]),
                                       aux=(None, (64, 32)), bits=192·64)
                 "b": encode_leaf → None (vector leaf, uncompressed)
    2. fuse    — payload parts [values, indices] are planned onto wire
                 chunks (matrixize.plan_flat): under wire_dtype="auto"
                 the f32 values form chunk 0 (itemsize 4) and the i32
                 indices chunk 1 (itemsize 4, its own dtype — ints are
                 never cast); "b" rides a separate fused *reduce*.
    3. travel  — wire_mode="gather": each chunk is all-gathered ONCE over
                 the data axes (Transport.gather → MeshCtx.allgather_flat);
                 every part returns with a leading worker dim:
                 values f32[4, 192], indices i32[4, 192].  CollectiveStats
                 records kind="gather", fanout=4, so bytes_per_collective
                 reports 4× the per-worker payload.  Meanwhile "b" came
                 back from ONE pmean as the worker-mean f32[32].
                 Total: 2 gather collectives + 1 reduce — O(1), whatever
                 the number of leaves.
    4. decode  — decode_leaf runs per worker payload (vmap over the
                 leading dim) → reconstructions f32[4, 64, 32], then
                 Transport.combine_mean averages them (weighted by
                 gather_data_weight() under scenario weights) into the
                 aggregated update f32[64, 32].  The error-feedback recon
                 is the *local* decode (recon_is_agg=False):
                 decode_leaf(enc, enc.payload) → f32[64, 32].

    A "reduce" scheme (e.g. UnbiasedRankK) differs only in step 3/4: the
    fused chunks are pmean'd in place and decode_leaf runs ONCE on the
    aggregated payload — decode∘mean = mean∘decode is exactly the paper's
    Lemma 3 linearity.

``CollectiveStats`` sees the difference: reduce-pattern records stay flat in
W, gather-pattern records carry ``fanout = data_size()`` so
``bytes_per_collective`` reports the W-scaled wire traffic — the honest
accounting the benchmarks compare (mis-modeling exactly this flips
conclusions; Agarwal et al., "On the Utility of Gradient Compression").
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import dist, matrixize
from repro.core.dist import MeshCtx, SINGLE


@dataclasses.dataclass
class CompressOut:
    """What one compress+aggregate step hands back to error feedback."""

    agg: Any            # tree: aggregated decompressed update (= mean_w Δ'_w)
    recon: Any          # tree: reconstruction used for the error update
    state: Any          # tree: new compressor state (e.g. warm-start Q)
    bits_per_worker: int  # payload bits sent per step per model shard
    metrics: Any = None   # optional dict of traced observability scalars
    #   (e.g. PowerSGD's residual-energy ratios when
    #   ``PowerSGDConfig.track_residual`` is on) — consumed by host-side
    #   controllers such as :class:`repro.core.powersgd.RankController`


def leaf_key(key: jax.Array, path) -> jax.Array:
    """Deterministic per-leaf PRNG key: shared-seed schemes rely on every
    worker deriving the same key from the same tree path."""
    h = hashlib.sha256(jax.tree_util.keystr(path).encode()).digest()
    return jax.random.fold_in(key, int.from_bytes(h[:4], "little"))


@dataclasses.dataclass(frozen=True)
class Encoded:
    """One leaf's wire declaration: ``payload`` travels, ``aux`` stays local
    (shared-seed indices, sampling matrices), ``bits`` is the analytic
    payload size (paper Tables 3/10/11 conventions)."""

    payload: Tuple[jax.Array, ...]
    aux: Any = None
    bits: int = 0

    def __post_init__(self):
        object.__setattr__(self, "payload", tuple(self.payload))


# ---------------------------------------------------------------------------
# Transport: fused data-axis collectives + receiver-side combine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Transport:
    """Fused data-axis transport bound to a context and a wire policy."""

    ctx: MeshCtx = SINGLE
    wire_dtype: str = "auto"            # matrixize.WIRE_DTYPES ("auto" |
    #                                     float/bfloat16 cast | int8/int4 quant)
    max_chunk_bytes: Optional[int] = None

    def reduce_mean(self, parts: Sequence[jax.Array],
                    sync: Optional[bool] = None) -> List[jax.Array]:
        """Fused all-reduce-mean (O(1) collectives; linear payloads).

        ``sync=False`` (meaningful under ``sync_mode="broadcast"`` only)
        marks this reduce as an *internal phase* of a multi-reduce scheme:
        it still uses the canonical deterministic reduction order but does
        not record a per-call broadcast leg — the scheme ends with one
        fused :meth:`broadcast` instead."""
        return self.ctx.pmean_flat(parts, wire_dtype=self.wire_dtype,
                                   max_chunk_bytes=self.max_chunk_bytes,
                                   sync=sync)

    def broadcast(self, parts: Sequence[jax.Array]) -> List[jax.Array]:
        """Fused rank-0 broadcast — the end-of-step replica sync of
        ``sync_mode="broadcast"`` (see :meth:`MeshCtx.broadcast_flat`)."""
        return self.ctx.broadcast_flat(parts, wire_dtype=self.wire_dtype,
                                       max_chunk_bytes=self.max_chunk_bytes)

    def gather(self, parts: Sequence[jax.Array]) -> List[jax.Array]:
        """Fused all-gather (O(1) collectives; non-linear payloads).  Every
        part returns with a leading worker dim of ``ctx.data_size()``."""
        return self.ctx.allgather_flat(parts, wire_dtype=self.wire_dtype,
                                       max_chunk_bytes=self.max_chunk_bytes)

    def combine_mean(self, stacked: jax.Array,
                     weights: Optional[jax.Array]) -> jax.Array:
        """Average W per-worker decodes over the leading gathered dim:
        ``mean_w`` (uniform) or the shared weighted-``pmean`` semantics of
        :func:`repro.core.dist.weighted_mean` (all-dropped round → exact
        zero, not NaN)."""
        if weights is None:
            return jnp.mean(stacked, axis=0)
        w = weights.reshape((-1,) + (1,) * (stacked.ndim - 1))
        return dist.weighted_mean(stacked, w, lambda v: jnp.sum(v, axis=0))


@dataclasses.dataclass(frozen=True)
class PipelinedTransport(Transport):
    """Double-buffered :class:`Transport` — the engine half of the one-step-
    stale pipeline (ISSUE 8).

    Two levels of overlap, both bit-identical to the serial transport:

    * **Intra-step** — :meth:`reduce_mean` emits the interleaved chunk
      schedule (``MeshCtx.pmean_flat(interleave=True)``): the fused reduce
      for payload chunk b is issued before chunk b−1 is unpacked, so the
      two-phase PowerSGD loop decompresses bucket b−1 while bucket b is on
      the wire.  Same chunks, same bytes, same reduction order, and
      :class:`~repro.core.dist.CollectiveStats` records at *issue* time —
      the collective-budget guards see exactly the serial trace.

    * **Cross-step** — :meth:`shift` is the explicit double-buffer rotation
      for ``staleness="one_step"``: hand it this step's fresh aggregate and
      the carried in-flight buffer, get back the buffer to *apply* now
      (step t−1's) and the new in-flight state (step t's).  The in-flight
      tree is explicit state so the train step can checkpoint it
      (``EFState.inflight``).
    """

    def reduce_mean(self, parts: Sequence[jax.Array],
                    sync: Optional[bool] = None) -> List[jax.Array]:
        return self.ctx.pmean_flat(parts, wire_dtype=self.wire_dtype,
                                   max_chunk_bytes=self.max_chunk_bytes,
                                   sync=sync, interleave=True)

    @staticmethod
    def shift(fresh, inflight):
        """Rotate the double buffer: returns ``(apply_now, new_inflight)``
        = ``(inflight, fresh)``.  Pure structure — numerics untouched."""
        return inflight, fresh

    @staticmethod
    def init_inflight(params):
        """The step-0 in-flight buffer: a zero aggregate shaped like
        ``params`` (the pipeline bubble applies no update)."""
        return jax.tree_util.tree_map(jnp.zeros_like, params)


# ---------------------------------------------------------------------------
# tree walking shared by every engine path
# ---------------------------------------------------------------------------


def collect_leaves(deltas, state, specs) -> list:
    """Flatten aligned (path, g, q, spec) tuples in deterministic tree order.

    ``state`` may be ``None`` (stateless schemes): every leaf then gets
    ``q=None``.
    """
    leaves = []

    def visit(path, g, q, spec):
        leaves.append((path, g, q, spec))
        return 0

    if state is None:
        jax.tree_util.tree_map_with_path(
            lambda path, g, spec: visit(path, g, None, spec), deltas, specs,
            is_leaf=lambda x: x is None)
    else:
        jax.tree_util.tree_map_with_path(
            visit, deltas, state, specs, is_leaf=lambda x: x is None)
    return leaves


def scatter_tree(deltas, specs, results, collapse_state: bool = True):
    """Re-assemble per-leaf ``(agg, recon, new_state)`` triples (in the same
    order as :func:`collect_leaves`) into three trees shaped like ``deltas``.
    ``collapse_state`` folds an all-``None`` state tree to a bare ``None``
    (stateless schemes); stateful schemes keep the per-leaf tree so their
    state layout round-trips exactly."""
    counter = [0]

    def emit(path, g, spec):
        out = results[counter[0]]
        counter[0] += 1
        return out

    triples = jax.tree_util.tree_map_with_path(
        emit, deltas, specs, is_leaf=lambda x: x is None)
    is_t = lambda x: isinstance(x, tuple)
    agg = jax.tree_util.tree_map(lambda t: t[0], triples, is_leaf=is_t)
    recon = jax.tree_util.tree_map(lambda t: t[1], triples, is_leaf=is_t)
    state = jax.tree_util.tree_map(lambda t: t[2], triples, is_leaf=is_t)
    if collapse_state and not jax.tree_util.tree_leaves(state):
        state = None
    return agg, recon, state


# ---------------------------------------------------------------------------
# generic single-round driver (the whole zoo except PowerSGD's 2-phase loop)
# ---------------------------------------------------------------------------


def run_step(comp, deltas, state, specs, ctx: MeshCtx = SINGLE,
             key: Optional[jax.Array] = None, *, wire_dtype: str = "auto",
             max_chunk_bytes: Optional[int] = None) -> CompressOut:
    """One compress+aggregate step through the fused transport engine.

    Collects the tree's leaves, asks the compressor to encode each one,
    fuses all payloads into O(1) collectives (reduce or gather per
    ``comp.wire_mode``), decodes, and scatters back to the tree.  Vector
    leaves the scheme leaves uncompressed (``encode_leaf → None``) ride a
    fused all-reduce — for gather schemes that is one extra reduce next to
    the payload gather.
    """
    assert not comp.stateful, (
        f"{comp.name}: run_step drives stateless single-round schemes; "
        "stateful multi-round schemes (PowerSGD) schedule their own "
        "Transport phases")
    transport = Transport(ctx=ctx, wire_dtype=wire_dtype,
                          max_chunk_bytes=max_chunk_bytes)
    leaves = collect_leaves(deltas, state, specs)

    encs, bits = [], 0
    for path, g, q, spec in leaves:
        enc = comp.encode_leaf(path, g, q, spec,
                               leaf_key(key, path) if key is not None else None)
        assert enc is None or isinstance(enc, Encoded), comp.name
        encs.append(enc)
        bits += (matrixize.uncompressed_floats(g.shape) * 32 if enc is None
                 else enc.bits)

    unc_ids = [i for i, e in enumerate(encs) if e is None]
    enc_ids = [i for i, e in enumerate(encs) if e is not None]
    payload_parts, payload_slices = [], {}
    for i in enc_ids:
        payload_slices[i] = (len(payload_parts),
                             len(payload_parts) + len(encs[i].payload))
        payload_parts.extend(encs[i].payload)

    results: dict = {}
    if comp.wire_mode == "reduce":
        # one fused pass: payloads + uncompressed leaves share the wire
        reduced = transport.reduce_mean(
            payload_parts + [leaves[i][1] for i in unc_ids])
        for i in enc_ids:
            lo, hi = payload_slices[i]
            agg = comp.decode_leaf(encs[i], tuple(reduced[lo:hi]))
            recon = agg if comp.recon_is_agg else (
                comp.decode_leaf(encs[i], encs[i].payload))
            results[i] = (agg, recon, None)
        for j, i in enumerate(unc_ids):
            results[i] = (reduced[len(payload_parts) + j], leaves[i][1], None)
    elif comp.wire_mode == "gather":
        unc_agg = transport.reduce_mean([leaves[i][1] for i in unc_ids])
        for j, i in enumerate(unc_ids):
            results[i] = (unc_agg[j], leaves[i][1], None)
        gathered = transport.gather(payload_parts)   # each: (W,) + shape
        weights = ctx.gather_data_weight()
        for i in enc_ids:
            lo, hi = payload_slices[i]
            decode_w = jax.vmap(
                lambda *p, _e=encs[i]: comp.decode_leaf(_e, tuple(p)))
            decoded = decode_w(*gathered[lo:hi])     # (W,) + leaf shape
            agg = transport.combine_mean(decoded, weights)
            recon = agg if comp.recon_is_agg else (
                comp.decode_leaf(encs[i], encs[i].payload))
            results[i] = (agg, recon, None)
    else:
        raise ValueError(f"unknown wire_mode {comp.wire_mode!r} on {comp.name}")

    agg, recon, new_state = scatter_tree(
        deltas, specs, [results[i] for i in range(len(leaves))])
    return CompressOut(agg=agg, recon=recon, state=new_state,
                       bits_per_worker=bits)


# ---------------------------------------------------------------------------
# Per-leaf state partitioning: how compressor state relates to the model axis
# ---------------------------------------------------------------------------

# A state leaf's content can relate to the mesh's model axis in three ways.
# The distinction matters because only the first two are visible in the
# leaf's dims-PartitionSpec — the third is exactly the class of leaves a
# naive `np.asarray` checkpoint silently corrupts (it reads device 0's
# replica, i.e. model rank 0's copy).
MODEL_REPLICATED = "replicated"  # same bits on every model rank
MODEL_SHARDED = "sharded"        # a dim carries the model axis (honest spec)
MODEL_LOCAL = "local"            # per-model-rank content with NO dim carrying
#                                  the axis (e.g. the Q factor of a
#                                  row-parallel weight: Q = Mᵀ P̂ is computed
#                                  from the rank's local n-rows of M, but its
#                                  (m, r) dims are replicated-shaped)


@dataclasses.dataclass(frozen=True)
class StatePartition:
    """Partition record for one compressor-state leaf.

    ``spec`` is the dims PartitionSpec the engine declares for the leaf
    (what ``shard_map`` in/out specs use); ``model`` is one of
    :data:`MODEL_REPLICATED` / :data:`MODEL_SHARDED` / :data:`MODEL_LOCAL`
    and tells the checkpoint layer whether the leaf needs a per-model-rank
    gather at save and a re-slice at restore (``checkpoint/train_state.py::
    canonicalize_mesh`` / ``replicate_mesh``).  Unregistered dataclass —
    trees of these are trees of leaves.
    """

    spec: Any    # jax.sharding.PartitionSpec (dims only)
    model: str   # MODEL_REPLICATED | MODEL_SHARDED | MODEL_LOCAL


def partition_leaves(partition, leaves) -> list:
    """Per-leaf model relation aligned with :func:`collect_leaves` output.

    ``partition`` is a tree of :class:`StatePartition`/None shaped like the
    compressor state; returns one relation string (or None) per leaf, in the
    same deterministic order ``collect_leaves`` produces."""
    flat = jax.tree_util.tree_flatten(
        partition, is_leaf=lambda x: x is None)[0]
    rels = [None if p is None else p.model for p in flat]
    assert len(rels) == len(leaves), (len(rels), len(leaves))
    return rels


def partition_mismatches(state, partition, model_axis: str = "model",
                         mesh_axes=None) -> list:
    """Structural audit of a :class:`StatePartition` tree against a state.

    Returns ``(path, problem, detail)`` triples — empty when the partition
    tree is sound.  Checked per state leaf (array or ShapeDtypeStruct):

    * **classified** — a :class:`StatePartition` exists at the leaf's
      position.  An unclassified leaf is invisible to the checkpoint
      gather/re-slice path and silently saves rank 0's copy (the PR 7
      corruption class).
    * **spec-fits** — the dims spec mentions at most ``ndim`` dims and only
      known mesh axes (when ``mesh_axes`` is given).
    * **spec-model-consistent** — the dims spec mentions ``model_axis``
      iff the leaf is :data:`MODEL_SHARDED`.  :data:`MODEL_LOCAL` means
      per-rank content behind a replicated-*shaped* spec, so a model-axis
      entry there (or on a replicated leaf) is a contradiction, and a
      sharded leaf without one is dishonest about its bytes.

    Used by gradlint's partition-consistency pass
    (``repro.analysis.partition``) and usable by checkpoint tooling as a
    pre-save sanity check.
    """
    from repro.core import powersgd as _psgd

    problems = []
    state_paths = {
        jax.tree_util.keystr(path): leaf
        for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]}
    part_paths = {
        jax.tree_util.keystr(path): part
        for path, part in jax.tree_util.tree_flatten_with_path(
            partition, is_leaf=lambda x: isinstance(x, StatePartition))[0]
        if isinstance(part, StatePartition)}

    for path, leaf in sorted(state_paths.items()):
        part = part_paths.get(path)
        if part is None:
            problems.append((path, "unclassified",
                             f"state leaf {getattr(leaf, 'shape', '?')} has "
                             "no StatePartition"))
            continue
        entries = tuple(part.spec) if part.spec is not None else ()
        ndim = len(getattr(leaf, "shape", ()))
        if len(entries) > ndim:
            problems.append((path, "spec-rank",
                             f"spec {part.spec} names {len(entries)} dims "
                             f"for a {ndim}-d leaf"))
        if mesh_axes is not None:
            for e in entries:
                for ax in ((e,) if isinstance(e, str) else (e or ())):
                    if ax not in mesh_axes:
                        problems.append((path, "unknown-axis",
                                         f"spec {part.spec} names axis "
                                         f"{ax!r} not on the mesh "
                                         f"{tuple(mesh_axes)}"))
        mentions_model = any(
            _psgd._mentions(e, model_axis) for e in entries)
        if part.model == MODEL_SHARDED and not mentions_model:
            problems.append((path, "model-mismatch",
                             f"classified {MODEL_SHARDED} but spec "
                             f"{part.spec} never carries {model_axis!r}"))
        if part.model in (MODEL_REPLICATED, MODEL_LOCAL) and mentions_model:
            problems.append((path, "model-mismatch",
                             f"classified {part.model} but spec {part.spec} "
                             f"carries {model_axis!r}"))
    return problems


# ---------------------------------------------------------------------------
# MatrixPayloads: the bucketed pack/scatter plan for matrix-shaped schemes
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MatrixPayloads:
    """A tree's compressed leaves as zero-padded ``(B, n, m)`` bucket slabs.

    This is the pack/fuse/scatter machinery the bucketed PowerSGD engine
    used to carry inline: collect leaves, matrixize, plan shape buckets,
    stack the slabs (and warm-start factor slabs), and — after the caller
    has run whatever batched math + :class:`Transport` phases it wants on
    the slabs — crop and scatter results back to the original tree.  Zero
    padding is exact through the power-iteration math (see
    ``core/matrixize.py``).

    Adaptive rank: the rank is *not* a constructor constant — it is read
    off each leaf's warm-start factor (``q.shape[-1]``), so payload shapes
    follow whatever rank the active :class:`~repro.core.powersgd.
    RankSchedule` (or the :mod:`repro.core.autotune` planner) last
    installed into the state, with no re-plumbing.  Leaves sharing a shape
    bucket must share a rank (bucket slabs stack their factors into one
    ``(B, m, r)`` array); bucket membership is a pure function of matrix
    shapes (:func:`repro.core.matrixize.plan_buckets` is deterministic), so
    any per-bucket rank assignment made against the same plan — e.g. an
    :func:`repro.core.autotune.autotune` plan — satisfies this by
    construction.  The O(1)-collectives-per-step invariant is unaffected:
    however ranks vary across buckets, each transport phase still fuses
    all bucket factors into one flat chunk per wire dtype.
    """

    deltas: Any                      # the original tree (structure template)
    specs: Any
    leaves: list                     # (path, g, q, spec) in tree order
    plan: matrixize.BucketPlan
    m_bufs: List[jax.Array]          # per bucket: (B, n, m) matrix slab
    q_bufs: List[jax.Array]          # per bucket: (B, m, r_b) factor slab
    lshapes: list                    # per leaf: (batch_shape, n, m) or None
    unc_ids: List[int]               # leaves that travel uncompressed
    bucket_ranks: List[int]          # per bucket: its leaves' shared rank
    bits: int                        # analytic payload bits per worker
    bucket_model_sharded: List[bool] = None  # per bucket: any leaf whose
    #   matrixized M (hence its state) is model-sharded or model-local —
    #   i.e. the bucket's factors are NOT whole-mesh replicated and its
    #   state needs mesh-aware checkpointing.  None when no partition tree
    #   was supplied (single-axis runs; the information is then unknown).

    @classmethod
    def build(cls, deltas, state, specs, *, dtype,
              tolerance: float = 0.25,
              resample_key: Optional[jax.Array] = None,
              partition=None) -> "MatrixPayloads":
        """``resample_key`` replaces every warm-start factor with a fresh
        i.i.d. normal draw (cold start, at the factor's own rank), derived
        per leaf via :func:`leaf_key`.  ``partition`` is an optional tree of
        :class:`StatePartition` aligned with ``state`` — when given, each
        bucket learns whether it holds model-sharded/-local leaves
        (``bucket_model_sharded``)."""
        leaves = collect_leaves(deltas, state, specs)
        relations = (None if partition is None
                     else partition_leaves(partition, leaves))
        mats, qs, plan_shapes, lshapes, unc_ids = [], [], [], [], []
        ranks = {}
        floats = 0
        for i, (path, g, q, spec) in enumerate(leaves):
            ms = matrixize.matrix_shape(g.shape, spec) if q is not None else None
            if ms is None:
                mats.append(None)
                qs.append(None)
                plan_shapes.append(None)
                lshapes.append(None)
                unc_ids.append(i)
                floats += matrixize.uncompressed_floats(g.shape)
                continue
            batch_shape, n, m = ms
            count = math.prod(batch_shape) if batch_shape else 1
            r = q.shape[-1]
            ranks[i] = r
            mats.append(matrixize.to_matrix(g, spec)
                        .astype(dtype).reshape((count, n, m)))
            if resample_key is not None:
                q = jax.random.normal(leaf_key(resample_key, path), q.shape,
                                      dtype=dtype)
            qs.append(q.astype(dtype).reshape((count, m, r)))
            plan_shapes.append((count, n, m))
            lshapes.append((batch_shape, n, m))
            floats += matrixize.compressed_floats(g.shape, spec, r)

        plan = matrixize.plan_buckets(plan_shapes, tolerance=tolerance)
        bucket_ranks = []
        for b in plan.buckets:
            rs = {ranks[e.index] for e in b.entries}
            if len(rs) != 1:
                raise ValueError(
                    "leaves sharing a shape bucket must share a rank "
                    f"(bucket ({b.n}, {b.m}) has ranks {sorted(rs)}); "
                    "assign ranks per bucket — see repro.core.autotune")
            bucket_ranks.append(rs.pop())
        bucket_ms = None
        if relations is not None:
            bucket_ms = [any(relations[e.index] not in (None, MODEL_REPLICATED)
                             for e in b.entries) for b in plan.buckets]
        return cls(
            deltas=deltas, specs=specs, leaves=leaves, plan=plan,
            m_bufs=[matrixize.pack_matrices(b, mats) for b in plan.buckets],
            q_bufs=[matrixize.pack_factors(b, qs) for b in plan.buckets],
            lshapes=lshapes, unc_ids=unc_ids, bucket_ranks=bucket_ranks,
            bits=floats * 32, bucket_model_sharded=bucket_ms)

    @property
    def unc_values(self) -> List[jax.Array]:
        """The uncompressed leaves' raw tensors (ride the first fused
        reduce)."""
        return [self.leaves[i][1] for i in self.unc_ids]

    def scatter(self, agg_bufs, recon_bufs, q_bufs, unc_agg):
        """Crop per-leaf blocks back out of the bucket slabs and emit the
        (agg, recon, state) trees.  ``unc_agg`` is aligned with
        ``unc_ids``."""
        unc_by_id = dict(zip(self.unc_ids, unc_agg))
        results = []
        for i, (path, g, q, spec) in enumerate(self.leaves):
            if self.lshapes[i] is None:
                results.append((unc_by_id[i], g, None))
                continue
            batch_shape, n, m = self.lshapes[i]
            b_id, entry = self.plan.entry_for(i)

            def crop(buf):
                mat = matrixize.unpack_entry(buf, entry, n, m)
                mat = mat.reshape(batch_shape + (n, m))
                return matrixize.from_matrix(mat, g.shape, spec).astype(g.dtype)

            new_q = matrixize.unpack_entry(q_bufs[b_id], entry, m)
            new_q = new_q.reshape(batch_shape + (m, self.bucket_ranks[b_id]))
            results.append((crop(agg_bufs[b_id]), crop(recon_bufs[b_id]),
                            new_q))
        return scatter_tree(self.deltas, self.specs, results,
                            collapse_state=False)
