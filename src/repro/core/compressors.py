"""The compressor zoo the paper benchmarks against (§5.1, Appendix G).

Every compressor implements the same interface so the error-feedback
optimizer (Alg. 2) and the benchmark harness can swap them freely:

    init(shapes, specs, key)                 -> state
    step(deltas, state, specs, ctx, key)     -> CompressOut

``CompressOut.agg`` is the aggregated decompressed update (mean over the
data axes) and ``CompressOut.recon`` is the reconstruction used for the
error-feedback update.  ``allreduce`` marks whether the scheme is linear
(all-reduce aggregatable) — the property the paper identifies as the key to
scalability (§3).

Transport: the fused engine vs the per-leaf reference path
----------------------------------------------------------
Every compressor runs through the unified transport engine
(:mod:`repro.core.engine`) by default: each scheme *declares* what travels
per leaf (``encode_leaf`` / ``decode_leaf``) and the engine fuses all
payloads into O(1) data-axis collectives per step — an all-reduce for
linear schemes (``wire_mode="reduce"``), a genuine W-scaled all-gather for
non-linear ones (``wire_mode="gather"``; every worker decodes all W
payloads, and :class:`~repro.core.dist.CollectiveStats` records the
gather-pattern traffic honestly).  ``transport="per_leaf"`` keeps the
original one-collective-per-leaf reference path (numerically matched by the
engine; see ``tests/sim/test_zoo_conformance.py``).  PowerSGD exposes the
same switch as ``bucketing="auto"|"off"``.

``bits_per_worker`` accounting
------------------------------
``CompressOut.bits_per_worker`` is the number of bits each worker (model
shard) contributes to gradient exchange per step — the paper's Tables
3/10/11 metric.  Conventions, uniform across the zoo:

* It counts the *payload* of the compressed representation (e.g. the r·(n+m)
  P and Q floats for PowerSGD), not wire overhead, headers, or padding that
  an implementation (such as the bucketed engine) may add for efficiency.
* Uncompressed leaves (biases, norms — ``MatrixSpec.kind == "none"``) are
  charged at full ``32 · numel`` by every compressor.
* Index/metadata side channels are included where the scheme needs them
  (Top-K charges 32 bits per index; Random-K / Random Block use shared
  seeds, so indices are free; Sign+Norm charges 1 bit per coordinate plus
  one 32-bit norm).
* The count is per step and per worker; multiply by ``ctx.data_size()`` for
  cluster-wide traffic (all-gather schemes) — ``benchmarks.common.comm_time``
  models the difference between all-reduce and all-gather scaling.

Actual on-the-wire bytes per collective (including bucket padding, the real
wire itemsize per chunk, and the W-scaling of gather payloads) are
observable via :class:`repro.core.dist.CollectiveStats`.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import engine, matrixize, powersgd
from repro.core.dist import MeshCtx, SINGLE
from repro.core.engine import CompressOut, Encoded, leaf_key as _leaf_key

TRANSPORTS = ("fused", "per_leaf")


class Compressor:
    """Base class; subclasses set ``name``, ``allreduce`` and the engine
    protocol (``encode_leaf`` / ``decode_leaf``).

    ``wire_mode`` defaults to the transport the ``allreduce`` flag implies
    ("reduce" for linear schemes, "gather" otherwise); oracles that need
    the *dense* aggregate before decoding (ExactRankK) override it.
    ``recon_is_agg`` marks schemes whose error-feedback reconstruction is
    the aggregated decode rather than the worker-local one.
    """

    name: str = "base"
    allreduce: bool = True
    stateful: bool = False   # carries per-matrix state (e.g. warm-start Q)
    recon_is_agg: bool = False

    def __init__(self, transport: str = "fused", wire_dtype: str = "auto",
                 max_chunk_bytes: Optional[int] = None):
        if transport not in TRANSPORTS:
            raise ValueError(
                f"unknown transport {transport!r}; use one of {TRANSPORTS}")
        if wire_dtype not in matrixize.WIRE_DTYPES:
            raise ValueError(
                f"unknown wire_dtype {wire_dtype!r}; "
                f"use one of {matrixize.WIRE_DTYPES}")
        self.transport = transport
        self.wire_dtype = wire_dtype
        self.max_chunk_bytes = max_chunk_bytes

    @property
    def wire_mode(self) -> str:
        return "reduce" if self.allreduce else "gather"

    #: gather schemes: the dtype census of the per-leaf payload parts on a
    #: float-dtype-homogeneous gradient tree — ``"float"`` for anything
    #: that follows the gradient dtype, concrete names for integer side
    #: channels (sign bytes, top-k indices).  ``matrixize.plan_flat`` fuses
    #: this census into wire chunks, so the chunk count — and with it the
    #: collective budget — is a pure function of (census, wire_dtype).
    payload_dtypes: tuple = ("float",)

    def payload_wire_chunks(self) -> int:
        """How many wire chunks :func:`matrixize.plan_flat` fuses the
        payload census into under this compressor's ``wire_dtype``:
        explicit float wire dtypes cast every part into one chunk; quant
        dtypes share one code chunk across float parts but never quantize
        integer side channels; ``auto`` keeps one chunk per dtype."""
        census = self.payload_dtypes
        if self.wire_dtype in ("float32", "bfloat16"):
            return 1
        # auto and quant wire dtypes both preserve the census: one chunk
        # per integer dtype plus one (code) chunk for the float parts
        n_int = len({d for d in census if d != "float"})
        return n_int + ("float" in census)

    def declared_budget(self) -> tuple:
        """``(total, reduce, gather)`` — the documented number of fused
        data-axis collectives one :meth:`step` issues on a gradient tree
        whose float leaves share a single dtype (every model tree here).

        This is the single source of truth behind the README budget table,
        the ``ZOO_BUDGETS`` conformance pins, and gradlint's static
        collective-budget pass (``repro.analysis.passes``): the paper's §3
        scalability argument is that this number is O(1) in model size,
        so it is a *declared* property of each scheme, not an observation.
        """
        if self.wire_mode == "reduce":
            return (1, 1, 0)
        n = self.payload_wire_chunks()
        return (1 + n, 1, n)

    def init(self, shapes, specs, key):
        return None

    def state_partition(self, param_pspecs, mspecs):
        """Per-leaf :class:`~repro.core.engine.StatePartition` tree for this
        compressor's state (shaped like :meth:`init`'s return), derived from
        the owning parameters' PartitionSpecs.  The launch layer calls this
        at step-build time and the checkpoint layer uses the result to
        gather/re-slice model-local leaves (``docs/checkpoint.md``).
        Stateless compressors have no state to partition: ``None``."""
        return None

    def step(self, deltas, state, specs, ctx: MeshCtx = SINGLE, key=None) -> CompressOut:
        if self.transport == "fused":
            return engine.run_step(self, deltas, state, specs, ctx, key,
                                   wire_dtype=self.wire_dtype,
                                   max_chunk_bytes=self.max_chunk_bytes)
        return self._step_per_leaf(deltas, state, specs, ctx, key)

    # -- engine protocol ----------------------------------------------------
    def encode_leaf(self, path, g, q, spec, key) -> Optional[Encoded]:
        """Declare what travels for one leaf; ``None`` = uncompressed."""
        raise NotImplementedError

    def decode_leaf(self, enc: Encoded, payload) -> jax.Array:
        """Reconstruct a full-shape tensor from one (possibly aggregated)
        payload."""
        raise NotImplementedError

    # -- per-leaf reference path --------------------------------------------
    def _step_per_leaf(self, deltas, state, specs, ctx, key) -> CompressOut:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _unzip3(triples):
    is_t = lambda x: isinstance(x, tuple)
    agg = jax.tree_util.tree_map(lambda t: t[0], triples, is_leaf=is_t)
    recon = jax.tree_util.tree_map(lambda t: t[1], triples, is_leaf=is_t)
    state = jax.tree_util.tree_map(lambda t: t[2], triples, is_leaf=is_t)
    return agg, recon, state


def _map_leaves(fn, deltas, state, specs, bits):
    """fn(path, g, q, spec) -> (agg, recon, new_q); threads bits counter."""
    triples = jax.tree_util.tree_map_with_path(
        fn, deltas, state, specs, is_leaf=lambda x: x is None
    )
    agg, recon, new_state = _unzip3(triples)
    if not jax.tree_util.tree_leaves(new_state):
        new_state = None  # stateless compressor: collapse dict-of-Nones
    return CompressOut(agg=agg, recon=recon, state=new_state, bits_per_worker=bits[0])


def _budget(shape, spec, rank):
    """Sparsifier budget b = (n+m)·r per matrix (paper Appendix G)."""
    ms = matrixize.matrix_shape(shape, spec)
    assert ms is not None
    batch_shape, n, m = ms
    return math.prod(batch_shape) * (n + m) * rank


# ---------------------------------------------------------------------------
# Identity (= full-precision SGD data path)
# ---------------------------------------------------------------------------

class IdentityCompressor(Compressor):
    """Full-precision baseline.

    bits_per_worker: ``32 · numel`` for every leaf (nothing is compressed).
    Transport: every leaf is its own payload, so the fused engine reduces
    the entire gradient in ONE flat collective per step — the classic
    gradient-bucketing data path of a DDP implementation.
    """

    name = "identity"
    allreduce = True

    def encode_leaf(self, path, g, q, spec, key):
        return Encoded(payload=(g,),
                       bits=matrixize.uncompressed_floats(g.shape) * 32)

    def decode_leaf(self, enc, payload):
        return payload[0]

    def _step_per_leaf(self, deltas, state, specs, ctx, key):
        bits = [0]

        def leaf(path, g, q, spec):
            bits[0] += matrixize.uncompressed_floats(g.shape) * 32
            return ctx.pmean_data(g), g, None

        return _map_leaves(leaf, deltas, deltas, specs, bits)


# ---------------------------------------------------------------------------
# PowerSGD (the paper's method) and its ablations
# ---------------------------------------------------------------------------

class PowerSGDCompressor(Compressor):
    """Rank-r PowerSGD (Alg. 1) with the bucketed batched engine by default.

    ``bucketing="auto"`` (or ``"on"``) stacks same-shape-bucket matrices and
    fuses all per-phase all-reduces into one flat collective each — 2
    data-axis collectives per power iteration regardless of model size.
    ``bucketing="off"`` is the per-leaf reference path (2 collectives per
    weight matrix); the two are numerically identical up to float32
    reassociation and share the same state layout.

    PowerSGD is the zoo's one *multi-round* scheme (reduce → orthogonalize →
    reduce), so it schedules its own :class:`~repro.core.engine.Transport`
    phases (``core/powersgd.py``) instead of the generic single-round
    ``engine.run_step`` driver.

    bits_per_worker: ``32 · r · (n + m)`` per weight matrix (the P and Q
    factors) plus ``32 · numel`` per uncompressed leaf.  Bucket zero-padding
    is excluded — it is an engine artifact, not payload (see
    ``CollectiveStats`` for wire bytes).

    Adaptive rank: ``rank`` only seeds ``init``; the *live* rank is carried
    by the state's Q factors and may change between steps.  Pass
    ``rank_schedule`` (anything :func:`repro.core.powersgd.parse_schedule`
    accepts — ``"4@0,2@60"``, ``"residual:min=1,max=8"``, a
    ``RankSchedule``) and drive :meth:`controller` from the host training
    loop; per-leaf bits accounting follows each factor's own rank
    automatically.  Residual-driven schedules force ``track_residual`` on,
    which adds ``residual_ratio`` (and per-bucket ratios under the fused
    engine) to ``CompressOut.metrics``.
    """

    name = "powersgd"
    allreduce = True
    stateful = True

    def __init__(self, rank=2, orthogonalizer="gram_schmidt", warm_start=True,
                 num_iters=1, error_mode="global", use_pallas=False,
                 bucketing="auto", bucket_pad_tolerance=0.25,
                 wire_dtype="auto", max_chunk_bytes=None,
                 rank_schedule=None, track_residual=False, pipeline=False):
        super().__init__(
            transport="per_leaf" if bucketing == "off" else "fused",
            wire_dtype=wire_dtype, max_chunk_bytes=max_chunk_bytes)
        self.rank_schedule = (None if rank_schedule is None
                              else powersgd.parse_schedule(rank_schedule))
        if self.rank_schedule is not None:
            rank = self.rank_schedule.initial_rank()
            track_residual = (track_residual
                              or self.rank_schedule.needs_residual)
        self.cfg = powersgd.PowerSGDConfig(
            rank=rank, orthogonalizer=orthogonalizer, warm_start=warm_start,
            num_iters=num_iters, error_mode=error_mode, use_pallas=use_pallas,
            bucketing=bucketing, bucket_pad_tolerance=bucket_pad_tolerance,
            wire_dtype=wire_dtype, max_chunk_bytes=max_chunk_bytes,
            track_residual=track_residual, pipeline=pipeline,
        )
        if num_iters > 1:
            self.name = f"powersgd_best_approx_{num_iters}it"
        elif not warm_start:
            self.name = "powersgd_cold"

    def declared_budget(self) -> tuple:
        """One fused P reduce + one fused Q reduce per power iteration,
        independent of model size (the paper's §3 headline property)."""
        n = 2 * self.cfg.num_iters
        return (n, n, 0)

    def controller(self, key=None) -> "powersgd.RankController":
        """A fresh host-side driver for this compressor's rank schedule
        (:class:`repro.core.powersgd.RankController`)."""
        schedule = self.rank_schedule or powersgd.FixedRank(self.cfg.rank)
        return powersgd.RankController(schedule, key)

    def init(self, shapes, specs, key):
        return powersgd.init_state(self.cfg, shapes, specs, key)

    def state_partition(self, param_pspecs, mspecs):
        """Per-leaf partition of the warm-start Q factors.  A Q factor is
        model-LOCAL when the owning weight's matrixized n dim is
        model-sharded (row-parallel): each model rank's ``Q = Mᵀ P̂`` is a
        function of its local n-rows, so the replicated-shaped leaf holds
        per-rank content — see :func:`repro.core.powersgd.factor_partition`.
        """
        return powersgd.state_partition(param_pspecs, mspecs)

    def bind_state_partition(self, partition):
        """Attach a :meth:`state_partition` tree so every subsequent
        :meth:`step` hands it to the bucketed engine
        (:class:`~repro.core.engine.MatrixPayloads` then marks which bucket
        slabs hold model-sharded/-local factors).  Returns ``partition``."""
        self._state_partition = partition
        return partition

    def step(self, deltas, state, specs, ctx=SINGLE, key=None):
        return powersgd.compress_aggregate(
            self.cfg, deltas, state, specs, ctx, key,
            partition=getattr(self, "_state_partition", None))


class UnbiasedRankK(Compressor):
    """§4.1: samples U with E[UUᵀ]=I and sends (MU, shared-seed U).

    bits_per_worker: ``32 · n · r`` per matrix (only MU travels; U is
    regenerated from the shared seed), plus full size for vector leaves.
    """

    name = "unbiased_rank_k"
    allreduce = True

    def __init__(self, rank=2, **kw):
        super().__init__(**kw)
        self.rank = rank

    def encode_leaf(self, path, g, q, spec, key):
        ms = matrixize.matrix_shape(g.shape, spec)
        if ms is None:
            return None
        batch_shape, n, m = ms
        mat = matrixize.to_matrix(g, spec)
        # E[UUᵀ] = I_m  ⇐  entries iid N(0, 1/r)
        u = jax.random.normal(key, (m, self.rank)) / jnp.sqrt(self.rank)
        p = jnp.einsum("...nm,mr->...nr", mat, u)
        return Encoded(payload=(p,), aux=(u, g.shape, spec),
                       bits=math.prod(batch_shape) * n * self.rank * 32)

    def decode_leaf(self, enc, payload):
        u, shape, spec = enc.aux
        mat = jnp.einsum("...nr,mr->...nm", payload[0], u)
        return matrixize.from_matrix(mat, shape, spec)

    def _step_per_leaf(self, deltas, state, specs, ctx, key):
        bits = [0]

        def leaf(path, g, q, spec):
            enc = self.encode_leaf(path, g, q, spec, _leaf_key(key, path))
            if enc is None:
                bits[0] += matrixize.uncompressed_floats(g.shape) * 32
                return ctx.pmean_data(g), g, None
            bits[0] += enc.bits
            p_agg = ctx.pmean_data(enc.payload[0])
            return self.decode_leaf(enc, (p_agg,)), \
                self.decode_leaf(enc, enc.payload), None

        return _map_leaves(leaf, deltas, deltas, specs, bits)


# ---------------------------------------------------------------------------
# Sparsifiers (Appendix G): Random Block / Random K / Sign+Norm / Top-K
# ---------------------------------------------------------------------------

class _FlatSparsifier(Compressor):
    """Common scaffolding: compress each leaf as a flat vector with budget
    ``b = (n+m)·r`` (rank-equivalent, paper Appendix G).  Subclasses declare
    their payload via ``_encode_flat`` / ``_decode_flat`` and document their
    own bits_per_worker accounting; transport (fused engine vs per-leaf
    reference collectives) is shared here."""

    def __init__(self, rank=2, **kw):
        super().__init__(**kw)
        self.rank = rank  # sets the budget b = (n+m)·r to match PowerSGD

    def _encode_flat(self, flat, b, key):
        """-> (payload tuple, aux, bits) for one raveled leaf."""
        raise NotImplementedError

    def _decode_flat(self, aux, payload, n):
        """-> flat (n,) reconstruction from one payload."""
        raise NotImplementedError

    def encode_leaf(self, path, g, q, spec, key):
        if not spec.is_compressed():
            return None
        b = min(_budget(g.shape, spec, self.rank), g.size)
        payload, aux, bits = self._encode_flat(g.reshape(-1), b, key)
        return Encoded(payload=payload, aux=(aux, g.shape), bits=bits)

    def decode_leaf(self, enc, payload):
        aux, shape = enc.aux
        return self._decode_flat(aux, payload, math.prod(shape)).reshape(shape)

    def _step_per_leaf(self, deltas, state, specs, ctx, key):
        bits = [0]

        def leaf(path, g, q, spec):
            enc = self.encode_leaf(path, g, q, spec, _leaf_key(key, path))
            if enc is None:
                bits[0] += matrixize.uncompressed_floats(g.shape) * 32
                return ctx.pmean_data(g), g, None
            bits[0] += enc.bits
            recon = self.decode_leaf(enc, enc.payload)
            if self.allreduce:
                # linear: the payload itself all-reduces (one collective
                # per payload array per leaf)
                agg_payload = tuple(ctx.pmean_data(a) for a in enc.payload)
                agg = self.decode_leaf(enc, agg_payload)
            else:
                # non-linear: mean of per-worker reconstructions.  The
                # *numerics* are the gather path's decode-then-average, but
                # this reference path simulates it with a dense all-reduce —
                # the engine's allgather_flat is the honest wire pattern.
                agg = ctx.pmean_data(recon)
            return agg, recon, None

        return _map_leaves(leaf, deltas, deltas, specs, bits)


class RandomBlock(_FlatSparsifier):
    """Alg. 3: a shared-seed contiguous slice of length b.  Linear ⇒ all-reduce.

    bits_per_worker: ``32 · b`` (block offset is derived from the shared seed).
    """

    name = "random_block"
    allreduce = True

    def _encode_flat(self, flat, b, key):
        n = flat.shape[0]
        start = jax.random.randint(key, (), 0, max(n - b, 1))
        block = jax.lax.dynamic_slice(flat, (start,), (b,))
        return (block,), start, b * 32

    def _decode_flat(self, aux, payload, n):
        zeros = jnp.zeros((n,), payload[0].dtype)
        return jax.lax.dynamic_update_slice(zeros, payload[0], (aux,))


class RandomK(_FlatSparsifier):
    """Alg. 4: b shared-seed random coordinates.  Linear ⇒ all-reduce.

    bits_per_worker: ``32 · b`` (indices are free via the shared seed).
    """

    name = "random_k"
    allreduce = True

    def _encode_flat(self, flat, b, key):
        n = flat.shape[0]
        idx = jax.random.choice(key, n, (b,), replace=False)
        return (flat[idx],), idx, b * 32

    def _decode_flat(self, aux, payload, n):
        return jnp.zeros((n,), payload[0].dtype).at[aux].set(payload[0])


class SignNorm(_FlatSparsifier):
    """Alg. 5: sign(M)·‖M‖₁/nm.  Not linear ⇒ all-gather.

    bits_per_worker: ``1 · numel + 32`` (one sign bit per coordinate plus the
    32-bit norm).  On the wire the signs travel as an int8 payload chunk and
    the norms as a float chunk — ``CollectiveStats`` records the 1-byte
    itemsize, the closest a dense-array simulation gets to the 1-bit claim.
    """

    name = "sign_norm"
    allreduce = False
    payload_dtypes = ("int8", "float")  # sign bytes + norms

    def _encode_flat(self, flat, b, key):
        n = flat.shape[0]
        scale = jnp.mean(jnp.abs(flat))
        signs = jnp.sign(flat).astype(jnp.int8)
        return (signs, scale.reshape((1,))), flat.dtype, n * 1 + 32

    def _decode_flat(self, aux, payload, n):
        signs, scale = payload
        return signs.astype(aux) * scale[0].astype(aux)


class TopK(_FlatSparsifier):
    """Alg. 6: the b largest-|.| coordinates.  Not linear ⇒ all-gather.

    bits_per_worker: ``(32 + 32) · b`` — a value and an explicit index per
    selected coordinate (both travel: every worker's selection differs, so
    the indices are a real int32 wire chunk, not a shared seed).
    """

    name = "top_k"
    allreduce = False
    payload_dtypes = ("float", "int32")  # values + indices

    def _encode_flat(self, flat, b, key):
        vals, idx = jax.lax.top_k(jnp.abs(flat), b)
        return (flat[idx], idx.astype(jnp.int32)), None, b * (32 + 32)

    def _decode_flat(self, aux, payload, n):
        picked, idx = payload
        return jnp.zeros((n,), picked.dtype).at[idx].set(picked)


# ---------------------------------------------------------------------------
# Spectral Atomo (Wang et al., 2018) — Appendix G.6
# ---------------------------------------------------------------------------

class SpectralAtomo(Compressor):
    """Importance-sampled SVD components; unbiased, all-gather, no EF.

    Follows the paper's modification: resample until exactly r components are
    selected (we use a fixed number of attempts with a deterministic top-r
    fallback so the whole step stays jittable).

    bits_per_worker: ``32 · r · (n + m)`` per matrix (r sampled singular
    triplets, the same budget as rank-r PowerSGD).  The payload is exactly
    those triplets — ``P = U_S diag(s_S/p_S)`` and ``V_S`` — gathered from
    every worker and decoded as ``P Vᵀ`` on the receiver.
    """

    name = "spectral_atomo"
    allreduce = False

    def __init__(self, rank=2, attempts=8, **kw):
        super().__init__(**kw)
        self.rank = rank
        self.attempts = attempts

    def _probs(self, s):
        """Atomo water-filling: p_i = min(1, s_i/τ) with Σ p_i = r."""
        r = self.rank
        p = jnp.minimum(s * r / (jnp.sum(s) + 1e-12), 1.0)
        for _ in range(12):  # fixed-point iterations, converges fast
            clipped = p >= 1.0
            mass = r - jnp.sum(jnp.where(clipped, 1.0, 0.0))
            rest = jnp.sum(jnp.where(clipped, 0.0, s))
            p = jnp.where(clipped, 1.0, s * jnp.maximum(mass, 0.0) / (rest + 1e-12))
            p = jnp.minimum(p, 1.0)
        return p

    def _compress_one(self, mat, key):
        """One matrix → the r sampled triplets (P = u·s/p, V), the payload."""
        n, m = mat.shape
        u, s, vt = jnp.linalg.svd(mat, full_matrices=False)
        p = self._probs(s)

        def attempt(k):
            sel = jax.random.uniform(k, s.shape) < p
            return sel, jnp.sum(sel)

        keys = jax.random.split(key, self.attempts)
        sels, counts = jax.vmap(attempt)(keys)
        ok = counts == self.rank
        first = jnp.argmax(ok)
        any_ok = jnp.any(ok)
        sel = sels[first]
        # fallback: deterministic top-r components
        topr = jnp.arange(s.shape[0]) < self.rank
        sel = jnp.where(any_ok, sel, topr)
        w = jnp.where(sel, s / jnp.maximum(p, 1e-12), 0.0)
        (idx,) = jnp.nonzero(sel, size=self.rank, fill_value=0)
        # when fewer than r components exist (min(n,m) < r) the fill slots
        # duplicate index 0 — zero their weight so decode adds exact zeros
        valid = jnp.arange(self.rank) < jnp.sum(sel)
        wsel = jnp.where(valid, w[idx], 0.0)
        pfac = u[:, idx] * wsel[None, :]             # (n, r)
        vfac = vt[idx, :].T                          # (m, r)
        return pfac, vfac

    def encode_leaf(self, path, g, q, spec, key):
        ms = matrixize.matrix_shape(g.shape, spec)
        if ms is None:
            return None
        batch_shape, n, m = ms
        mat = matrixize.to_matrix(g, spec).reshape((-1, n, m))
        pfac, vfac = jax.vmap(self._compress_one)(
            mat, jax.random.split(key, mat.shape[0]))
        return Encoded(payload=(pfac, vfac), aux=(g.shape, spec),
                       bits=math.prod(batch_shape) * self.rank * (n + m) * 32)

    def decode_leaf(self, enc, payload):
        shape, spec = enc.aux
        pfac, vfac = payload
        mat = jnp.einsum("bnr,bmr->bnm", pfac, vfac)
        ms = matrixize.matrix_shape(shape, spec)
        batch_shape, n, m = ms
        return matrixize.from_matrix(
            mat.reshape(batch_shape + (n, m)), shape, spec)

    def _step_per_leaf(self, deltas, state, specs, ctx, key):
        bits = [0]

        def leaf(path, g, q, spec):
            enc = self.encode_leaf(path, g, q, spec, _leaf_key(key, path))
            if enc is None:
                bits[0] += matrixize.uncompressed_floats(g.shape) * 32
                return ctx.pmean_data(g), g, None
            bits[0] += enc.bits
            recon = self.decode_leaf(enc, enc.payload)
            agg = ctx.pmean_data(recon)  # simulated gather (see _FlatSparsifier)
            return agg, recon, None

        return _map_leaves(leaf, deltas, deltas, specs, bits)


# ---------------------------------------------------------------------------
# Exact best rank-r (SVD truncation) — used by tests/benchmarks as the oracle
# ---------------------------------------------------------------------------

class ExactRankK(Compressor):
    """Best rank-r approximation via SVD of the *aggregated* gradient.

    bits_per_worker: ``32 · r · (n + m)`` per matrix — nominal; the oracle is
    not actually communicable without first aggregating the dense gradient,
    which is why its wire_mode is a dense *reduce* (decode runs after
    aggregation — SVD of the mean, not mean of SVDs) and its recon is the
    aggregated decode.
    """

    name = "exact_rank_k"
    allreduce = False  # the compressed repr is not linear; oracle only
    recon_is_agg = True

    @property
    def wire_mode(self):
        return "reduce"  # dense gradient travels, decode after aggregation

    def __init__(self, rank=2, **kw):
        super().__init__(**kw)
        self.rank = rank

    def encode_leaf(self, path, g, q, spec, key):
        ms = matrixize.matrix_shape(g.shape, spec)
        if ms is None:
            return None
        batch_shape, n, m = ms
        return Encoded(payload=(g,), aux=(g.shape, spec),
                       bits=math.prod(batch_shape) * self.rank * (n + m) * 32)

    def decode_leaf(self, enc, payload):
        shape, spec = enc.aux
        ms = matrixize.matrix_shape(shape, spec)
        batch_shape, n, m = ms
        mat = matrixize.to_matrix(payload[0], spec).reshape((-1, n, m))

        def trunc(a):
            u, s, vt = jnp.linalg.svd(a, full_matrices=False)
            s = s.at[self.rank:].set(0.0)
            return jnp.einsum("nk,k,km->nm", u, s, vt)

        recon = jax.vmap(trunc)(mat).reshape(batch_shape + (n, m))
        return matrixize.from_matrix(recon, shape, spec)

    def _step_per_leaf(self, deltas, state, specs, ctx, key):
        bits = [0]

        def leaf(path, g, q, spec):
            enc = self.encode_leaf(path, g, q, spec, None)
            if enc is None:
                bits[0] += matrixize.uncompressed_floats(g.shape) * 32
                return ctx.pmean_data(g), g, None
            bits[0] += enc.bits
            recon = self.decode_leaf(enc, (ctx.pmean_data(g),))
            return recon, recon, None

        return _map_leaves(leaf, deltas, deltas, specs, bits)


def make_compressor(name: str, rank: int = 2, **kw) -> Compressor:
    registry = {
        "identity": lambda: IdentityCompressor(**kw),
        "powersgd": lambda: PowerSGDCompressor(rank=rank, **kw),
        "powersgd_cold": lambda: PowerSGDCompressor(rank=rank, warm_start=False, **kw),
        "powersgd_best_approx": lambda: PowerSGDCompressor(
            rank=rank, warm_start=False, num_iters=4, **kw),
        "powersgd_per_leaf": lambda: PowerSGDCompressor(
            rank=rank, bucketing="off", **kw),
        "unbiased_rank_k": lambda: UnbiasedRankK(rank=rank, **kw),
        "random_block": lambda: RandomBlock(rank=rank, **kw),
        "random_k": lambda: RandomK(rank=rank, **kw),
        "sign_norm": lambda: SignNorm(rank=rank, **kw),
        "top_k": lambda: TopK(rank=rank, **kw),
        "spectral_atomo": lambda: SpectralAtomo(rank=rank, **kw),
        "exact_rank_k": lambda: ExactRankK(rank=rank, **kw),
    }
    try:
        return registry[name]()
    except KeyError:
        raise ValueError(f"unknown compressor {name!r}; available: {sorted(registry)}") from None
