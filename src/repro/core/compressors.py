"""The compressor zoo the paper benchmarks against (§5.1, Appendix G).

Every compressor implements the same interface so the error-feedback
optimizer (Alg. 2) and the benchmark harness can swap them freely:

    init(shapes, specs, key)                 -> state
    step(deltas, state, specs, ctx, key)     -> CompressOut

``CompressOut.agg`` is the aggregated decompressed update (mean over the
data axes) and ``CompressOut.recon`` is the reconstruction used for the
error-feedback update.  ``allreduce`` marks whether the scheme is linear
(all-reduce aggregatable) — the property the paper identifies as the key to
scalability (§3).

``bits_per_worker`` accounting
------------------------------
``CompressOut.bits_per_worker`` is the number of bits each worker (model
shard) contributes to gradient exchange per step — the paper's Tables
3/10/11 metric.  Conventions, uniform across the zoo:

* It counts the *payload* of the compressed representation (e.g. the r·(n+m)
  P and Q floats for PowerSGD), not wire overhead, headers, or padding that
  an implementation (such as the bucketed engine) may add for efficiency.
* Uncompressed leaves (biases, norms — ``MatrixSpec.kind == "none"``) are
  charged at full ``32 · numel`` by every compressor.
* Index/metadata side channels are included where the scheme needs them
  (Top-K charges 32 bits per index; Random-K / Random Block use shared
  seeds, so indices are free; Sign+Norm charges 1 bit per coordinate plus
  one 32-bit norm).
* The count is per step and per worker; multiply by ``ctx.data_size()`` for
  cluster-wide traffic (all-gather schemes) — ``benchmarks.common.comm_time``
  models the difference between all-reduce and all-gather scaling.

Actual on-the-wire bytes per collective (including bucket padding) are
observable via :class:`repro.core.dist.CollectiveStats`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import matrixize, powersgd
from repro.core.dist import MeshCtx, SINGLE
from repro.core.powersgd import PowerSGDOut as CompressOut, _leaf_key


class Compressor:
    """Base class; subclasses set ``name`` and ``allreduce``."""

    name: str = "base"
    allreduce: bool = True
    stateful: bool = False   # carries per-matrix state (e.g. warm-start Q)

    def init(self, shapes, specs, key):
        return None

    def step(self, deltas, state, specs, ctx: MeshCtx = SINGLE, key=None) -> CompressOut:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _unzip3(triples):
    is_t = lambda x: isinstance(x, tuple)
    agg = jax.tree_util.tree_map(lambda t: t[0], triples, is_leaf=is_t)
    recon = jax.tree_util.tree_map(lambda t: t[1], triples, is_leaf=is_t)
    state = jax.tree_util.tree_map(lambda t: t[2], triples, is_leaf=is_t)
    return agg, recon, state


def _map_leaves(fn, deltas, state, specs, bits):
    """fn(path, g, q, spec) -> (agg, recon, new_q); threads bits counter."""
    triples = jax.tree_util.tree_map_with_path(
        fn, deltas, state, specs, is_leaf=lambda x: x is None
    )
    agg, recon, new_state = _unzip3(triples)
    if not jax.tree_util.tree_leaves(new_state):
        new_state = None  # stateless compressor: collapse dict-of-Nones
    return CompressOut(agg=agg, recon=recon, state=new_state, bits_per_worker=bits[0])


def _budget(shape, spec, rank):
    """Sparsifier budget b = (n+m)·r per matrix (paper Appendix G)."""
    ms = matrixize.matrix_shape(shape, spec)
    assert ms is not None
    batch_shape, n, m = ms
    return math.prod(batch_shape) * (n + m) * rank


# ---------------------------------------------------------------------------
# Identity (= full-precision SGD data path)
# ---------------------------------------------------------------------------

class IdentityCompressor(Compressor):
    """Full-precision baseline.

    bits_per_worker: ``32 · numel`` for every leaf (nothing is compressed).
    """

    name = "identity"
    allreduce = True

    def step(self, deltas, state, specs, ctx=SINGLE, key=None):
        bits = [0]

        def leaf(path, g, q, spec):
            bits[0] += matrixize.uncompressed_floats(g.shape) * 32
            return ctx.pmean_data(g), g, None

        return _map_leaves(leaf, deltas, deltas, specs, bits)


# ---------------------------------------------------------------------------
# PowerSGD (the paper's method) and its ablations
# ---------------------------------------------------------------------------

class PowerSGDCompressor(Compressor):
    """Rank-r PowerSGD (Alg. 1) with the bucketed batched engine by default.

    ``bucketing="auto"`` (or ``"on"``) stacks same-shape-bucket matrices and
    fuses all per-phase all-reduces into one flat collective each — 2
    data-axis collectives per power iteration regardless of model size.
    ``bucketing="off"`` is the per-leaf reference path (2 collectives per
    weight matrix); the two are numerically identical up to float32
    reassociation and share the same state layout.

    bits_per_worker: ``32 · r · (n + m)`` per weight matrix (the P and Q
    factors) plus ``32 · numel`` per uncompressed leaf.  Bucket zero-padding
    is excluded — it is an engine artifact, not payload (see
    ``CollectiveStats`` for wire bytes).
    """

    name = "powersgd"
    allreduce = True
    stateful = True

    def __init__(self, rank=2, orthogonalizer="gram_schmidt", warm_start=True,
                 num_iters=1, error_mode="global", use_pallas=False,
                 bucketing="auto", bucket_pad_tolerance=0.25):
        self.cfg = powersgd.PowerSGDConfig(
            rank=rank, orthogonalizer=orthogonalizer, warm_start=warm_start,
            num_iters=num_iters, error_mode=error_mode, use_pallas=use_pallas,
            bucketing=bucketing, bucket_pad_tolerance=bucket_pad_tolerance,
        )
        if num_iters > 1:
            self.name = f"powersgd_best_approx_{num_iters}it"
        elif not warm_start:
            self.name = "powersgd_cold"

    def init(self, shapes, specs, key):
        return powersgd.init_state(self.cfg, shapes, specs, key)

    def step(self, deltas, state, specs, ctx=SINGLE, key=None):
        return powersgd.compress_aggregate(self.cfg, deltas, state, specs, ctx, key)


class UnbiasedRankK(Compressor):
    """§4.1: samples U with E[UUᵀ]=I and sends (MU, shared-seed U).

    bits_per_worker: ``32 · n · r`` per matrix (only MU travels; U is
    regenerated from the shared seed), plus full size for vector leaves.
    """

    name = "unbiased_rank_k"
    allreduce = True

    def __init__(self, rank=2):
        self.rank = rank

    def step(self, deltas, state, specs, ctx=SINGLE, key=None):
        bits = [0]

        def leaf(path, g, q, spec):
            ms = matrixize.matrix_shape(g.shape, spec)
            if ms is None:
                bits[0] += matrixize.uncompressed_floats(g.shape) * 32
                return ctx.pmean_data(g), g, None
            batch_shape, n, m = ms
            mat = matrixize.to_matrix(g, spec)
            k = _leaf_key(key, path)
            # E[UUᵀ] = I_m  ⇐  entries iid N(0, 1/r)
            u = jax.random.normal(k, (m, self.rank)) / jnp.sqrt(self.rank)
            p = jnp.einsum("...nm,mr->...nr", mat, u)
            p_agg = ctx.pmean_data(p)
            recon = jnp.einsum("...nr,mr->...nm", p, u)
            agg = jnp.einsum("...nr,mr->...nm", p_agg, u)
            bits[0] += math.prod(batch_shape) * n * self.rank * 32
            return (matrixize.from_matrix(agg, g.shape, spec),
                    matrixize.from_matrix(recon, g.shape, spec), None)

        return _map_leaves(leaf, deltas, deltas, specs, bits)


# ---------------------------------------------------------------------------
# Sparsifiers (Appendix G): Random Block / Random K / Sign+Norm / Top-K
# ---------------------------------------------------------------------------

class _FlatSparsifier(Compressor):
    """Common scaffolding: compress each leaf as a flat vector with budget
    ``b = (n+m)·r`` (rank-equivalent, paper Appendix G).  Subclasses document
    their own bits_per_worker accounting."""

    def __init__(self, rank=2):
        self.rank = rank  # sets the budget b = (n+m)·r to match PowerSGD

    def _leaf_flat(self, path, flat, b, key, ctx):
        raise NotImplementedError

    def step(self, deltas, state, specs, ctx=SINGLE, key=None):
        bits = [0]

        def leaf(path, g, q, spec):
            if not spec.is_compressed():
                bits[0] += matrixize.uncompressed_floats(g.shape) * 32
                return ctx.pmean_data(g), g, None
            b = min(_budget(g.shape, spec, self.rank), g.size)
            k = _leaf_key(key, path)
            agg_f, recon_f, leaf_bits = self._leaf_flat(path, g.reshape(-1), b, k, ctx)
            bits[0] += leaf_bits
            return agg_f.reshape(g.shape), recon_f.reshape(g.shape), None

        return _map_leaves(leaf, deltas, deltas, specs, bits)


class RandomBlock(_FlatSparsifier):
    """Alg. 3: a shared-seed contiguous slice of length b.  Linear ⇒ all-reduce.

    bits_per_worker: ``32 · b`` (block offset is derived from the shared seed).
    """

    name = "random_block"
    allreduce = True

    def _leaf_flat(self, path, flat, b, key, ctx):
        n = flat.shape[0]
        start = jax.random.randint(key, (), 0, max(n - b, 1))
        block = jax.lax.dynamic_slice(flat, (start,), (b,))
        agg_block = ctx.pmean_data(block)
        zeros = jnp.zeros_like(flat)
        recon = jax.lax.dynamic_update_slice(zeros, block, (start,))
        agg = jax.lax.dynamic_update_slice(zeros, agg_block, (start,))
        return agg, recon, b * 32


class RandomK(_FlatSparsifier):
    """Alg. 4: b shared-seed random coordinates.  Linear ⇒ all-reduce.

    bits_per_worker: ``32 · b`` (indices are free via the shared seed).
    """

    name = "random_k"
    allreduce = True

    def _leaf_flat(self, path, flat, b, key, ctx):
        n = flat.shape[0]
        idx = jax.random.choice(key, n, (b,), replace=False)
        vals = flat[idx]
        agg_vals = ctx.pmean_data(vals)
        recon = jnp.zeros_like(flat).at[idx].set(vals)
        agg = jnp.zeros_like(flat).at[idx].set(agg_vals)
        return agg, recon, b * 32


class SignNorm(_FlatSparsifier):
    """Alg. 5: sign(M)·‖M‖₁/nm.  Not linear ⇒ needs all-gather.

    bits_per_worker: ``1 · numel + 32`` (one sign bit per coordinate plus the
    32-bit norm).
    """

    name = "sign_norm"
    allreduce = False

    def _leaf_flat(self, path, flat, b, key, ctx):
        n = flat.shape[0]
        scale = jnp.mean(jnp.abs(flat))
        recon = jnp.sign(flat) * scale
        agg = ctx.pmean_data(recon)  # mean of per-worker reconstructions (gather)
        return agg, recon, n * 1 + 32


class TopK(_FlatSparsifier):
    """Alg. 6: the b largest-|.| coordinates.  Not linear ⇒ all-gather.

    bits_per_worker: ``(32 + 32) · b`` — a value and an explicit index per
    selected coordinate.
    """

    name = "top_k"
    allreduce = False

    def _leaf_flat(self, path, flat, b, key, ctx):
        vals, idx = jax.lax.top_k(jnp.abs(flat), b)
        picked = flat[idx]
        recon = jnp.zeros_like(flat).at[idx].set(picked)
        agg = ctx.pmean_data(recon)
        return agg, recon, b * (32 + 32)


# ---------------------------------------------------------------------------
# Spectral Atomo (Wang et al., 2018) — Appendix G.6
# ---------------------------------------------------------------------------

class SpectralAtomo(Compressor):
    """Importance-sampled SVD components; unbiased, all-gather, no EF.

    Follows the paper's modification: resample until exactly r components are
    selected (we use a fixed number of attempts with a deterministic top-r
    fallback so the whole step stays jittable).

    bits_per_worker: ``32 · r · (n + m)`` per matrix (r sampled singular
    triplets, the same budget as rank-r PowerSGD).
    """

    name = "spectral_atomo"
    allreduce = False

    def __init__(self, rank=2, attempts=8):
        self.rank = rank
        self.attempts = attempts

    def _probs(self, s):
        """Atomo water-filling: p_i = min(1, s_i/τ) with Σ p_i = r."""
        r = self.rank
        p = jnp.minimum(s * r / (jnp.sum(s) + 1e-12), 1.0)
        for _ in range(12):  # fixed-point iterations, converges fast
            clipped = p >= 1.0
            mass = r - jnp.sum(jnp.where(clipped, 1.0, 0.0))
            rest = jnp.sum(jnp.where(clipped, 0.0, s))
            p = jnp.where(clipped, 1.0, s * jnp.maximum(mass, 0.0) / (rest + 1e-12))
            p = jnp.minimum(p, 1.0)
        return p

    def _compress_one(self, mat, key):
        n, m = mat.shape
        u, s, vt = jnp.linalg.svd(mat, full_matrices=False)
        p = self._probs(s)

        def attempt(k):
            sel = jax.random.uniform(k, s.shape) < p
            return sel, jnp.sum(sel)

        keys = jax.random.split(key, self.attempts)
        sels, counts = jax.vmap(attempt)(keys)
        ok = counts == self.rank
        first = jnp.argmax(ok)
        any_ok = jnp.any(ok)
        sel = sels[first]
        # fallback: deterministic top-r components
        topr = jnp.arange(s.shape[0]) < self.rank
        sel = jnp.where(any_ok, sel, topr)
        w = jnp.where(sel, s / jnp.maximum(p, 1e-12), 0.0)
        recon = jnp.einsum("nk,k,km->nm", u, w, vt)
        return recon

    def step(self, deltas, state, specs, ctx=SINGLE, key=None):
        bits = [0]

        def leaf(path, g, q, spec):
            ms = matrixize.matrix_shape(g.shape, spec)
            if ms is None:
                bits[0] += matrixize.uncompressed_floats(g.shape) * 32
                return ctx.pmean_data(g), g, None
            batch_shape, n, m = ms
            mat = matrixize.to_matrix(g, spec).reshape((-1, n, m))
            k = _leaf_key(key, path)
            recon = jax.vmap(self._compress_one)(mat, jax.random.split(k, mat.shape[0]))
            recon = recon.reshape(g.shape)
            agg = ctx.pmean_data(recon)
            bits[0] += math.prod(batch_shape) * self.rank * (n + m) * 32
            return agg, recon, None

        return _map_leaves(leaf, deltas, deltas, specs, bits)


# ---------------------------------------------------------------------------
# Exact best rank-r (SVD truncation) — used by tests/benchmarks as the oracle
# ---------------------------------------------------------------------------

class ExactRankK(Compressor):
    """Best rank-r approximation via SVD of the *aggregated* gradient.

    bits_per_worker: ``32 · r · (n + m)`` per matrix — nominal; the oracle is
    not actually communicable without first aggregating the dense gradient.
    """

    name = "exact_rank_k"
    allreduce = False  # requires aggregating first (or gather); oracle only

    def __init__(self, rank=2):
        self.rank = rank

    def step(self, deltas, state, specs, ctx=SINGLE, key=None):
        bits = [0]

        def leaf(path, g, q, spec):
            ms = matrixize.matrix_shape(g.shape, spec)
            if ms is None:
                bits[0] += matrixize.uncompressed_floats(g.shape) * 32
                return ctx.pmean_data(g), g, None
            batch_shape, n, m = ms
            g_mean = ctx.pmean_data(g)
            mat = matrixize.to_matrix(g_mean, spec).reshape((-1, n, m))

            def trunc(a):
                u, s, vt = jnp.linalg.svd(a, full_matrices=False)
                s = s.at[self.rank:].set(0.0)
                return jnp.einsum("nk,k,km->nm", u, s, vt)

            recon = jax.vmap(trunc)(mat).reshape(g.shape)
            bits[0] += math.prod(batch_shape) * self.rank * (n + m) * 32
            return recon, recon, None

        return _map_leaves(leaf, deltas, deltas, specs, bits)


def make_compressor(name: str, rank: int = 2, **kw) -> Compressor:
    registry = {
        "identity": lambda: IdentityCompressor(),
        "powersgd": lambda: PowerSGDCompressor(rank=rank, **kw),
        "powersgd_cold": lambda: PowerSGDCompressor(rank=rank, warm_start=False, **kw),
        "powersgd_best_approx": lambda: PowerSGDCompressor(
            rank=rank, warm_start=False, num_iters=4, **kw),
        "powersgd_per_leaf": lambda: PowerSGDCompressor(
            rank=rank, bucketing="off", **kw),
        "unbiased_rank_k": lambda: UnbiasedRankK(rank=rank),
        "random_block": lambda: RandomBlock(rank=rank),
        "random_k": lambda: RandomK(rank=rank),
        "sign_norm": lambda: SignNorm(rank=rank),
        "top_k": lambda: TopK(rank=rank),
        "spectral_atomo": lambda: SpectralAtomo(rank=rank),
        "exact_rank_k": lambda: ExactRankK(rank=rank),
    }
    try:
        return registry[name]()
    except KeyError:
        raise ValueError(f"unknown compressor {name!r}; available: {sorted(registry)}") from None
