"""Tensor ⇄ matrix reshaping rules for gradient compression (paper §3).

The paper treats each parameter's gradient as a matrix:

* dense / fully-connected weights are used as-is,
* conv kernels ``(O, I, kh, kw)`` are flattened to ``(O, I·kh·kw)``
  (Appendix F, Table 10),
* vectors (biases, norm scales, per-head SSM scalars) are exempt and
  aggregated uncompressed.

Our parameters additionally carry *stacking* dimensions — a leading layer dim
from ``lax.scan`` over the block stack, and an expert dim for MoE weights.
Those become vmap batch dims of the compressor.

Every parameter leaf is described by a :class:`MatrixSpec`; model inits
produce a spec tree (same structure as the param tree).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    """How one parameter tensor maps to compression matrices.

    kind:
      "none"   — aggregated uncompressed (vectors / tiny params)
      "matrix" — reshape trailing dims to 2-D
      "conv"   — (O, I, kh, kw) → (O, I·kh·kw), after batch dims
    batch_dims: number of leading stacking dims (layer stack, expert dim)
                that become vmap batch dims.
    """

    kind: str = "matrix"
    batch_dims: int = 0

    def is_compressed(self) -> bool:
        return self.kind != "none"


NONE = MatrixSpec(kind="none")


def default_spec(leaf: jax.ShapeDtypeStruct | jax.Array, batch_dims: int = 0) -> MatrixSpec:
    """Heuristic used by model inits: <2 trailing dims ⇒ uncompressed."""
    trailing = len(leaf.shape) - batch_dims
    if trailing < 2:
        return NONE
    if trailing == 4:
        return MatrixSpec(kind="conv", batch_dims=batch_dims)
    return MatrixSpec(kind="matrix", batch_dims=batch_dims)


def matrix_shape(shape: Tuple[int, ...], spec: MatrixSpec) -> Optional[Tuple[Tuple[int, ...], int, int]]:
    """Returns (batch_shape, n, m) or None for uncompressed leaves."""
    if not spec.is_compressed():
        return None
    b = spec.batch_dims
    batch_shape, rest = tuple(shape[:b]), shape[b:]
    if spec.kind == "conv":
        assert len(rest) == 4, f"conv spec needs 4 trailing dims, got {rest}"
        n, m = rest[0], rest[1] * rest[2] * rest[3]
    else:
        assert len(rest) >= 2, f"matrix spec needs ≥2 trailing dims, got {rest}"
        n, m = rest[0], math.prod(rest[1:])
    return batch_shape, n, m


def to_matrix(x: jax.Array, spec: MatrixSpec) -> jax.Array:
    ms = matrix_shape(x.shape, spec)
    assert ms is not None
    batch_shape, n, m = ms
    return x.reshape(batch_shape + (n, m))


def from_matrix(mat: jax.Array, shape: Tuple[int, ...], spec: MatrixSpec) -> jax.Array:
    return mat.reshape(shape)


# ---------------------------------------------------------------------------
# Shape bucketing (the batched-compression engine's planning stage)
# ---------------------------------------------------------------------------
#
# The per-leaf compressor runs two tiny matmuls + two tiny collectives per
# weight matrix.  The bucketed engine instead groups matrices of similar shape
# into buckets, zero-pads each matrix up to its bucket's (n, m), stacks the
# bucket into one (B, n, m) slab and runs the whole power-iteration step as
# batched ops.  Zero padding is exact: padded rows/columns of M contribute
# exact zeros to P = M Q and Q = Mᵀ P̂, and zero rows do not perturb
# Gram-Schmidt / Cholesky-QR (they add nothing to any column inner product).
#
# Planning is pure Python over static shapes — it happens once at trace time.
#
# Plans are deliberately RANK-AGNOSTIC: buckets are a function of the (n, m)
# matrix shapes only, never of the compression rank.  That is what lets the
# adaptive-rank subsystem (core/powersgd.py RankSchedule, core/autotune.py)
# move ranks between steps — and assign *different* ranks to different
# buckets — without invalidating any plan: the factor slabs
# (pack_factors / unpack_entry with cols=None) carry whatever trailing rank
# the state's Q factors have, and an offline autotune plan computed from the
# same shapes re-derives the identical buckets by determinism.  Only the
# per-call accounting (compressed_floats) takes a rank, per leaf.


@dataclasses.dataclass(frozen=True)
class BucketEntry:
    """One leaf's slot range inside a bucket's stacking dimension."""

    index: int    # position of the leaf in the planner's input sequence
    count: int    # matrices this leaf contributes (= prod(batch_shape))
    n: int        # un-padded rows
    m: int        # un-padded cols
    offset: int   # first slot in the bucket's leading (stack) dim


@dataclasses.dataclass(frozen=True)
class Bucket:
    """A (n, m)-padded stack of matrices compressed as one batched op."""

    n: int
    m: int
    entries: Tuple[BucketEntry, ...]

    @property
    def count(self) -> int:
        return sum(e.count for e in self.entries)

    @property
    def padded_elems(self) -> int:
        return self.count * self.n * self.m

    @property
    def real_elems(self) -> int:
        return sum(e.count * e.n * e.m for e in self.entries)


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    buckets: Tuple[Bucket, ...]

    @functools.cached_property
    def _by_index(self):
        return {e.index: (b_id, e)
                for b_id, b in enumerate(self.buckets) for e in b.entries}

    def entry_for(self, index: int) -> Tuple[int, BucketEntry]:
        """(bucket position, entry) for the leaf at ``index``."""
        return self._by_index[index]


def plan_buckets(matrix_shapes, tolerance: float = 0.25) -> BucketPlan:
    """Greedy shape bucketing with a padding-waste tolerance.

    ``matrix_shapes`` is a sequence aligned with the (flattened) compressed
    leaves: each element is ``(count, n, m)`` — ``count`` matrices of shape
    ``(n, m)`` — or ``None`` for leaves that do not participate (uncompressed
    vectors).  Shapes are placed largest-area first; a shape joins an existing
    bucket iff it fits inside the bucket's (n, m) and the padded area exceeds
    its own by at most ``tolerance`` (relative).  ``tolerance=0`` buckets only
    exactly-equal shapes together.

    The plan is deterministic: bucket order follows descending seed-shape
    area, and entries within a bucket follow leaf order.
    """
    items = [(i, s[0], s[1], s[2])
             for i, s in enumerate(matrix_shapes) if s is not None]
    order = sorted(items, key=lambda t: (-(t[2] * t[3]), t[0]))
    raw = []  # [n, m, [items]]
    for it in order:
        i, c, n, m = it
        for b in raw:
            bn, bm = b[0], b[1]
            if n <= bn and m <= bm and bn * bm <= (1.0 + tolerance) * n * m:
                b[2].append(it)
                break
        else:
            raw.append([n, m, [it]])
    buckets = []
    for bn, bm, its in raw:
        its.sort(key=lambda t: t[0])  # deterministic pack order: leaf order
        entries, off = [], 0
        for i, c, n, m in its:
            entries.append(BucketEntry(index=i, count=c, n=n, m=m, offset=off))
            off += c
        buckets.append(Bucket(n=bn, m=bm, entries=tuple(entries)))
    return BucketPlan(buckets=tuple(buckets))


def pack_matrices(bucket: Bucket, arrays) -> jax.Array:
    """Stack per-leaf ``(count, n, m)`` arrays into the bucket's
    ``(B, bucket.n, bucket.m)`` slab, zero-padding rows and columns.
    ``arrays`` is indexable by ``entry.index``."""
    parts = []
    for e in bucket.entries:
        x = arrays[e.index]
        parts.append(jnp.pad(x, ((0, 0), (0, bucket.n - e.n),
                                 (0, bucket.m - e.m))))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


def pack_factors(bucket: Bucket, arrays) -> jax.Array:
    """Stack per-leaf ``(count, m, r)`` factor arrays into ``(B, bucket.m, r)``,
    zero-padding the m rows (exact: padded columns of M are zero)."""
    parts = []
    for e in bucket.entries:
        x = arrays[e.index]
        parts.append(jnp.pad(x, ((0, 0), (0, bucket.m - e.m), (0, 0))))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


def unpack_entry(stacked: jax.Array, entry: BucketEntry,
                 rows: int, cols: Optional[int] = None) -> jax.Array:
    """Slice one leaf's ``(count, rows, cols)`` block back out of a bucket
    slab, cropping the padding.  ``cols=None`` keeps the trailing dim whole
    (for (B, m, r) factor slabs)."""
    x = stacked[entry.offset:entry.offset + entry.count, :rows]
    return x if cols is None else x[:, :, :cols]


# ---------------------------------------------------------------------------
# Flat-payload planning (the transport engine's fused-buffer stage)
# ---------------------------------------------------------------------------
#
# Matrix slabs (above) batch the *compute*; flat plans batch the *wire*.  A
# FlatPlan maps an ordered list of payload arrays — P/Q factor slabs, sparse
# value/index vectors, sign buffers, uncompressed bias leaves — onto one or
# more contiguous 1-D wire buffers ("chunks").  Chunking policy:
#
# * ``wire_dtype="auto"``  — parts keep their own dtype; parts of the same
#   dtype share a chunk (in input order).  This deliberately replaces the
#   old ``jnp.result_type(*parts)`` behaviour, where a single float32
#   straggler silently upcast an entire bfloat16 payload on the wire.
# * ``wire_dtype="float32"|"bfloat16"`` — every part is cast to that dtype
#   for transport (and cast back on unpack), one shared chunk.
# * ``max_chunk_bytes`` — optional cap; a chunk is split once its wire size
#   would exceed the cap (a part never spans two chunks).
#
# Planning is pure Python over static shapes/dtypes — trace-time only.


WIRE_DTYPES = ("auto", "float32", "bfloat16")


@dataclasses.dataclass(frozen=True)
class FlatSlot:
    """One payload array's position inside a flat wire chunk."""

    index: int                 # position in the planner's input sequence
    offset: int                # first element inside the chunk buffer
    size: int                  # number of elements
    shape: Tuple[int, ...]     # original shape (restored on unpack)
    dtype: "jnp.dtype"         # original dtype (restored on unpack)


@dataclasses.dataclass(frozen=True)
class FlatChunk:
    """One contiguous wire buffer: same wire dtype, issued as one collective."""

    wire_dtype: "jnp.dtype"
    slots: Tuple[FlatSlot, ...]

    @property
    def size(self) -> int:
        return sum(s.size for s in self.slots)

    @property
    def wire_bytes(self) -> int:
        return self.size * jnp.dtype(self.wire_dtype).itemsize


@dataclasses.dataclass(frozen=True)
class FlatPlan:
    chunks: Tuple[FlatChunk, ...]

    @property
    def total_wire_bytes(self) -> int:
        return sum(c.wire_bytes for c in self.chunks)


def plan_flat(parts, wire_dtype: str = "auto",
              max_chunk_bytes: Optional[int] = None) -> FlatPlan:
    """Plan the fused wire layout for an ordered sequence of arrays.

    ``parts`` needs only ``.shape`` and ``.dtype`` (arrays or
    ShapeDtypeStructs).  Returns a deterministic :class:`FlatPlan`: chunk
    order follows first appearance of each wire dtype, slots follow input
    order.  See the module comment for the chunking policy.
    """
    if wire_dtype not in WIRE_DTYPES:
        raise ValueError(
            f"unknown wire_dtype {wire_dtype!r}; use one of {WIRE_DTYPES}")
    cast = None if wire_dtype == "auto" else jnp.dtype(wire_dtype)
    chunks: list = []          # [wire_dtype, offset, [FlatSlot]]
    by_dtype: dict = {}        # wire dtype -> open chunk (last of its dtype)
    for i, p in enumerate(parts):
        wd = cast if cast is not None else jnp.dtype(p.dtype)
        size = math.prod(p.shape) if p.shape else 1
        open_chunk = by_dtype.get(wd)
        if open_chunk is not None and max_chunk_bytes is not None:
            if (open_chunk[1] + size) * wd.itemsize > max_chunk_bytes:
                open_chunk = None  # cap reached: start a fresh chunk
        if open_chunk is None:
            open_chunk = [wd, 0, []]
            chunks.append(open_chunk)
            by_dtype[wd] = open_chunk
        open_chunk[2].append(FlatSlot(
            index=i, offset=open_chunk[1], size=size,
            shape=tuple(p.shape), dtype=jnp.dtype(p.dtype)))
        open_chunk[1] += size
    return FlatPlan(chunks=tuple(
        FlatChunk(wire_dtype=wd, slots=tuple(slots))
        for wd, _, slots in chunks))


def pack_flat(chunk: FlatChunk, parts) -> jax.Array:
    """Concatenate the chunk's slots (indexable ``parts``) into its 1-D wire
    buffer, casting to the wire dtype."""
    flats = [jnp.ravel(parts[s.index]).astype(chunk.wire_dtype)
             for s in chunk.slots]
    return flats[0] if len(flats) == 1 else jnp.concatenate(flats)


def unpack_flat(chunk: FlatChunk, buf: jax.Array, leading=()) -> dict:
    """Split a (possibly gathered: ``leading=(W,)``) wire buffer back into
    ``{slot.index: array}`` with original shapes/dtypes restored."""
    out = {}
    for s in chunk.slots:
        x = jax.lax.slice_in_dim(buf, s.offset, s.offset + s.size, axis=-1)
        out[s.index] = x.reshape(tuple(leading) + s.shape).astype(s.dtype)
    return out


def compressed_floats(shape: Tuple[int, ...], spec: MatrixSpec, rank: int) -> int:
    """Number of floats sent per all-reduce for this leaf at rank r
    (the P and Q messages together: r·(n+m) per matrix in the batch)."""
    ms = matrix_shape(shape, spec)
    if ms is None:
        return math.prod(shape)  # sent uncompressed
    batch_shape, n, m = ms
    return math.prod(batch_shape) * rank * (n + m)


def uncompressed_floats(shape: Tuple[int, ...]) -> int:
    return math.prod(shape)
