"""Tensor ⇄ matrix reshaping rules for gradient compression (paper §3).

The paper treats each parameter's gradient as a matrix:

* dense / fully-connected weights are used as-is,
* conv kernels ``(O, I, kh, kw)`` are flattened to ``(O, I·kh·kw)``
  (Appendix F, Table 10),
* vectors (biases, norm scales, per-head SSM scalars) are exempt and
  aggregated uncompressed.

Our parameters additionally carry *stacking* dimensions — a leading layer dim
from ``lax.scan`` over the block stack, and an expert dim for MoE weights.
Those become vmap batch dims of the compressor.

Every parameter leaf is described by a :class:`MatrixSpec`; model inits
produce a spec tree (same structure as the param tree).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    """How one parameter tensor maps to compression matrices.

    kind:
      "none"   — aggregated uncompressed (vectors / tiny params)
      "matrix" — reshape trailing dims to 2-D
      "conv"   — (O, I, kh, kw) → (O, I·kh·kw), after batch dims
    batch_dims: number of leading stacking dims (layer stack, expert dim)
                that become vmap batch dims.
    """

    kind: str = "matrix"
    batch_dims: int = 0

    def is_compressed(self) -> bool:
        return self.kind != "none"


NONE = MatrixSpec(kind="none")


def default_spec(leaf: jax.ShapeDtypeStruct | jax.Array, batch_dims: int = 0) -> MatrixSpec:
    """Heuristic used by model inits: <2 trailing dims ⇒ uncompressed."""
    trailing = len(leaf.shape) - batch_dims
    if trailing < 2:
        return NONE
    if trailing == 4:
        return MatrixSpec(kind="conv", batch_dims=batch_dims)
    return MatrixSpec(kind="matrix", batch_dims=batch_dims)


def matrix_shape(shape: Tuple[int, ...], spec: MatrixSpec) -> Optional[Tuple[Tuple[int, ...], int, int]]:
    """Returns (batch_shape, n, m) or None for uncompressed leaves."""
    if not spec.is_compressed():
        return None
    b = spec.batch_dims
    batch_shape, rest = tuple(shape[:b]), shape[b:]
    if spec.kind == "conv":
        assert len(rest) == 4, f"conv spec needs 4 trailing dims, got {rest}"
        n, m = rest[0], rest[1] * rest[2] * rest[3]
    else:
        assert len(rest) >= 2, f"matrix spec needs ≥2 trailing dims, got {rest}"
        n, m = rest[0], math.prod(rest[1:])
    return batch_shape, n, m


def to_matrix(x: jax.Array, spec: MatrixSpec) -> jax.Array:
    ms = matrix_shape(x.shape, spec)
    assert ms is not None
    batch_shape, n, m = ms
    return x.reshape(batch_shape + (n, m))


def from_matrix(mat: jax.Array, shape: Tuple[int, ...], spec: MatrixSpec) -> jax.Array:
    return mat.reshape(shape)


# ---------------------------------------------------------------------------
# Shape bucketing (the batched-compression engine's planning stage)
# ---------------------------------------------------------------------------
#
# The per-leaf compressor runs two tiny matmuls + two tiny collectives per
# weight matrix.  The bucketed engine instead groups matrices of similar shape
# into buckets, zero-pads each matrix up to its bucket's (n, m), stacks the
# bucket into one (B, n, m) slab and runs the whole power-iteration step as
# batched ops.  Zero padding is exact: padded rows/columns of M contribute
# exact zeros to P = M Q and Q = Mᵀ P̂, and zero rows do not perturb
# Gram-Schmidt / Cholesky-QR (they add nothing to any column inner product).
#
# Planning is pure Python over static shapes — it happens once at trace time.
#
# Plans are deliberately RANK-AGNOSTIC: buckets are a function of the (n, m)
# matrix shapes only, never of the compression rank.  That is what lets the
# adaptive-rank subsystem (core/powersgd.py RankSchedule, core/autotune.py)
# move ranks between steps — and assign *different* ranks to different
# buckets — without invalidating any plan: the factor slabs
# (pack_factors / unpack_entry with cols=None) carry whatever trailing rank
# the state's Q factors have, and an offline autotune plan computed from the
# same shapes re-derives the identical buckets by determinism.  Only the
# per-call accounting (compressed_floats) takes a rank, per leaf.


@dataclasses.dataclass(frozen=True)
class BucketEntry:
    """One leaf's slot range inside a bucket's stacking dimension."""

    index: int    # position of the leaf in the planner's input sequence
    count: int    # matrices this leaf contributes (= prod(batch_shape))
    n: int        # un-padded rows
    m: int        # un-padded cols
    offset: int   # first slot in the bucket's leading (stack) dim


@dataclasses.dataclass(frozen=True)
class Bucket:
    """A (n, m)-padded stack of matrices compressed as one batched op."""

    n: int
    m: int
    entries: Tuple[BucketEntry, ...]

    @property
    def count(self) -> int:
        return sum(e.count for e in self.entries)

    @property
    def padded_elems(self) -> int:
        return self.count * self.n * self.m

    @property
    def real_elems(self) -> int:
        return sum(e.count * e.n * e.m for e in self.entries)


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    buckets: Tuple[Bucket, ...]

    @functools.cached_property
    def _by_index(self):
        return {e.index: (b_id, e)
                for b_id, b in enumerate(self.buckets) for e in b.entries}

    def entry_for(self, index: int) -> Tuple[int, BucketEntry]:
        """(bucket position, entry) for the leaf at ``index``."""
        return self._by_index[index]


def plan_buckets(matrix_shapes, tolerance: float = 0.25) -> BucketPlan:
    """Greedy shape bucketing with a padding-waste tolerance.

    ``matrix_shapes`` is a sequence aligned with the (flattened) compressed
    leaves: each element is ``(count, n, m)`` — ``count`` matrices of shape
    ``(n, m)`` — or ``None`` for leaves that do not participate (uncompressed
    vectors).  Shapes are placed largest-area first; a shape joins an existing
    bucket iff it fits inside the bucket's (n, m) and the padded area exceeds
    its own by at most ``tolerance`` (relative).  ``tolerance=0`` buckets only
    exactly-equal shapes together.

    The plan is deterministic: bucket order follows descending seed-shape
    area, and entries within a bucket follow leaf order.
    """
    items = [(i, s[0], s[1], s[2])
             for i, s in enumerate(matrix_shapes) if s is not None]
    order = sorted(items, key=lambda t: (-(t[2] * t[3]), t[0]))
    raw = []  # [n, m, [items]]
    for it in order:
        i, c, n, m = it
        for b in raw:
            bn, bm = b[0], b[1]
            if n <= bn and m <= bm and bn * bm <= (1.0 + tolerance) * n * m:
                b[2].append(it)
                break
        else:
            raw.append([n, m, [it]])
    buckets = []
    for bn, bm, its in raw:
        its.sort(key=lambda t: t[0])  # deterministic pack order: leaf order
        entries, off = [], 0
        for i, c, n, m in its:
            entries.append(BucketEntry(index=i, count=c, n=n, m=m, offset=off))
            off += c
        buckets.append(Bucket(n=bn, m=bm, entries=tuple(entries)))
    return BucketPlan(buckets=tuple(buckets))


def pack_matrices(bucket: Bucket, arrays) -> jax.Array:
    """Stack per-leaf ``(count, n, m)`` arrays into the bucket's
    ``(B, bucket.n, bucket.m)`` slab, zero-padding rows and columns.
    ``arrays`` is indexable by ``entry.index``."""
    parts = []
    for e in bucket.entries:
        x = arrays[e.index]
        parts.append(jnp.pad(x, ((0, 0), (0, bucket.n - e.n),
                                 (0, bucket.m - e.m))))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


def pack_factors(bucket: Bucket, arrays) -> jax.Array:
    """Stack per-leaf ``(count, m, r)`` factor arrays into ``(B, bucket.m, r)``,
    zero-padding the m rows (exact: padded columns of M are zero)."""
    parts = []
    for e in bucket.entries:
        x = arrays[e.index]
        parts.append(jnp.pad(x, ((0, 0), (0, bucket.m - e.m), (0, 0))))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


def unpack_entry(stacked: jax.Array, entry: BucketEntry,
                 rows: int, cols: Optional[int] = None) -> jax.Array:
    """Slice one leaf's ``(count, rows, cols)`` block back out of a bucket
    slab, cropping the padding.  ``cols=None`` keeps the trailing dim whole
    (for (B, m, r) factor slabs)."""
    x = stacked[entry.offset:entry.offset + entry.count, :rows]
    return x if cols is None else x[:, :, :cols]


# ---------------------------------------------------------------------------
# Flat-payload planning (the transport engine's fused-buffer stage)
# ---------------------------------------------------------------------------
#
# Matrix slabs (above) batch the *compute*; flat plans batch the *wire*.  A
# FlatPlan maps an ordered list of payload arrays — P/Q factor slabs, sparse
# value/index vectors, sign buffers, uncompressed bias leaves — onto one or
# more contiguous 1-D wire buffers ("chunks").  Chunking policy:
#
# * ``wire_dtype="auto"``  — parts keep their own dtype; parts of the same
#   dtype share a chunk (in input order).  This deliberately replaces the
#   old ``jnp.result_type(*parts)`` behaviour, where a single float32
#   straggler silently upcast an entire bfloat16 payload on the wire.
# * ``wire_dtype="float32"|"bfloat16"`` — every part is cast to that dtype
#   for transport (and cast back on unpack), one shared chunk.
# * ``wire_dtype="int8"|"int4"`` — float parts are symmetrically quantized
#   per slot (scale = max|x|/qmax, a float32 scale sidecar per slot) and
#   share one integer chunk; int4 additionally nibble-packs two codes per
#   uint8 byte (``repro.kernels`` pack/unpack).  Integer parts (top-k
#   indices, sign bytes) are never quantized — they keep their own dtype
#   in auto-style chunks, exactly like under ``"auto"``.
# * ``max_chunk_bytes`` — optional cap; a chunk is split once its wire size
#   would exceed the cap (a part never spans two chunks).
#
# Planning is pure Python over static shapes/dtypes — trace-time only.


WIRE_DTYPES = ("auto", "float32", "bfloat16", "int8", "int4")
QUANT_WIRE_DTYPES = ("int8", "int4")
QUANT_QMAX = {"int8": 127, "int4": 7}
_QUANT_ITEMSIZE = {"int8": 1.0, "int4": 0.5}   # wire bytes per element
SCALE_BYTES = 4                                # one f32 scale per quant slot


@dataclasses.dataclass(frozen=True)
class FlatSlot:
    """One payload array's position inside a flat wire chunk."""

    index: int                 # position in the planner's input sequence
    offset: int                # first element inside the chunk buffer
    size: int                  # number of elements
    shape: Tuple[int, ...]     # original shape (restored on unpack)
    dtype: "jnp.dtype"         # original dtype (restored on unpack)


@dataclasses.dataclass(frozen=True)
class FlatChunk:
    """One contiguous wire buffer: same wire dtype, issued as one collective.

    ``quant`` marks a quantized payload chunk (``"int8"``/``"int4"``):
    ``wire_dtype`` is then the *storage* dtype of the shipped codes (int8,
    or uint8 for nibble-packed int4) and every slot carries a float32
    symmetric scale in a sidecar that rides the same collective."""

    wire_dtype: "jnp.dtype"
    slots: Tuple[FlatSlot, ...]
    quant: Optional[str] = None

    @property
    def size(self) -> int:
        return sum(s.size for s in self.slots)

    @property
    def wire_itemsize(self) -> float:
        """Bytes ONE element costs on the wire — fractional for int4."""
        if self.quant is not None:
            return _QUANT_ITEMSIZE[self.quant]
        return float(jnp.dtype(self.wire_dtype).itemsize)

    @property
    def overhead_bytes(self) -> int:
        """Scale-sidecar bytes (zero for unquantized chunks)."""
        return SCALE_BYTES * len(self.slots) if self.quant is not None else 0

    @property
    def wire_bytes(self):
        """Honest wire bytes: payload at ``wire_itemsize`` + scale sidecar.
        An int (the common case) or a float for odd-size int4 payloads."""
        b = self.size * self.wire_itemsize + self.overhead_bytes
        return int(b) if float(b).is_integer() else b


@dataclasses.dataclass(frozen=True)
class FlatPlan:
    chunks: Tuple[FlatChunk, ...]

    @property
    def total_wire_bytes(self):
        b = sum(c.wire_bytes for c in self.chunks)
        return int(b) if float(b).is_integer() else b


def plan_flat(parts, wire_dtype: str = "auto",
              max_chunk_bytes: Optional[int] = None) -> FlatPlan:
    """Plan the fused wire layout for an ordered sequence of arrays.

    ``parts`` needs only ``.shape`` and ``.dtype`` (arrays or
    ShapeDtypeStructs).  Returns a deterministic :class:`FlatPlan`: chunk
    order follows first appearance of each wire dtype, slots follow input
    order.  See the module comment for the chunking policy.
    """
    if wire_dtype not in WIRE_DTYPES:
        raise ValueError(
            f"unknown wire_dtype {wire_dtype!r}; use one of {WIRE_DTYPES}")
    quant = wire_dtype if wire_dtype in QUANT_WIRE_DTYPES else None
    cast = (None if (wire_dtype == "auto" or quant is not None)
            else jnp.dtype(wire_dtype))
    chunks: list = []          # [wire_dtype, offset, [FlatSlot], quant_label]
    by_key: dict = {}          # chunk key -> open chunk (last of its key)
    for i, p in enumerate(parts):
        if quant is not None and jnp.issubdtype(jnp.dtype(p.dtype),
                                                jnp.floating):
            # float payloads share one quantized chunk; storage dtype is the
            # shipped code array: int8 codes, or packed nibbles for int4.
            wd = jnp.dtype(jnp.int8 if quant == "int8" else jnp.uint8)
            key: object = quant
            label = quant
            itemsize: float = _QUANT_ITEMSIZE[quant]
        else:
            # integer parts (top-k indices, sign bytes) are never quantized
            wd = cast if cast is not None else jnp.dtype(p.dtype)
            key = wd
            label = None
            itemsize = float(wd.itemsize)
        size = math.prod(p.shape) if p.shape else 1
        open_chunk = by_key.get(key)
        if open_chunk is not None and max_chunk_bytes is not None:
            if (open_chunk[1] + size) * itemsize > max_chunk_bytes:
                open_chunk = None  # cap reached: start a fresh chunk
        if open_chunk is None:
            open_chunk = [wd, 0, [], label]
            chunks.append(open_chunk)
            by_key[key] = open_chunk
        open_chunk[2].append(FlatSlot(
            index=i, offset=open_chunk[1], size=size,
            shape=tuple(p.shape), dtype=jnp.dtype(p.dtype)))
        open_chunk[1] += size
    return FlatPlan(chunks=tuple(
        FlatChunk(wire_dtype=wd, slots=tuple(slots), quant=label)
        for wd, _, slots, label in chunks))


def pack_flat(chunk: FlatChunk, parts) -> jax.Array:
    """Concatenate the chunk's slots (indexable ``parts``) into its 1-D wire
    buffer, casting to the wire dtype."""
    if chunk.quant is not None:
        raise ValueError(
            "pack_flat on a quantized chunk — use quant_pack_flat / "
            "quant_dequant_flat (the payload needs its scale sidecar)")
    flats = [jnp.ravel(parts[s.index]).astype(chunk.wire_dtype)
             for s in chunk.slots]
    return flats[0] if len(flats) == 1 else jnp.concatenate(flats)


def unpack_flat(chunk: FlatChunk, buf: jax.Array, leading=()) -> dict:
    """Split a (possibly gathered: ``leading=(W,)``) wire buffer back into
    ``{slot.index: array}`` with original shapes/dtypes restored."""
    out = {}
    for s in chunk.slots:
        x = jax.lax.slice_in_dim(buf, s.offset, s.offset + s.size, axis=-1)
        out[s.index] = x.reshape(tuple(leading) + s.shape).astype(s.dtype)
    return out


# ---------------------------------------------------------------------------
# quantized payload chunks (wire_dtype="int8"/"int4", ISSUE 9)
#
# Each slot is quantized symmetrically on its own: scale = max|x|/qmax, codes
# = clip(round(x/scale)).  The float32 scales ride the same collective as a
# sidecar (SCALE_BYTES per slot in the byte accounting).  Two combine modes:
#
# * reduce path (all-reduce schemes): quantize → dequantize locally, then
#   reduce the dequantized float32 buffer — the "widened accumulator": every
#   worker contributes exactly its wire-representable values, the mean is
#   taken at full precision, and the transport stays a plain all-reduce.
# * gather path (schemes that already all-gather): ship the real integer
#   payload (nibble-packed for int4) plus per-slot scales and dequantize
#   per-worker after the gather.
# ---------------------------------------------------------------------------


def _nibble_pack(q):
    # 1-D codes (the per-worker pack path) go through the ops dispatcher so
    # accelerators hit the Pallas kernel; leading-dim arrays (post-gather
    # unpack sees (W, bytes)) use the vmap-safe reference directly.
    from repro.kernels import ops as _kops
    from repro.kernels import ref as _kref
    return _kops.nibble_pack(q) if q.ndim == 1 else _kref.nibble_pack(q)


def _nibble_unpack(packed, n):
    from repro.kernels import ops as _kops
    from repro.kernels import ref as _kref
    if packed.ndim == 1:
        return _kops.nibble_unpack(packed, n)
    return _kref.nibble_unpack(packed, n)


def quant_slot_sizes(chunk: FlatChunk):
    """Per-slot payload lengths in the shipped code buffer: ceil(size/2)
    bytes for int4 (each slot padded to its own even length so slot
    boundaries stay byte-aligned), size for int8."""
    if chunk.quant == "int4":
        return [(s.size + 1) // 2 for s in chunk.slots]
    return [s.size for s in chunk.slots]


def quant_pack_flat(chunk: FlatChunk, parts):
    """Quantize + pack a quantized chunk → ``(payload, scales)``.

    ``payload`` is the 1-D shipped code buffer (int8 codes, or uint8
    nibble-packed for int4, each slot padded to an even code count);
    ``scales`` is the float32 per-slot scale sidecar, shape (n_slots,)."""
    from repro.kernels import ref as _kref
    qmax = QUANT_QMAX[chunk.quant]
    codes, scales = [], []
    for s in chunk.slots:
        x = jnp.ravel(parts[s.index]).astype(jnp.float32)
        sc = _kref.quant_scale(x, qmax)
        scales.append(sc)
        codes.append(_kref.quantize(x, sc, qmax))
    if chunk.quant == "int4":
        codes = [_nibble_pack(c) for c in codes]
    payload = codes[0] if len(codes) == 1 else jnp.concatenate(codes)
    return payload, jnp.stack(scales)


def quant_unpack_flat(chunk: FlatChunk, payload, scales, leading=()) -> dict:
    """Dequantize a (possibly gathered: ``leading=(W,)``) quantized payload
    back into ``{slot.index: array}`` with original shapes/dtypes."""
    out, poff = {}, 0
    for k, s in enumerate(chunk.slots):
        psz = (s.size + 1) // 2 if chunk.quant == "int4" else s.size
        piece = jax.lax.slice_in_dim(payload, poff, poff + psz, axis=-1)
        poff += psz
        if chunk.quant == "int4":
            piece = _nibble_unpack(piece, s.size)
        sc = scales[..., k]
        x = piece.astype(jnp.float32) * sc[..., None]
        out[s.index] = x.reshape(tuple(leading) + s.shape).astype(s.dtype)
    return out


def quant_dequant_flat(chunk: FlatChunk, parts) -> jax.Array:
    """Local quantize→dequantize of a quantized chunk as one float32 wire
    buffer — the all-reduce path's widened accumulator.  The reduced buffer
    is laid out exactly like an unquantized chunk, so :func:`unpack_flat`
    splits it."""
    from repro.kernels import ref as _kref
    qmax = QUANT_QMAX[chunk.quant]
    outs = []
    for s in chunk.slots:
        x = jnp.ravel(parts[s.index]).astype(jnp.float32)
        sc = _kref.quant_scale(x, qmax)
        outs.append(_kref.dequantize(_kref.quantize(x, sc, qmax), sc))
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs)


def compressed_floats(shape: Tuple[int, ...], spec: MatrixSpec, rank: int) -> int:
    """Number of floats sent per all-reduce for this leaf at rank r
    (the P and Q messages together: r·(n+m) per matrix in the batch)."""
    ms = matrix_shape(shape, spec)
    if ms is None:
        return math.prod(shape)  # sent uncompressed
    batch_shape, n, m = ms
    return math.prod(batch_shape) * rank * (n + m)


def uncompressed_floats(shape: Tuple[int, ...]) -> int:
    return math.prod(shape)
