"""Tensor ⇄ matrix reshaping rules for gradient compression (paper §3).

The paper treats each parameter's gradient as a matrix:

* dense / fully-connected weights are used as-is,
* conv kernels ``(O, I, kh, kw)`` are flattened to ``(O, I·kh·kw)``
  (Appendix F, Table 10),
* vectors (biases, norm scales, per-head SSM scalars) are exempt and
  aggregated uncompressed.

Our parameters additionally carry *stacking* dimensions — a leading layer dim
from ``lax.scan`` over the block stack, and an expert dim for MoE weights.
Those become vmap batch dims of the compressor.

Every parameter leaf is described by a :class:`MatrixSpec`; model inits
produce a spec tree (same structure as the param tree).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    """How one parameter tensor maps to compression matrices.

    kind:
      "none"   — aggregated uncompressed (vectors / tiny params)
      "matrix" — reshape trailing dims to 2-D
      "conv"   — (O, I, kh, kw) → (O, I·kh·kw), after batch dims
    batch_dims: number of leading stacking dims (layer stack, expert dim)
                that become vmap batch dims.
    """

    kind: str = "matrix"
    batch_dims: int = 0

    def is_compressed(self) -> bool:
        return self.kind != "none"


NONE = MatrixSpec(kind="none")


def default_spec(leaf: jax.ShapeDtypeStruct | jax.Array, batch_dims: int = 0) -> MatrixSpec:
    """Heuristic used by model inits: <2 trailing dims ⇒ uncompressed."""
    trailing = len(leaf.shape) - batch_dims
    if trailing < 2:
        return NONE
    if trailing == 4:
        return MatrixSpec(kind="conv", batch_dims=batch_dims)
    return MatrixSpec(kind="matrix", batch_dims=batch_dims)


def matrix_shape(shape: Tuple[int, ...], spec: MatrixSpec) -> Optional[Tuple[Tuple[int, ...], int, int]]:
    """Returns (batch_shape, n, m) or None for uncompressed leaves."""
    if not spec.is_compressed():
        return None
    b = spec.batch_dims
    batch_shape, rest = tuple(shape[:b]), shape[b:]
    if spec.kind == "conv":
        assert len(rest) == 4, f"conv spec needs 4 trailing dims, got {rest}"
        n, m = rest[0], rest[1] * rest[2] * rest[3]
    else:
        assert len(rest) >= 2, f"matrix spec needs ≥2 trailing dims, got {rest}"
        n, m = rest[0], math.prod(rest[1:])
    return batch_shape, n, m


def to_matrix(x: jax.Array, spec: MatrixSpec) -> jax.Array:
    ms = matrix_shape(x.shape, spec)
    assert ms is not None
    batch_shape, n, m = ms
    return x.reshape(batch_shape + (n, m))


def from_matrix(mat: jax.Array, shape: Tuple[int, ...], spec: MatrixSpec) -> jax.Array:
    return mat.reshape(shape)


def compressed_floats(shape: Tuple[int, ...], spec: MatrixSpec, rank: int) -> int:
    """Number of floats sent per all-reduce for this leaf at rank r
    (the P and Q messages together: r·(n+m) per matrix in the batch)."""
    ms = matrix_shape(shape, spec)
    if ms is None:
        return math.prod(shape)  # sent uncompressed
    batch_shape, n, m = ms
    return math.prod(batch_shape) * rank * (n + m)


def uncompressed_floats(shape: Tuple[int, ...]) -> int:
    return math.prod(shape)
