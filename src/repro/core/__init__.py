"""Core library: the paper's contribution (PowerSGD + EF-SGD) as composable
JAX modules."""

from repro import compat  # noqa: F401  (installs jax API shims)
from repro.core.dist import (
    AXIS,
    AxisBackend,
    CollectiveBackend,
    CollectiveStats,
    MeshCtx,
    SimBackend,
    SINGLE,
)
from repro.core.simmesh import SimMesh
from repro.core.matrixize import MatrixSpec, default_spec
from repro.core.engine import CompressOut, Encoded, MatrixPayloads, Transport
from repro.core.powersgd import PowerSGDConfig, compress_aggregate, init_state
from repro.core.compressors import (
    Compressor,
    IdentityCompressor,
    PowerSGDCompressor,
    UnbiasedRankK,
    RandomBlock,
    RandomK,
    SignNorm,
    TopK,
    SpectralAtomo,
    ExactRankK,
    make_compressor,
)
from repro.core import error_feedback
