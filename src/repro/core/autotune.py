"""α-β autotuner for PowerSGD's compressed-collective transport.

Picking the rank is the whole game (paper §4.2, Tables 1–3): too low hurts
quality, too high wastes the bandwidth the scheme exists to save — and
whether a configuration actually beats dense SGD depends on the *cluster*,
not just the payload size (Zhang et al., 2023: compression knobs must be
tuned against an α-β communication model).  This module closes that loop:

    shapes/specs ──► bucket plan (matrixize.plan_buckets, the same
                     deterministic plan the engine executes)
    CollectiveStats / roofline ──► HardwareModel (α latency, β bandwidth)
    bits budget ──► per-bucket (rank, wire_dtype, max_chunk_bytes)

:func:`autotune` returns a :class:`TunePlan`; :func:`apply_plan` installs
its per-bucket ranks into a live compressor state with the
warm-start-preserving transitions of :func:`repro.core.powersgd.
transition_state` (retained factor columns survive bit-exactly), and
``wire_dtype`` / ``max_chunk_bytes`` thread into
:class:`~repro.core.compressors.PowerSGDCompressor` unchanged.

Two deliberate constraints, both in service of the engine's
O(1)-collectives-per-step invariant:

* ``wire_dtype`` is selected *globally*, not per bucket: per-bucket wire
  dtypes would fragment the fused flat chunk into one collective per dtype
  per phase (see ``plan_flat``'s "auto" policy), trading the latency win
  the transport engine exists for.  The tuner therefore scores each
  candidate dtype over the whole plan and keeps the cheapest.
* Ranks are assigned per *bucket*, never per leaf: leaves sharing a shape
  bucket share a ``(B, m, r)`` factor slab, so a per-leaf split would
  force bucket fission.  Bucket membership is a pure function of matrix
  shapes, so a plan computed here stays valid for the engine's own
  planning pass (``engine.MatrixPayloads.build`` re-derives the identical
  buckets and reads the ranks off the transitioned state).

The greedy knapsack (see :func:`autotune`) starts every bucket at the
largest candidate rank and walks ranks down until the bits budget holds,
each time shrinking the bucket with the best bits-saved per modeled
quality loss.  Quality loss for stepping bucket b from r to r' is the
flat-tail spectrum proxy ``(r − r')/min(n, m) · Σ count·n·m`` — each extra
tracked direction captures ~1/min(n,m) of a matrix's residual tail energy
— optionally scaled by a *measured* per-bucket residual-energy ratio
(``CompressOut.metrics["bucket_residual_ratio"]`` from a probe step with
``track_residual=True``): buckets whose residual is already low are
cheaper to shrink.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import jax

from repro.core import matrixize, powersgd
from repro.launch import roofline

# α-β parameters of the paper's Appendix B cluster (10 Gbit/s ethernet),
# mirrored from benchmarks/common.py — core cannot import benchmarks/.
_BACKENDS = {
    "nccl_10gbit": (30e-6, 10e9 / 8),
    "gloo_10gbit": (150e-6, 2.5e9 / 8),
}

# How many budget bits one payload float costs under each wire dtype.
# float32/bfloat16 both charge 32: the bits budget keeps the paper's
# float-accounting convention and the bf16 cast is a free precision/wire
# win on top (legacy behavior).  The quantized dtypes genuinely re-price
# the budget — the same bits afford 4×/8× the payload floats, which is
# exactly the rank-vs-precision trade the tuner arbitrates.
_WIRE_BUDGET_BITS = {"float32": 32, "bfloat16": 32, "int8": 8, "int4": 4}
# Honest bytes one payload element occupies on the wire (α-β pricing).
_WIRE_ITEMSIZE = {"float32": 4.0, "bfloat16": 2.0, "int8": 1.0, "int4": 0.5}


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """α-β link model: one collective costs α·(#rounds) + β·(bytes moved).

    ``alpha`` is the per-round launch latency in seconds, ``bw`` the
    per-link bandwidth in bytes/s (β = 1/bw).
    """

    alpha: float
    bw: float

    @classmethod
    def from_roofline(cls, alpha: float = 20e-6) -> "HardwareModel":
        """The TPU-v5e ICI link of :mod:`repro.launch.roofline`
        (~50 GB/s/link) with a nominal launch latency."""
        return cls(alpha=alpha, bw=roofline.LINK_BW)

    @classmethod
    def from_backend(cls, name: str) -> "HardwareModel":
        """The paper's ethernet backends (``nccl_10gbit``/``gloo_10gbit``),
        same numbers as ``benchmarks/common.py``."""
        alpha, bw = _BACKENDS[name]
        return cls(alpha=alpha, bw=bw)

    def collective_time(self, wire_bytes: float, workers: int,
                        kind: str = "reduce") -> float:
        """Modeled seconds for one fused collective among ``workers``."""
        if workers <= 1:
            return 0.0
        if kind == "reduce":  # ring all-reduce
            rounds = math.ceil(math.log2(workers))
            return (self.alpha * rounds
                    + 2 * (workers - 1) / workers * wire_bytes / self.bw)
        if kind == "broadcast":
            # scatter + all-gather broadcast (van de Geijn): half an
            # all-reduce's bandwidth term, same tree depth in latency —
            # the extra leg sync_mode="broadcast" pays per synced aggregate
            rounds = math.ceil(math.log2(workers))
            return (self.alpha * rounds
                    + (workers - 1) / workers * wire_bytes / self.bw)
        # all-gather: a worker receives every other worker's payload
        return (self.alpha + wire_bytes / self.bw) * (workers - 1)


def comm_time_from_stats(stats, workers: int, hw: HardwareModel, *,
                         overlap_compute_s: float = 0.0) -> float:
    """α-β time of one *recorded* step (`repro.core.dist.CollectiveStats`):
    each collective at its actual wire size, itemsize and transport kind.
    This is how a measured trace calibrates/validates a :class:`TunePlan`
    (compare against ``TunePlan.predicted_comm_s``).

    ``overlap_compute_s`` prices the one-step-stale pipeline
    (``staleness="one_step"``): comm that runs concurrently with that much
    compute (the roofline model's fwd+bwd time — see
    :meth:`repro.launch.roofline.Roofline.compute_s`) is hidden, and only
    the *exposed* remainder ``max(0, comm − compute)`` stays on the
    critical path.  The default 0.0 is the synchronous schedule (all comm
    exposed), so existing call sites are unchanged."""
    total = 0.0
    overheads = list(getattr(stats, "overheads", ()) or ())
    overheads += [0] * (len(stats.sizes) - len(overheads))
    for size, itemsize, kind, overhead in zip(stats.sizes, stats.itemsizes,
                                              stats.kinds, overheads):
        total += hw.collective_time(size * itemsize + overhead, workers, kind)
    return max(0.0, total - overlap_compute_s)


# ---------------------------------------------------------------------------
# plan data model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BucketDecision:
    """The tuner's verdict for one shape bucket."""

    bucket: int                # index into the BucketPlan's buckets
    n: int                     # bucket (padded) rows
    m: int                     # bucket (padded) cols
    count: int                 # stacked matrices in the bucket
    rank: int                  # assigned rank
    payload_floats: int        # Σ_leaves count·r·(n_leaf + m_leaf), unpadded
    wire_floats: int           # count·r·(n + m) at bucket dims (what travels)


@dataclasses.dataclass(frozen=True)
class TunePlan:
    """Per-bucket ranks + a global wire policy, under a bits budget."""

    decisions: Tuple[BucketDecision, ...]
    wire_dtype: str
    max_chunk_bytes: Optional[int]
    tolerance: float           # bucket_pad_tolerance the plan was built at —
    #                            the engine must re-plan with the SAME value
    #                            or its buckets (and therefore which leaves
    #                            must share a rank) diverge from this plan's
    payload_floats: int        # compressed floats per step (bits metric)
    uncompressed_floats: int   # vector leaves riding the first reduce
    bits_per_step: int         # (payload + uncompressed) × 32 — the paper's
    #                            Tables 3/10/11 accounting convention
    wire_bits_per_step: int    # honest on-the-wire bits: payload at the wire
    #                            dtype's real width (16/8/4) + scale sidecars
    predicted_comm_s: float    # α-β modeled gradient exchange per step
    workers: int
    leaf_ranks: Tuple[Optional[int], ...]  # per planner leaf, tree order

    def rank_tree(self, shapes, specs):
        """Per-leaf rank tree aligned with ``shapes`` (None = uncompressed
        or untouched) — the shape :func:`repro.core.powersgd.
        transition_state` takes for per-bucket switches."""
        idx = [0]

        def leaf(shape_leaf, spec):
            r = self.leaf_ranks[idx[0]]
            idx[0] += 1
            return r

        return jax.tree_util.tree_map(leaf, shapes, specs)


def _collect(shapes, specs):
    """(shape, spec) pairs in deterministic tree order — the exact leaf
    order ``engine.collect_leaves`` uses, so planner indices line up."""
    leaves = []
    jax.tree_util.tree_map(
        lambda s, sp: leaves.append((tuple(s.shape), sp)), shapes, specs)
    return leaves


def _phase_time(wire_floats: Sequence[int], unc_floats: int, itemsize: float,
                workers: int, hw: HardwareModel,
                max_chunk_bytes: Optional[int],
                overhead_bytes: float = 0.0) -> float:
    """Modeled time of the two fused reduce phases of one PowerSGD step.

    Phase 1 carries every bucket's P slab (n-side factors) plus the
    uncompressed leaves; phase 2 the Q slabs (m-side).  Factors split
    r·(n+m) as r·n / r·m; modeling each phase at half the total is exact
    in aggregate and keeps the tuner independent of the n/m split.
    ``overhead_bytes`` is the per-step sidecar cost (quantization scales),
    split evenly over the two phases."""
    total = 0.0
    for phase_floats in (sum(wire_floats) / 2 + unc_floats,
                         sum(wire_floats) / 2):
        nbytes = phase_floats * itemsize + overhead_bytes / 2
        chunks = (1 if not max_chunk_bytes
                  else max(1, math.ceil(nbytes / max_chunk_bytes)))
        per_chunk = nbytes / chunks
        total += sum(hw.collective_time(per_chunk, workers, "reduce")
                     for _ in range(chunks))
    return total


def autotune(shapes, specs, *, bits_budget: int, workers: int,
             hw: Optional[HardwareModel] = None,
             ranks: Sequence[int] = (1, 2, 4, 8),
             wire_dtypes: Sequence[str] = ("float32", "bfloat16"),
             max_chunk_bytes_options: Sequence[Optional[int]] = (None,),
             tolerance: float = 0.25,
             bucket_residuals: Optional[Sequence[float]] = None,
             overlap_compute_s: float = 0.0) -> TunePlan:
    """Select per-bucket ``rank`` + global ``(wire_dtype, max_chunk_bytes)``.

    ``bits_budget`` bounds the *payload* bits per step per worker.  Under
    the float wire dtypes this is the paper's accounting (32 bits per
    compressed float plus the uncompressed vector leaves, a fixed cost the
    tuner cannot reduce; the bfloat16 cast is a free win on top).  The
    quantized wire dtypes re-price the budget at their real width
    (``_WIRE_BUDGET_BITS``: 8 for int8, 4 for int4), so one budget can be
    spent on rank *or* precision: the tuner runs the greedy rank walk-down
    (module docstring) once per wire candidate and keeps the candidate
    retaining the most payload floats, tie-broken by the α-β modeled
    exchange time over the chunk-cap options.  ``bucket_residuals`` (ordered like the bucket plan,
    e.g. from a ``track_residual=True`` probe step) steers the walk-down
    toward buckets whose subspace already covers their gradients.

    ``overlap_compute_s`` prices a pipelined (``staleness="one_step"``)
    schedule: pass the step's compute time (e.g. from
    :meth:`repro.launch.roofline.Roofline.compute_s`) and candidates are
    compared by *exposed* comm — ``max(0, modeled − overlap_compute_s)`` —
    matching :func:`comm_time_from_stats`'s overlap term, so the tuner
    trades bit budget toward rank once latency is hidden.

    Deterministic: same inputs → same plan, on every worker.
    """
    hw = hw or HardwareModel.from_roofline()
    ranks = sorted(set(int(r) for r in ranks))
    assert ranks and ranks[0] >= 1, ranks

    leaves = _collect(shapes, specs)
    plan_shapes, unc_floats = [], 0
    for shape, spec in leaves:
        ms = matrixize.matrix_shape(shape, spec)
        if ms is None:
            plan_shapes.append(None)
            unc_floats += matrixize.uncompressed_floats(shape)
        else:
            batch_shape, n, m = ms
            plan_shapes.append((math.prod(batch_shape) if batch_shape else 1,
                                n, m))
    plan = matrixize.plan_buckets(plan_shapes, tolerance=tolerance)
    if bucket_residuals is not None:
        assert len(bucket_residuals) == len(plan.buckets), (
            len(bucket_residuals), len(plan.buckets))

    # per bucket: payload floats per rank unit (real leaf dims), wire floats
    # per rank unit (padded bucket dims), and the quality-proxy weight
    pay_unit = [sum(e.count * (e.n + e.m) for e in b.entries)
                for b in plan.buckets]
    wire_unit = [b.count * (b.n + b.m) for b in plan.buckets]
    elems = [sum(e.count * e.n * e.m for e in b.entries)
             for b in plan.buckets]
    min_nm = [min(b.n, b.m) for b in plan.buckets]
    # rank is only compression while r·(n+m) < n·m AND r ≤ min(n, m); cap
    # each bucket's candidate grid there (per its smallest member) so tiny
    # buckets never get ranks that cost more than sending them dense — and
    # never soak up budget the walk-down should leave to the big buckets
    rank_cap = [max(1, min(min(e.n, e.m, e.n * e.m // (e.n + e.m))
                           for e in b.entries))
                for b in plan.buckets]

    # --- greedy rank walk-down under the bits budget ----------------------
    def top_index(cap: int) -> int:
        """Largest candidate ≤ cap (index 0 if even ranks[0] exceeds it)."""
        return max([i for i, r in enumerate(ranks) if r <= cap] or [0])

    def payload_floats(cur) -> int:
        return sum(pay_unit[b] * ranks[i] for b, i in cur.items())

    def walk_down(budget_floats: int) -> dict:
        """Start every bucket at its top candidate rank and greedily shrink
        the best bits-saved-per-quality-loss bucket until the budget holds."""
        cur = {b: top_index(rank_cap[b]) for b in range(len(plan.buckets))}
        while payload_floats(cur) > budget_floats:
            best, best_score = None, None
            for b, i in cur.items():
                if i == 0:
                    continue
                saved = pay_unit[b] * (ranks[i] - ranks[i - 1])
                loss = (ranks[i] - ranks[i - 1]) / max(min_nm[b], 1) * elems[b]
                if bucket_residuals is not None:
                    # low measured residual ⇒ subspace over-covers ⇒ cheap cut
                    loss *= max(float(bucket_residuals[b]), 1e-3)
                score = saved / max(loss, 1e-12)
                if best_score is None or score > best_score:
                    best, best_score = b, score
            if best is None:
                break  # every bucket at min rank: budget is simply infeasible
            cur[best] -= 1
        return cur

    # --- joint (rank, wire) selection under ONE bits budget ---------------
    # Each wire candidate re-prices the budget (_WIRE_BUDGET_BITS): a
    # quantized wire affords 4×/8× the payload floats, so its walk-down
    # stops at higher ranks.  Keep the candidate that retains the most
    # payload floats (tracked directions are the quality currency); break
    # ties — float32 vs bfloat16 always tie, same budget — by the α-β
    # modeled exchange time, then by candidate order.
    n_unc_leaves = sum(1 for ps in plan_shapes if ps is None)
    best_cfg = best_cur = best_time = best_pay = None
    for wd in wire_dtypes:
        if wd not in matrixize.WIRE_DTYPES or wd == "auto":
            raise ValueError(
                f"wire_dtype candidate {wd!r} must be an explicit dtype "
                f"(one of {[d for d in matrixize.WIRE_DTYPES if d != 'auto']})")
        budget_floats = max(
            0, bits_budget // _WIRE_BUDGET_BITS[wd] - unc_floats)
        cur = walk_down(budget_floats)
        pay = payload_floats(cur)
        wire_floats = [wire_unit[b] * ranks[i] for b, i in cur.items()]
        quant = wd in matrixize.QUANT_WIRE_DTYPES
        # scale sidecar: one f32 per quantized slot — each bucket ships a P
        # and a Q slab per step, and every uncompressed leaf rides phase 1
        overhead = (matrixize.SCALE_BYTES
                    * (2 * len(plan.buckets) + n_unc_leaves) if quant else 0)
        for mcb in max_chunk_bytes_options:
            t = _phase_time(wire_floats, unc_floats, _WIRE_ITEMSIZE[wd],
                            workers, hw, mcb, overhead_bytes=overhead)
            # Pipelined (one-step-stale) schedules hide comm behind the
            # step's compute; price candidates by *exposed* time so the
            # tuner stops shrinking the wire once comm fits under compute
            # and spends the bit budget on rank instead.
            t = max(0.0, t - overlap_compute_s)
            if (best_pay is None or pay > best_pay
                    or (pay == best_pay and t < best_time)):
                best_cfg, best_cur, best_time, best_pay = (wd, mcb), cur, t, pay

    cur = best_cur
    decisions = tuple(
        BucketDecision(
            bucket=b, n=bk.n, m=bk.m, count=bk.count, rank=ranks[cur[b]],
            payload_floats=pay_unit[b] * ranks[cur[b]],
            wire_floats=wire_unit[b] * ranks[cur[b]])
        for b, bk in enumerate(plan.buckets))

    # per-leaf ranks, planner order (None = uncompressed leaf)
    leaf_ranks: List[Optional[int]] = []
    for i, ps in enumerate(plan_shapes):
        if ps is None:
            leaf_ranks.append(None)
        else:
            b_id, _ = plan.entry_for(i)
            leaf_ranks.append(decisions[b_id].rank)

    pay = sum(d.payload_floats for d in decisions)
    wd = best_cfg[0]
    wire_bits_per_step = int((pay + unc_floats) * _WIRE_ITEMSIZE[wd] * 8)
    if wd in matrixize.QUANT_WIRE_DTYPES:
        wire_bits_per_step += 8 * matrixize.SCALE_BYTES * (
            2 * len(plan.buckets) + n_unc_leaves)
    return TunePlan(
        decisions=decisions, wire_dtype=wd,
        max_chunk_bytes=best_cfg[1], tolerance=tolerance,
        payload_floats=pay, uncompressed_floats=unc_floats,
        bits_per_step=(pay + unc_floats) * 32,
        wire_bits_per_step=wire_bits_per_step,
        predicted_comm_s=best_time, workers=workers,
        leaf_ranks=tuple(leaf_ranks))


def apply_plan(plan: TunePlan, state, shapes, specs,
               key: jax.Array):
    """Install the plan's per-bucket ranks into a live compressor state via
    warm-start-preserving transitions (retained columns bit-exact).  The
    state must be unreplicated (no stacked worker dim); fresh columns are
    path-keyed, so every worker computes identical ones."""
    return powersgd.transition_state(state, plan.rank_tree(shapes, specs),
                                     key)


def make_tuned_compressor(plan: TunePlan, **kw):
    """A :class:`~repro.core.compressors.PowerSGDCompressor` carrying the
    plan's wire policy AND its ``bucket_pad_tolerance`` — the engine must
    re-derive the exact buckets the plan assigned ranks to, or two leaves
    the plan put in different buckets could land in one bucket with mixed
    ranks.  ``init`` seeds at the plan's *largest* rank; call
    :func:`apply_plan` on the fresh state to install the per-bucket ranks
    (or transition an existing warm state mid-run)."""
    from repro.core.compressors import PowerSGDCompressor

    rank = max((d.rank for d in plan.decisions), default=1)
    return PowerSGDCompressor(rank=rank, wire_dtype=plan.wire_dtype,
                              max_chunk_bytes=plan.max_chunk_bytes,
                              bucket_pad_tolerance=plan.tolerance, **kw)
