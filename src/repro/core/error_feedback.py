"""Distributed error-feedback SGD with post-compression momentum (Alg. 2).

    Δ_w   ← g_w + e_w                      (feedback)
    C(Δ)  ← compress+aggregate(Δ_1..Δ_W)   (the compressor's job)
    e_w   ← Δ_w − recon                    (memorize local error)
    Δ'    ← decompress(C(Δ))
    m     ← λ m + Δ'
    x     ← x − γ (Δ' + m)

``start_compress_step`` delays compression, as in the PyTorch DDP PowerSGD
hook: for the first k steps the deltas are aggregated *dense* (one fused
flat all-reduce through the transport engine) and the reconstruction is the
delta itself, so the error buffers stay exactly zero and the trajectory is
bit-identical to the identity compressor's.  Compression — and error
feedback — kick in at step k against gradients whose statistics have
stabilised, which is what makes warm-started low-rank compression safe at
the very start of training.

The error buffer ``e_w`` is per-worker state: in the distributed train step it
is carried with a leading data-parallel dim sharded over the data axes, so
each rank owns a distinct buffer.  This module itself is shape-agnostic — it
operates on whatever (local) tree it is given.  Under the in-process
W-worker simulator (:mod:`repro.core.simmesh`, ``make_sim_train_step``) the
same code runs per logical worker under ``vmap``: ``e_w`` carries a stacked
leading worker dim and the compressor's collectives become exact means over
it.  A worker dropped from a round (scenario weight 0) still updates its
error from its own ``Δ_w`` as usual (against the round's reconstruction:
the worker's own back-projection under ``error_mode="local"``, the
aggregated one under the default ``"global"``) — Algorithm 2's per-worker
state is local by construction, only the aggregation is weighted.

Weight decay follows the paper's recipe (§5): coupled, added to the gradient
*before* compression, and disabled for uncompressed (norm/bias) parameters.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import matrixize
from repro.core.compressors import Compressor
from repro.core.dist import MeshCtx, SINGLE


@dataclasses.dataclass
class EFState:
    """The optimizer's full cross-step state.

    Every field is *algorithm state* in the fault-tolerance sense — the
    trajectory is a function of all four, so a checkpoint that drops any of
    them does not resume the same algorithm: zeroed ``error`` discards the
    compression error Algorithm 1's EF loop was about to feed back, and a
    re-randomized ``comp`` restarts the warm-start power iteration from
    scratch (§3 ablation).  ``repro.checkpoint.train_state`` serializes the
    whole thing; the measured cost of dropping each piece is in
    ``docs/paper_map.md`` (resume design note).
    """

    error: Any        # per-worker error buffers e_w (tree like params)
    momentum: Any     # post-compression momentum m (tree like params)
    comp: Any         # compressor state (e.g. PowerSGD Q factors)
    step: jax.Array   # int32 step counter
    # One-step-stale pipeline only (``staleness="one_step"``): the aggregated
    # update Δ'_{t-1} produced at the previous step but not yet applied —
    # the in-flight half of the double-buffered schedule.  ``None`` under the
    # synchronous default, so existing 4-field constructions keep their exact
    # tree structure and numerics.
    inflight: Any = None


jax.tree_util.register_dataclass(
    EFState, data_fields=["error", "momentum", "comp", "step", "inflight"],
    meta_fields=[])


def init_state(compressor: Compressor, params, specs, key: jax.Array,
               *, staleness: str = "none") -> EFState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    shapes = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
    return EFState(
        error=zeros,
        momentum=jax.tree_util.tree_map(jnp.zeros_like, params),
        comp=compressor.init(shapes, specs, key),
        step=jnp.zeros((), jnp.int32),
        inflight=(jax.tree_util.tree_map(jnp.zeros_like, params)
                  if staleness == "one_step" else None),
    )


def rescale_path(w_old: int, w_new: int) -> str:
    """Which :func:`rescale_error_buffers` branch a ``w_old → w_new``
    rescale takes: ``"identity"`` / ``"grow"`` / ``"shrink"`` /
    ``"coprime-mean"``.  Pure — the checkpoint layer records it into the
    restore ``meta`` (``meta["ef_rescale"]``) so post-resume trajectory
    deltas are attributable to the rescale semantics actually applied."""
    if w_new == w_old:
        return "identity"
    if w_new % w_old == 0:
        return "grow"
    if w_old % w_new == 0:
        return "shrink"
    return "coprime-mean"


def rescale_error_buffers(error, workers: int):
    """Re-shard a stacked per-worker error-buffer tree to a new worker count.

    ``error`` carries a leading worker dim ``W_old`` on every leaf (the
    SimMesh stacked layout, or the distributed step's global
    ``(dp_total, ...)`` buffers pulled to host).  The elastic-resume
    contract is about the quantity Algorithm 2 actually aggregates — the
    *worker-mean* of ``Δ_w = g_w + e_w`` — so the rescale preserves the
    worker-mean of the buffers (Lemma 3's linearity then carries the
    trajectory):

    * ``W_new == W_old`` — identity, bit-exact.
    * ``W_new % W_old == 0`` (grow, e.g. 1→4): each original buffer is
      duplicated to its ``W_new/W_old`` successor workers.  Every new
      buffer equals an original bit-exactly, and the worker-mean is the
      original multiset mean unchanged.
    * ``W_old % W_new == 0`` (shrink, e.g. 4→1): each new buffer is the
      mean of the ``W_old/W_new`` buffers it absorbs — the global mean is
      preserved up to one float32 reassociation.
    * otherwise: every new buffer is the global worker-mean (the documented
      fallback for coprime rescales).

    Only the *mean* is an invariant: per-worker identity is necessarily
    lost when W changes, so a rescaled resume is trajectory-preserving in
    the Lemma-3 sense, not bit-exact (``tests/sim/test_resume.py`` pins
    both sides of that line).
    """
    leaves = jax.tree_util.tree_leaves(error)
    if not leaves:
        return error
    w_old = leaves[0].shape[0]
    for l in leaves:
        assert l.shape[0] == w_old, (l.shape, w_old)
    path = rescale_path(w_old, workers)
    if path == "identity":
        return error
    if path == "coprime-mean":
        warnings.warn(
            f"coprime EF rescale {w_old} -> {workers}: every new buffer is "
            f"the global worker-mean (per-worker identity lost; mean "
            f"preserved)", stacklevel=2)

    def leaf(e):
        if path == "grow":
            return jnp.repeat(e, workers // w_old, axis=0)
        if path == "shrink":
            k = w_old // workers
            return jnp.mean(e.reshape((workers, k) + e.shape[1:]), axis=1)
        mean = jnp.mean(e, axis=0, keepdims=True)
        return jnp.broadcast_to(mean, (workers,) + e.shape[1:])

    return jax.tree_util.tree_map(leaf, error)


def replace_comp(state: EFState, comp) -> EFState:
    """``state`` with a new compressor state — the rank-transition hook.

    A :class:`~repro.core.powersgd.RankSchedule` switch replaces only the
    warm-start factors; error buffers, momentum and the step counter pass
    through bit-exactly (``tests/sim/test_rank_transitions.py`` pins this)."""
    return EFState(error=state.error, momentum=state.momentum, comp=comp,
                   step=state.step, inflight=state.inflight)


def apply_updates(
    compressor: Compressor,
    params,
    grads,                      # per-worker local gradients g_w
    state: EFState,
    specs,
    *,
    lr,                         # scalar or traced schedule value
    momentum: float = 0.9,
    weight_decay: float = 0.0,
    ctx: MeshCtx = SINGLE,
    key: Optional[jax.Array] = None,
    use_pallas_apply: bool = False,
    start_compress_step: int = 0,
    staleness: str = "none",
):
    """One EF-SGD step.  Returns (new_params, new_state, aux).

    ``start_compress_step=k`` aggregates the first k steps dense (see module
    docstring); with the default 0 every step compresses.

    ``staleness="one_step"`` turns on the delayed-parameter-update pipeline
    (the DPU/ACCO pattern): the update *applied* at step t is the aggregate
    Δ'_{t-1} carried in ``state.inflight``, and this step's fresh aggregate
    Δ'_t is parked as the next ``inflight`` — so the fused collectives that
    produce Δ'_t never sit between the gradient computation and the
    parameter write of the same step.  The error buffers are untouched by
    the delay: ``e_w = Δ_w − recon_t`` memorizes exactly what step t's
    compression dropped, regardless of *when* the aggregate is applied, so
    Alg. 2's EF guarantee absorbs the one-step shift like any other bounded
    perturbation.  Step 0 applies the zero aggregate (the pipeline bubble).
    ``state.inflight`` must be a params-shaped tree (see :func:`init_state`).
    """
    if staleness not in ("none", "one_step"):
        raise ValueError(f"unknown staleness mode {staleness!r}")
    if staleness == "one_step" and state.inflight is None:
        raise ValueError(
            "staleness='one_step' needs EFState.inflight initialized "
            "(init_state(..., staleness='one_step'))")
    if key is not None:
        key = jax.random.fold_in(key, state.step)

    if weight_decay:
        def add_wd(g, p, spec):
            return g + weight_decay * p if spec.is_compressed() else g
        grads = jax.tree_util.tree_map(add_wd, grads, params, specs)

    # Δ_w = g_w + e_w
    deltas = jax.tree_util.tree_map(jnp.add, grads, state.error)

    if start_compress_step:
        out = _warmup_or_compress(compressor, deltas, state.comp, specs,
                                  ctx, key, state.step, start_compress_step)
    else:
        out = compressor.step(deltas, state.comp, specs, ctx=ctx, key=key)

    # e_w = Δ_w − recon
    new_error = jax.tree_util.tree_map(jnp.subtract, deltas, out.recon)

    # Synchronous: apply this step's aggregate.  One-step-stale: apply the
    # in-flight aggregate from step t−1 and park this step's for step t+1.
    if staleness == "one_step":
        applied, new_inflight = state.inflight, out.agg
    else:
        applied, new_inflight = out.agg, state.inflight

    if use_pallas_apply:
        from repro.kernels import ops

        new_params, new_momentum = ops.ef_apply_tree(
            params, applied, state.momentum, lr=lr, momentum=momentum)
    else:
        # m ← λ m + Δ' ;  x ← x − γ (Δ' + m)
        new_momentum = jax.tree_util.tree_map(
            lambda m, d: momentum * m + d, state.momentum, applied)
        new_params = jax.tree_util.tree_map(
            lambda x, d, m: x - lr * (d + m), params, applied, new_momentum)

    new_state = EFState(
        error=new_error,
        momentum=new_momentum,
        comp=out.state,
        step=state.step + 1,
        inflight=new_inflight,
    )
    aux = {"bits_per_worker": out.bits_per_worker}
    if getattr(out, "metrics", None):
        # compressor observability (e.g. PowerSGD residual-energy ratios
        # when track_residual is on) — host-side RankControllers read these
        aux.update(out.metrics)
    return new_params, new_state, aux


def _warmup_or_compress(compressor, deltas, comp_state, specs, ctx, key,
                        step, k):
    """Dense fused all-reduce for ``step < k``, the compressor afterwards.

    Both branches run under ``lax.cond`` (a jittable, traced-step-compatible
    switch), so the compressor's state must pass through the dense branch
    unchanged — which it does by construction: warm-start factors only start
    evolving once compression starts.  The dense reconstruction is the delta
    itself, keeping the error buffers exactly zero through the warmup.

    Note for :class:`~repro.core.dist.CollectiveStats` users: recording is
    trace-time, and ``cond`` traces both branches, so a warmup-enabled step
    records the dense collective *and* the compressor's — gate on
    ``start_compress_step=0`` when asserting collective budgets.
    """
    from repro.core.engine import CompressOut

    wire_dtype = getattr(compressor, "wire_dtype", "auto")
    max_chunk = getattr(compressor, "max_chunk_bytes", None)
    dense_bits = sum(matrixize.uncompressed_floats(g.shape) * 32
                     for g in jax.tree_util.tree_leaves(deltas))
    comp_bits = [dense_bits]

    def dense(args):
        deltas, comp_state = args
        leaves, treedef = jax.tree_util.tree_flatten(deltas)
        agg = jax.tree_util.tree_unflatten(
            treedef, ctx.pmean_flat(leaves, wire_dtype=wire_dtype,
                                    max_chunk_bytes=max_chunk))
        return agg, deltas, comp_state

    def compress(args):
        deltas, comp_state = args
        out = compressor.step(deltas, comp_state, specs, ctx=ctx, key=key)
        comp_bits[0] = out.bits_per_worker  # captured at trace time
        return out.agg, out.recon, out.state

    agg, recon, new_comp = lax.cond(
        step < k, dense, compress, (deltas, comp_state))
    bits = jnp.where(step < k, dense_bits, comp_bits[0])
    return CompressOut(agg=agg, recon=recon, state=new_comp,
                       bits_per_worker=bits)
