"""Orthogonalization of tall-skinny matrices (the P factor in PowerSGD).

Three implementations:

* ``gram_schmidt`` — the paper's choice (Alg. 1 line 5), hardened for
  replica determinism: scale-invariant (per-column max-abs prescale, so the
  guard needs no absolute epsilon and ~1e-20 early-training gradients
  normalize exactly like O(1) ones) and ULP-guarded (a residual column whose
  norm falls below the dtype's post-projection rounding floor is numerically
  rank-deficient — pure noise — and becomes an *exact zero* column instead
  of normalized noise).  That floor is what stops ULP-level input
  differences across data ranks from being amplified into O(1) factor
  divergence: normalizing a noise-dominated residual is a divide-by-ULP.
* ``cholesky_qr`` — TPU adaptation (beyond-paper): ``R = chol(PᵀP + εI)``,
  ``P̂ = P R⁻ᵀ``.  Two tall-skinny matmuls that map onto the MXU instead of a
  sequential column loop.  Numerically adequate because r ≤ 32 here and we
  regularise the Gram matrix.
* ``gs_cholqr`` — ``gram_schmidt`` with a per-matrix CholeskyQR2 stability
  fallback: when the Gram-Schmidt output's Gram matrix is not a projector
  to within a dtype-ULP budget (ill-conditioned P where sequential MGS
  loses orthogonality as κ·ulp), that batch element is replaced by the
  CholeskyQR2 result.

Both operate on arrays of shape ``(..., n, r)`` and are *batched*: leading
dims (layer-stacked / expert-stacked parameters, or the ``(B, n, r)`` slabs
of the bucketed compression engine) are handled in one call — Gram-Schmidt
runs its column loop once for the whole stack, Cholesky-QR batches the r×r
factorizations.  Zero-padded rows (bucket padding) are exact no-ops: they
contribute nothing to any column inner product, so the orthogonalization of
a padded stack equals the per-matrix orthogonalization of its members.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

_EPS = 1e-8


def gram_schmidt(p: jax.Array, eps: float = _EPS) -> jax.Array:
    """Modified Gram-Schmidt over the last axis' columns.  Shape (..., n, r).

    Scale-invariant: each column is prescaled by its max-abs entry (exactly
    invariant under power-of-two rescaling of the input), so a nonzero
    column enters the loop with norm in [1, √n] and every guard threshold
    can be stated in dtype ULPs rather than as an absolute epsilon.  A
    residual column whose squared norm falls below the post-projection
    rounding floor ``n·(32·ulp)²`` is numerically rank-deficient and is
    zeroed exactly — never normalized — so near-dependent and all-zero
    columns produce exact-zero output columns instead of NaN or amplified
    noise.  ``eps`` is retained for signature compatibility and unused.
    """
    del eps  # the guard scales with dtype ULP, not an absolute epsilon
    n, r = p.shape[-2], p.shape[-1]
    ulp = float(jnp.finfo(p.dtype).eps)
    floor = n * (32.0 * ulp) ** 2

    scale = jnp.max(jnp.abs(p), axis=-2, keepdims=True)            # (..., 1, r)
    m = p / jnp.where(scale > 0, scale, jnp.ones_like(scale))

    def body(i, m):
        col = lax.dynamic_slice_in_dim(m, i, 1, axis=-1)          # (..., n, 1)
        nrm2 = jnp.sum(col * col, axis=-2, keepdims=True)
        inv = jnp.where(nrm2 > floor,
                        lax.rsqrt(jnp.maximum(nrm2, floor)),
                        jnp.zeros_like(nrm2))
        col = col * inv
        # remove the projection of the remaining columns on `col`
        proj = jnp.sum(col * m, axis=-2, keepdims=True)            # (..., 1, r)
        # only update columns j > i; column i itself becomes the normalised col
        col_ids = lax.broadcasted_iota(jnp.int32, (r,), 0)
        later = (col_ids > i).astype(m.dtype)                      # (r,)
        m = m - col * (proj * later)
        m = lax.dynamic_update_slice_in_dim(m, col, i, axis=-1)
        return m

    return lax.fori_loop(0, r, body, m)


def _cholesky_qr_once(p: jax.Array, eps: float) -> jax.Array:
    r = p.shape[-1]
    gram = jnp.einsum("...nr,...ns->...rs", p, p)
    # Scale-aware jitter keeps the factorisation safe for tiny gradients AND
    # for near-rank-deficient P (warm-started P collapses toward the top
    # singular directions whenever the gradient rank is below r, so this is
    # the common converged case, not a corner).  The shift must dominate the
    # dtype's rounding noise in the Gram entries — O(ulp·‖G‖) — or the
    # factorisation goes NaN on numerically indefinite inputs; directions the
    # shift swamps come back orthonormal through the second pass (CholeskyQR2)
    # or stay harmlessly near zero when truly dependent.
    scale = jnp.trace(gram, axis1=-2, axis2=-1)[..., None, None] / r
    ulp = jnp.finfo(p.dtype).eps
    gram = gram + (eps + 64.0 * ulp * scale) * jnp.eye(r, dtype=p.dtype)
    chol = jnp.linalg.cholesky(gram)
    # solve P̂ Lᵀ = P  ⇒  P̂ = P L⁻ᵀ
    return lax.linalg.triangular_solve(
        chol, p, left_side=False, lower=True, transpose_a=True
    )


def cholesky_qr(p: jax.Array, eps: float = _EPS) -> jax.Array:
    """CholeskyQR2: MXU-friendly (two matmul passes + r×r chols).

    A single CholeskyQR pass loses orthogonality as κ²(P)·ε — visibly so in
    fp32 for ill-conditioned P (e.g. square gaussian blocks).  Repeating the
    factorisation on its own output (CholeskyQR2, Yamamoto et al. 2015)
    squares the residual, restoring orthonormality at the cost of one more
    tall-skinny matmul — still MXU-native, unlike sequential Gram-Schmidt."""
    return _cholesky_qr_once(_cholesky_qr_once(p, eps), eps)


def gs_cholqr(p: jax.Array, eps: float = _EPS) -> jax.Array:
    """``gram_schmidt`` with a per-matrix CholeskyQR2 stability fallback.

    Accepts the Gram-Schmidt result when its Gram matrix ``G = QᵀQ`` is a
    projector (``‖G² − G‖_max`` within a dtype-ULP budget — this treats
    exact-zero columns from rank-deficient input as valid, where a plain
    ``‖G − I‖`` check would not); otherwise that batch element falls back
    to CholeskyQR2.  Both candidates are computed (the select is per batch
    element under jit), so this costs one extra orthogonalization pass —
    use it when P may be ill-conditioned enough for sequential MGS to lose
    orthogonality, not as the default.
    """
    q = gram_schmidt(p)
    gram = jnp.einsum("...nr,...ns->...rs", q, q)
    resid = jnp.einsum("...rs,...st->...rt", gram, gram) - gram
    err = jnp.max(jnp.abs(resid), axis=(-2, -1))                   # (...,)
    tol = 1024.0 * float(jnp.finfo(p.dtype).eps)
    return jnp.where((err <= tol)[..., None, None], q, cholesky_qr(p, eps))


ORTHOGONALIZERS = {
    "gram_schmidt": gram_schmidt,
    "cholesky_qr": cholesky_qr,
    "gs_cholqr": gs_cholqr,
}


def get_orthogonalizer(name: str):
    try:
        return ORTHOGONALIZERS[name]
    except KeyError:
        raise ValueError(
            f"unknown orthogonalizer {name!r}; available: {sorted(ORTHOGONALIZERS)}"
        ) from None
