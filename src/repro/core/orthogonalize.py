"""Orthogonalization of tall-skinny matrices (the P factor in PowerSGD).

Two implementations:

* ``gram_schmidt`` — the paper's choice (Alg. 1 line 5).  Sequential over the
  r columns; faithful reproduction.
* ``cholesky_qr`` — TPU adaptation (beyond-paper): ``R = chol(PᵀP + εI)``,
  ``P̂ = P R⁻ᵀ``.  Two tall-skinny matmuls that map onto the MXU instead of a
  sequential column loop.  Numerically adequate because r ≤ 32 here and we
  regularise the Gram matrix.

Both operate on arrays of shape ``(..., n, r)`` and are *batched*: leading
dims (layer-stacked / expert-stacked parameters, or the ``(B, n, r)`` slabs
of the bucketed compression engine) are handled in one call — Gram-Schmidt
runs its column loop once for the whole stack, Cholesky-QR batches the r×r
factorizations.  Zero-padded rows (bucket padding) are exact no-ops: they
contribute nothing to any column inner product, so the orthogonalization of
a padded stack equals the per-matrix orthogonalization of its members.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

_EPS = 1e-8


def gram_schmidt(p: jax.Array, eps: float = _EPS) -> jax.Array:
    """Modified Gram-Schmidt over the last axis' columns.  Shape (..., n, r)."""
    r = p.shape[-1]

    def body(i, m):
        col = lax.dynamic_slice_in_dim(m, i, 1, axis=-1)          # (..., n, 1)
        col = col * lax.rsqrt(jnp.sum(col * col, axis=-2, keepdims=True) + eps)
        # remove the projection of the remaining columns on `col`
        proj = jnp.sum(col * m, axis=-2, keepdims=True)            # (..., 1, r)
        # only update columns j > i; column i itself becomes the normalised col
        col_ids = lax.broadcasted_iota(jnp.int32, (r,), 0)
        later = (col_ids > i).astype(m.dtype)                      # (r,)
        m = m - col * (proj * later)
        m = lax.dynamic_update_slice_in_dim(m, col, i, axis=-1)
        return m

    return lax.fori_loop(0, r, body, p)


def _cholesky_qr_once(p: jax.Array, eps: float) -> jax.Array:
    r = p.shape[-1]
    gram = jnp.einsum("...nr,...ns->...rs", p, p)
    # Scale-aware jitter keeps the factorisation safe for tiny gradients AND
    # for near-rank-deficient P (warm-started P collapses toward the top
    # singular directions whenever the gradient rank is below r, so this is
    # the common converged case, not a corner).  The shift must dominate the
    # dtype's rounding noise in the Gram entries — O(ulp·‖G‖) — or the
    # factorisation goes NaN on numerically indefinite inputs; directions the
    # shift swamps come back orthonormal through the second pass (CholeskyQR2)
    # or stay harmlessly near zero when truly dependent.
    scale = jnp.trace(gram, axis1=-2, axis2=-1)[..., None, None] / r
    ulp = jnp.finfo(p.dtype).eps
    gram = gram + (eps + 64.0 * ulp * scale) * jnp.eye(r, dtype=p.dtype)
    chol = jnp.linalg.cholesky(gram)
    # solve P̂ Lᵀ = P  ⇒  P̂ = P L⁻ᵀ
    return lax.linalg.triangular_solve(
        chol, p, left_side=False, lower=True, transpose_a=True
    )


def cholesky_qr(p: jax.Array, eps: float = _EPS) -> jax.Array:
    """CholeskyQR2: MXU-friendly (two matmul passes + r×r chols).

    A single CholeskyQR pass loses orthogonality as κ²(P)·ε — visibly so in
    fp32 for ill-conditioned P (e.g. square gaussian blocks).  Repeating the
    factorisation on its own output (CholeskyQR2, Yamamoto et al. 2015)
    squares the residual, restoring orthonormality at the cost of one more
    tall-skinny matmul — still MXU-native, unlike sequential Gram-Schmidt."""
    return _cholesky_qr_once(_cholesky_qr_once(p, eps), eps)


ORTHOGONALIZERS = {
    "gram_schmidt": gram_schmidt,
    "cholesky_qr": cholesky_qr,
}


def get_orthogonalizer(name: str):
    try:
        return ORTHOGONALIZERS[name]
    except KeyError:
        raise ValueError(
            f"unknown orthogonalizer {name!r}; available: {sorted(ORTHOGONALIZERS)}"
        ) from None
