"""Mesh/axis context threaded through the whole framework.

All model and compressor code is written against :class:`MeshCtx` instead of
hard-coding ``lax.psum(..., axis_name=...)`` calls.  Outside of a
``shard_map`` (single-device smoke tests, benchmarks) the context has no axis
names and every collective degenerates to the identity, so the *same* code
path runs on one CPU device and on a 512-chip mesh.

Collective dispatch
-------------------
``MeshCtx`` does not issue ``lax`` collectives directly; every collective
goes through a :class:`CollectiveBackend`.  Two backends exist:

* :data:`AXIS` (:class:`AxisBackend`) — the production backend: delegates to
  the ``lax`` named-axis collectives, which resolve against the enclosing
  ``shard_map`` (or ``vmap``) axis environment.  This is the default and is
  behaviourally identical to the pre-backend code.
* :class:`SimBackend` — the in-process W-worker simulation backend used by
  :class:`repro.core.simmesh.SimMesh`.  The worker axis is a ``jax.vmap``
  axis carried as a stacked leading dimension through the whole step, so
  collectives lower to *exact* sums/means over that stacked axis on a single
  device — no XLA collectives, bit-deterministic, and byte-for-byte the same
  compressor code path as production.  It additionally supports per-worker
  *weights* (heterogeneous batch sizes, worker dropout, stragglers): with a
  weight ``w_i`` attached, ``pmean`` becomes ``Σ w_i x_i / Σ w_i`` and
  ``psum`` becomes ``Σ w_i x_i``.

``CollectiveStats`` recording and ``pmean_flat`` fusion live in ``MeshCtx``
itself and therefore work unchanged under either backend.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(eq=False)
class CollectiveStats:
    """Trace-time counter of *data-axis* collectives.

    Attach one to a :class:`MeshCtx` (``MeshCtx(..., stats=CollectiveStats())``)
    and every ``psum_data`` / ``pmean_data`` / ``pmean_flat`` call records the
    logical collective it issues — the count a real mesh would see.  Recording
    happens at Python trace time, so counts are exact for an eagerly executed
    step and count one trace for a jitted one.  Collectives that degenerate to
    the identity (empty ``data_axes``) are still recorded: the *would-be*
    communication pattern is what the benchmarks compare.
    """

    data_collectives: int = 0
    data_floats: int = 0
    sizes: List[int] = dataclasses.field(default_factory=list)
    itemsizes: List[int] = dataclasses.field(default_factory=list)

    def record(self, n_elems: int, itemsize: int = 4) -> None:
        self.data_collectives += 1
        self.data_floats += int(n_elems)
        self.sizes.append(int(n_elems))
        self.itemsizes.append(int(itemsize))

    def reset(self) -> None:
        self.data_collectives = 0
        self.data_floats = 0
        self.sizes.clear()
        self.itemsizes.clear()

    def bytes_per_collective(self) -> List[int]:
        """Wire bytes per collective, using each buffer's recorded dtype."""
        return [s * i for s, i in zip(self.sizes, self.itemsizes)]


# ---------------------------------------------------------------------------
# collective backends
# ---------------------------------------------------------------------------

class CollectiveBackend:
    """The primitive collectives :class:`MeshCtx` dispatches through.

    ``axes`` arguments are tuples of axis names (or a single name for the
    single-axis collectives) that are guaranteed non-empty by the caller —
    ``MeshCtx`` short-circuits empty axis sets to the identity before
    dispatching.
    """

    def psum(self, x, axes):
        raise NotImplementedError

    def pmean(self, x, axes):
        raise NotImplementedError

    def pmax(self, x, axes):
        raise NotImplementedError

    def all_gather(self, x, axis, *, gather_axis: int, tiled: bool):
        raise NotImplementedError

    def ppermute(self, x, axis, perm):
        raise NotImplementedError

    def all_to_all(self, x, axis, *, split_axis: int, concat_axis: int):
        raise NotImplementedError

    def axis_size(self, axes) -> int:
        raise NotImplementedError

    def axis_index(self, axis):
        raise NotImplementedError


class AxisBackend(CollectiveBackend):
    """Named-axis collectives against the enclosing shard_map/vmap env."""

    def psum(self, x, axes):
        return lax.psum(x, axes)

    def pmean(self, x, axes):
        return lax.pmean(x, axes)

    def pmax(self, x, axes):
        return lax.pmax(x, axes)

    def all_gather(self, x, axis, *, gather_axis: int, tiled: bool):
        return lax.all_gather(x, axis, axis=gather_axis, tiled=tiled)

    def ppermute(self, x, axis, perm):
        return lax.ppermute(x, axis, perm)

    def all_to_all(self, x, axis, *, split_axis: int, concat_axis: int):
        return lax.all_to_all(x, axis, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)

    def axis_size(self, axes) -> int:
        n = 1
        for a in (axes if isinstance(axes, (tuple, list)) else (axes,)):
            n *= lax.axis_size(a)
        return n

    def axis_index(self, axis):
        return lax.axis_index(axis)


AXIS = AxisBackend()  # stateless — one shared instance


@dataclasses.dataclass(frozen=True, eq=False)
class SimBackend(AxisBackend):
    """W-logical-worker simulation backend (see :mod:`repro.core.simmesh`).

    Must run inside ``jax.vmap(..., axis_name=self.axis)`` over the stacked
    worker dimension; the named-axis collectives then lower to exact
    reductions over that stacked axis on one device.

    ``weight`` (optional) is this worker's scalar contribution weight — a
    traced value under ``vmap``, one scalar per worker.  It models
    heterogeneous per-worker batch sizes (weight ∝ local token count),
    worker dropout and straggler-skipped rounds (weight 0 for the affected
    round).  Weighted ``pmean`` is ``Σ w_i x_i / Σ w_i``; if every worker is
    dropped the aggregate degenerates to exactly zero (the denominator is
    guarded), i.e. the round becomes a no-op on the aggregated update.
    Weights apply to ``psum``/``pmean`` only — in simulation the context has
    no model/seq axes, so those are the data-parallel collectives.
    """

    axis: str
    size: int
    weight: Optional[jax.Array] = None

    def psum(self, x, axes):
        if self.weight is not None:
            x = x * self.weight.astype(x.dtype)
        return lax.psum(x, axes)

    def pmean(self, x, axes):
        if self.weight is None:
            return lax.pmean(x, axes)
        w = self.weight
        total = lax.psum(w, axes)
        numer = lax.psum(x * w.astype(x.dtype), axes)
        # divide in the weight dtype (f32): finfo.tiny would underflow to 0
        # if cast to a low-precision wire dtype, turning the all-dropped
        # round into 0/0 = NaN instead of the documented exact zero
        denom = jnp.maximum(total, jnp.finfo(total.dtype).tiny)
        return (numer.astype(total.dtype) / denom).astype(x.dtype)

    def axis_size(self, axes) -> int:
        n = 1
        for a in (axes if isinstance(axes, (tuple, list)) else (axes,)):
            n *= self.size if a == self.axis else lax.axis_size(a)
        return n


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    """Names of the mesh axes the current computation is mapped over.

    data_axes:  axes that carry data parallelism (gradient all-reduce),
                e.g. ``("pod", "data")`` or ``("data",)``.
    model_axis: axis carrying tensor/expert parallelism, e.g. ``"model"``.
    seq_axes:   axes over which a decode KV cache is sequence-sharded
                (flash-decode softmax merge): ``("model",)`` for decode_32k,
                ``("pod", "data", "model")`` for long_500k (batch=1).
    stats:      optional :class:`CollectiveStats` that records every data-axis
                collective issued through this context (excluded from eq/hash;
                purely observational).
    backend:    :class:`CollectiveBackend` the collectives dispatch through —
                :data:`AXIS` (production shard_map) by default, or a
                :class:`SimBackend` inside a :class:`~repro.core.simmesh.
                SimMesh` step (excluded from eq/hash: a ``SimBackend`` may
                hold traced per-worker weights).
    """

    data_axes: Tuple[str, ...] = ()
    model_axis: Optional[str] = None
    seq_axes: Tuple[str, ...] = ()
    stats: Optional[CollectiveStats] = dataclasses.field(
        default=None, compare=False)
    backend: CollectiveBackend = dataclasses.field(
        default=AXIS, compare=False)

    def _record_data(self, x) -> None:
        if self.stats is not None:
            self.stats.record(x.size, jnp.dtype(x.dtype).itemsize)

    # -- data-parallel collectives (gradient aggregation) ------------------
    def psum_data(self, x):
        self._record_data(x)
        return self.backend.psum(x, self.data_axes) if self.data_axes else x

    def pmean_data(self, x):
        self._record_data(x)
        return self.backend.pmean(x, self.data_axes) if self.data_axes else x

    def pmean_flat(self, parts: Sequence[jax.Array]) -> List[jax.Array]:
        """Fused all-reduce-mean: ONE collective for a whole list of arrays.

        Ravels every part, concatenates them into a single contiguous buffer
        (in a common wire dtype), issues a single ``pmean`` over the data
        axes, then splits the buffer back into the original shapes/dtypes.
        Because ``pmean`` is elementwise, this is numerically identical to
        per-part ``pmean_data`` calls (up to the wire-dtype cast) while
        replacing N latency-bound collectives with one bandwidth-bound one —
        the communication model of the bucketed PowerSGD engine.
        """
        parts = list(parts)
        if not parts:
            return []
        wire = jnp.result_type(*parts)
        flats = [jnp.ravel(p).astype(wire) for p in parts]
        buf = flats[0] if len(flats) == 1 else jnp.concatenate(flats)
        self._record_data(buf)
        if self.data_axes:
            buf = self.backend.pmean(buf, self.data_axes)
        out, off = [], 0
        for p in parts:
            out.append(
                lax.slice_in_dim(buf, off, off + p.size, axis=0)
                .reshape(p.shape).astype(p.dtype))
            off += p.size
        return out

    # -- model-parallel collectives (tensor parallelism) --------------------
    def psum_model(self, x):
        return self.backend.psum(x, self.model_axis) if self.model_axis else x

    def pmean_model(self, x):
        return self.backend.pmean(x, self.model_axis) if self.model_axis else x

    def pmax_model(self, x):
        return self.backend.pmax(x, self.model_axis) if self.model_axis else x

    def all_gather_model(self, x, axis: int = -1, tiled: bool = True):
        if self.model_axis is None:
            return x
        return self.backend.all_gather(x, self.model_axis, gather_axis=axis,
                                       tiled=tiled)

    def ppermute_model(self, x, perm):
        if self.model_axis is None:
            return x
        return self.backend.ppermute(x, self.model_axis, perm)

    def all_to_all_model(self, x, split_axis: int, concat_axis: int):
        """Re-distribute: split ``split_axis`` over the model axis, gather
        ``concat_axis`` (e.g. column-sharded → row-sharded activations)."""
        if self.model_axis is None:
            return x
        return self.backend.all_to_all(x, self.model_axis,
                                       split_axis=split_axis,
                                       concat_axis=concat_axis)

    # -- sequence-shard collectives (flash-decode merge) ---------------------
    def psum_seq(self, x):
        return self.backend.psum(x, self.seq_axes) if self.seq_axes else x

    def pmax_seq(self, x):
        return self.backend.pmax(x, self.seq_axes) if self.seq_axes else x

    # -- sizes / indices ----------------------------------------------------
    def data_size(self) -> int:
        return self.backend.axis_size(self.data_axes) if self.data_axes else 1

    def model_size(self) -> int:
        return self.backend.axis_size(self.model_axis) if self.model_axis else 1

    def seq_size(self) -> int:
        return self.backend.axis_size(self.seq_axes) if self.seq_axes else 1

    def model_index(self):
        if self.model_axis is None:
            return 0
        return self.backend.axis_index(self.model_axis)

    def seq_index(self):
        """Linearised index over the seq axes (row-major)."""
        if not self.seq_axes:
            return 0
        idx = 0
        for a in self.seq_axes:
            idx = idx * self.backend.axis_size((a,)) + self.backend.axis_index(a)
        return idx

    def data_index(self):
        """Linearised index over the data axes (row-major)."""
        if not self.data_axes:
            return 0
        idx = 0
        for a in self.data_axes:
            idx = idx * self.backend.axis_size((a,)) + self.backend.axis_index(a)
        return idx


SINGLE = MeshCtx()  # single-device context: all collectives are identities
