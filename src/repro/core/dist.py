"""Mesh/axis context threaded through the whole framework.

All model and compressor code is written against :class:`MeshCtx` instead of
hard-coding ``lax.psum(..., axis_name=...)`` calls.  Outside of a
``shard_map`` (single-device smoke tests, benchmarks) the context has no axis
names and every collective degenerates to the identity, so the *same* code
path runs on one CPU device and on a 512-chip mesh.

Collective dispatch
-------------------
``MeshCtx`` does not issue ``lax`` collectives directly; every collective
goes through a :class:`CollectiveBackend`.  Two backends exist:

* :data:`AXIS` (:class:`AxisBackend`) — the production backend: delegates to
  the ``lax`` named-axis collectives, which resolve against the enclosing
  ``shard_map`` (or ``vmap``) axis environment.  This is the default and is
  behaviourally identical to the pre-backend code.
* :class:`SimBackend` — the in-process W-worker simulation backend used by
  :class:`repro.core.simmesh.SimMesh`.  The worker axis is a ``jax.vmap``
  axis carried as a stacked leading dimension through the whole step, so
  collectives lower to *exact* sums/means over that stacked axis on a single
  device — no XLA collectives, bit-deterministic, and byte-for-byte the same
  compressor code path as production.  It additionally supports per-worker
  *weights* (heterogeneous batch sizes, worker dropout, stragglers): with a
  weight ``w_i`` attached, ``pmean`` becomes ``Σ w_i x_i / Σ w_i`` and
  ``psum`` becomes ``Σ w_i x_i``.

``CollectiveStats`` recording and ``pmean_flat`` fusion live in ``MeshCtx``
itself and therefore work unchanged under either backend.

Which collective carries which payload
--------------------------------------
The transport engine (:mod:`repro.core.engine`, see its worked TopK
example) maps every compressor's wire traffic onto exactly three ``MeshCtx``
entry points:

* :meth:`MeshCtx.pmean_flat` — the fused all-reduce.  Carries every
  *linear* payload (PowerSGD's P and Q factor slabs — one call per
  power-iteration phase — identity/random-k/random-block values, the
  ``exact_rank_k`` oracle's dense gradient) and ALL uncompressed
  bias/norm leaves, which ride the first reduce of the step whatever the
  scheme.  One ``pmean`` per wire chunk; bytes flat in W.
* :meth:`MeshCtx.allgather_flat` — the fused all-gather.  Carries
  *non-linear* payloads (sign_norm's int8 signs + f32 norms, top_k's f32
  values + i32 indices, spectral_atomo's (P, V) triplets); every part
  returns with a leading worker dim of ``data_size()`` and is decoded
  per worker.  Bytes scale with W (``CollectiveStats`` fanout).
* :meth:`MeshCtx.gather_data_weight` — the scenario side channel: the
  per-worker contribution weights a gather-pattern combine needs on the
  receiver (one tiny all-gather, only under a weighted ``SimBackend``).

``pmean_data``/``psum_data`` remain the unfused per-tensor path (the
``transport="per_leaf"`` / ``bucketing="off"`` reference engines).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _psum_identity_bwd(x, axes):
    """``lax.psum`` forward, *identity* backward (Megatron's *f* operator).

    ``lax.psum``'s own transpose is ``psum`` — the right adjoint when every
    rank's output is a distinct loss contribution, but a ×W overcount under
    this codebase's convention that the loss is *replicated* over the model
    axis (every rank redundantly computes the same scalar).  A row-parallel
    output reduce must then pass the (already-full, replicated) cotangent
    straight through; the matching backward ``psum`` lives at the
    replicated→sharded *entry* instead (:func:`repro.models.common.
    grad_synced`)."""
    return lax.psum(x, axes)


def _psum_identity_bwd_fwd(x, axes):
    return lax.psum(x, axes), None


def _psum_identity_bwd_bwd(axes, _, ct):
    return (ct,)


_psum_identity_bwd.defvjp(_psum_identity_bwd_fwd, _psum_identity_bwd_bwd)


@dataclasses.dataclass(eq=False)
class CollectiveStats:
    """Trace-time counter of *data-axis* collectives.

    Attach one to a :class:`MeshCtx` (``MeshCtx(..., stats=CollectiveStats())``)
    and every ``psum_data`` / ``pmean_data`` / ``pmean_flat`` /
    ``allgather_flat`` call records the logical collective it issues — the
    count a real mesh would see.  Recording happens at Python trace time, so
    counts are exact for an eagerly executed step and count one trace for a
    jitted one.  Collectives that degenerate to the identity (empty
    ``data_axes``) are still recorded: the *would-be* communication pattern is
    what the benchmarks compare.

    Each record carries its transport ``kind``:

    * ``"reduce"`` — all-reduce pattern (``psum``/``pmean``): every worker
      contributes and receives ``size`` elements; traffic does not grow
      with the number of workers W (the paper's §3 scalability argument).
    * ``"gather"`` — all-gather pattern: every worker contributes ``size``
      elements and *receives* ``fanout·size`` (fanout = W), so wire bytes
      scale with the data-parallel world size.
    * ``"broadcast"`` — one-to-all pattern (``sync_mode="broadcast"``): the
      root contributes ``size`` elements and every worker receives ``size``;
      like a reduce, wire bytes are flat in W (a tree broadcast moves
      ``(W−1)/W·size`` per link), so it is recorded at face value with
      ``fanout=1``.

    ``itemsizes`` records the *actual* wire itemsize of each buffer (e.g. 2
    for a bfloat16 chunk, 1 for int8 sign payloads, fractional 0.5 for
    nibble-packed int4) — not a blanket float32 assumption — and
    ``overheads`` the per-collective sidecar bytes (the float32 scale per
    quantized slot), so ``bytes_per_collective`` is honest about the wire
    dtype, sub-byte packing, sidecars and the reduce-vs-gather scaling.
    """

    data_collectives: int = 0
    data_floats: int = 0
    sizes: List[int] = dataclasses.field(default_factory=list)
    itemsizes: List[float] = dataclasses.field(default_factory=list)
    kinds: List[str] = dataclasses.field(default_factory=list)
    fanouts: List[int] = dataclasses.field(default_factory=list)
    overheads: List[int] = dataclasses.field(default_factory=list)

    def record(self, n_elems: int, itemsize: float = 4, kind: str = "reduce",
               fanout: int = 1, overhead: int = 0) -> None:
        assert kind in ("reduce", "gather", "broadcast"), kind
        self.data_collectives += 1
        self.data_floats += int(n_elems)
        self.sizes.append(int(n_elems))
        i = float(itemsize)
        self.itemsizes.append(int(i) if i.is_integer() else i)
        self.kinds.append(kind)
        self.fanouts.append(int(fanout))
        self.overheads.append(int(overhead))

    def reset(self) -> None:
        self.data_collectives = 0
        self.data_floats = 0
        self.sizes.clear()
        self.itemsizes.clear()
        self.kinds.clear()
        self.fanouts.clear()
        self.overheads.clear()

    @property
    def reduce_collectives(self) -> int:
        return sum(1 for k in self.kinds if k == "reduce")

    @property
    def gather_collectives(self) -> int:
        return sum(1 for k in self.kinds if k == "gather")

    @property
    def broadcast_collectives(self) -> int:
        return sum(1 for k in self.kinds if k == "broadcast")

    def bytes_per_collective(self) -> List[float]:
        """Wire bytes per collective: ``size·itemsize + overhead``, using
        each buffer's recorded (possibly fractional) itemsize and its scale
        sidecar.  Integral entries come back as ints.

        Gather-pattern entries are scaled by their fanout (the data-parallel
        world size W): each worker receives every other worker's payload, so
        the bytes crossing a worker's NIC are W× the per-worker payload —
        the cost the paper's all-reduce argument avoids.
        """
        out = []
        for s, i, k, f, o in zip(self.sizes, self.itemsizes, self.kinds,
                                 self.fanouts, self.overheads):
            b = (s * i + o) * (f if k == "gather" else 1)
            out.append(int(b) if float(b).is_integer() else b)
        return out


# ---------------------------------------------------------------------------
# collective backends
# ---------------------------------------------------------------------------

class CollectiveBackend:
    """The primitive collectives :class:`MeshCtx` dispatches through.

    ``axes`` arguments are tuples of axis names (or a single name for the
    single-axis collectives) that are guaranteed non-empty by the caller —
    ``MeshCtx`` short-circuits empty axis sets to the identity before
    dispatching.
    """

    def psum(self, x, axes):
        raise NotImplementedError

    def pmean(self, x, axes):
        raise NotImplementedError

    def pmax(self, x, axes):
        raise NotImplementedError

    def all_gather(self, x, axis, *, gather_axis: int, tiled: bool):
        raise NotImplementedError

    def ppermute(self, x, axis, perm):
        raise NotImplementedError

    def all_to_all(self, x, axis, *, split_axis: int, concat_axis: int):
        raise NotImplementedError

    def axis_size(self, axes) -> int:
        raise NotImplementedError

    def axis_index(self, axis):
        raise NotImplementedError

    def broadcast0(self, x, axes, index):
        """Deliver rank 0's value to every rank along ``axes``.

        Implemented as a masked *unweighted* ``psum`` (every non-root
        contributes exact zeros), the standard one-to-all lowering on
        all-reduce-only transports.  Deliberately NOT overridden by
        :class:`SimBackend`: a broadcast is a control-plane replica sync,
        not a data aggregation, so scenario weights never apply — a
        weight-0 (dropped) root would otherwise destroy the payload.
        Bit-stability note: summing one value with W−1 exact ``+0.0``
        terms is exact in any association order, so this is bit-identical
        across substrates and reduction orders (modulo ``−0.0 → +0.0``,
        which both substrates flip identically).
        """
        return lax.psum(jnp.where(index == 0, x, jnp.zeros_like(x)), axes)


class AxisBackend(CollectiveBackend):
    """Named-axis collectives against the enclosing shard_map/vmap env."""

    def psum(self, x, axes):
        return lax.psum(x, axes)

    def pmean(self, x, axes):
        return lax.pmean(x, axes)

    def pmax(self, x, axes):
        return lax.pmax(x, axes)

    def all_gather(self, x, axis, *, gather_axis: int, tiled: bool):
        return lax.all_gather(x, axis, axis=gather_axis, tiled=tiled)

    def ppermute(self, x, axis, perm):
        return lax.ppermute(x, axis, perm)

    def all_to_all(self, x, axis, *, split_axis: int, concat_axis: int):
        return lax.all_to_all(x, axis, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)

    def axis_size(self, axes) -> int:
        n = 1
        for a in (axes if isinstance(axes, (tuple, list)) else (axes,)):
            n *= lax.axis_size(a)
        return n

    def axis_index(self, axis):
        return lax.axis_index(axis)


AXIS = AxisBackend()  # stateless — one shared instance


def _tree_sum(stacked: jax.Array) -> jax.Array:
    """Fixed pairwise-tree sum over the leading axis.

    The canonical reduction order behind ``sync_mode="broadcast"``: every
    rank gathers all W contributions in rank order and replays this exact
    expression tree, so the result is bit-identical across ranks *by
    construction* — and, because the tree is plain elementwise adds (which
    XLA does not reassociate), bit-identical between the ``shard_map`` and
    SimMesh substrates too.  This is the deterministic-allreduce recipe
    (reduce in a fixed order at a root, broadcast the result) executed
    redundantly on every rank instead of shipping the result separately.
    """
    n = stacked.shape[0]
    while n > 1:
        half = n // 2
        paired = stacked[0:2 * half:2] + stacked[1:2 * half:2]
        if n % 2:
            paired = jnp.concatenate([paired, stacked[2 * half:]], axis=0)
        stacked = paired
        n = stacked.shape[0]
    return stacked[0]


def weighted_mean(x, w, sum_fn):
    """``Σ w·x / Σ w`` with a guarded denominator, generic over how the sum
    is taken (``lax.psum`` over a named axis, ``jnp.sum`` over a stacked
    worker dim).  The single home of the weighted-aggregation semantics:
    :meth:`SimBackend.pmean` (wire-side weighting) and
    :meth:`repro.core.engine.Transport.combine_mean` (receiver-side
    weighting of gathered decodes) must stay exactly equal — the zoo
    conformance suite compares them bit-for-bit.

    The division happens in the weight dtype (f32): ``finfo.tiny`` would
    underflow to 0 if cast to a low-precision wire dtype, turning the
    all-dropped round into 0/0 = NaN instead of the documented exact zero.
    """
    total = sum_fn(w)
    numer = sum_fn(x * w.astype(x.dtype))
    denom = jnp.maximum(total, jnp.finfo(total.dtype).tiny)
    return (numer.astype(total.dtype) / denom).astype(x.dtype)


@dataclasses.dataclass(frozen=True, eq=False)
class SimBackend(AxisBackend):
    """W-logical-worker simulation backend (see :mod:`repro.core.simmesh`).

    Must run inside ``jax.vmap(..., axis_name=self.axis)`` over the stacked
    worker dimension; the named-axis collectives then lower to exact
    reductions over that stacked axis on one device.

    ``weight`` (optional) is this worker's scalar contribution weight — a
    traced value under ``vmap``, one scalar per worker.  It models
    heterogeneous per-worker batch sizes (weight ∝ local token count),
    worker dropout and straggler-skipped rounds (weight 0 for the affected
    round).  Weighted ``pmean`` is ``Σ w_i x_i / Σ w_i``; if every worker is
    dropped the aggregate degenerates to exactly zero (the denominator is
    guarded), i.e. the round becomes a no-op on the aggregated update.
    Weights apply to ``psum``/``pmean`` only — in simulation the context has
    no model/seq axes, so those are the data-parallel collectives.
    """

    axis: str
    size: int
    weight: Optional[jax.Array] = None

    def psum(self, x, axes):
        if self.weight is not None:
            x = x * self.weight.astype(x.dtype)
        return lax.psum(x, axes)

    def pmean(self, x, axes):
        if self.weight is None:
            return lax.pmean(x, axes)
        return weighted_mean(x, self.weight, lambda v: lax.psum(v, axes))

    def axis_size(self, axes) -> int:
        n = 1
        for a in (axes if isinstance(axes, (tuple, list)) else (axes,)):
            n *= self.size if a == self.axis else lax.axis_size(a)
        return n


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    """Names of the mesh axes the current computation is mapped over.

    data_axes:  axes that carry data parallelism (gradient all-reduce),
                e.g. ``("pod", "data")`` or ``("data",)``.
    sync_mode:  how data-axis aggregates reach the ranks.  ``"allreduce"``
                (default) trusts the substrate's all-reduce to hand every
                rank the same value — true mathematically, but NOT at ULP
                level on real meshes (XLA's reduction order can be
                rank-dependent), which lets replicated state drift apart
                bit-wise over steps.  ``"broadcast"`` makes every data-axis
                aggregate replica-deterministic: contributions are gathered
                in rank order and reduced in one canonical pairwise-tree
                order (:func:`_tree_sum`) — logically a reduce-to-root
                followed by a rank-0 broadcast, and recorded in
                :class:`CollectiveStats` as those two legs (``"reduce"`` +
                ``"broadcast"``).  Fused transports can suppress the
                per-call broadcast leg (``sync=False``) and issue ONE real
                end-of-step rank-0 broadcast instead
                (:meth:`broadcast_flat`), keeping the collective budget at
                reduces + 1 broadcast per step.
    model_axis: axis carrying tensor/expert parallelism, e.g. ``"model"``.
    tp_grad_sync: whether :func:`repro.models.common.grad_synced` inserts
                the model-axis ``psum`` on backward cotangents at
                replicated→sharded boundaries.  ``True`` (default) is
                required for correct gradients whenever ``model_axis`` is
                set; ``False`` is a debug switch that reproduces the
                historical per-rank partial gradients (replicated params
                drift apart across model ranks — the divergence formerly
                misattributed to all-reduce nondeterminism in
                docs/checkpoint.md, pinned by tests/sim/test_drift.py).
    seq_axes:   axes over which a decode KV cache is sequence-sharded
                (flash-decode softmax merge): ``("model",)`` for decode_32k,
                ``("pod", "data", "model")`` for long_500k (batch=1).
    stats:      optional :class:`CollectiveStats` that records every data-axis
                collective issued through this context (excluded from eq/hash;
                purely observational).
    backend:    :class:`CollectiveBackend` the collectives dispatch through —
                :data:`AXIS` (production shard_map) by default, or a
                :class:`SimBackend` inside a :class:`~repro.core.simmesh.
                SimMesh` step (excluded from eq/hash: a ``SimBackend`` may
                hold traced per-worker weights).
    """

    data_axes: Tuple[str, ...] = ()
    model_axis: Optional[str] = None
    seq_axes: Tuple[str, ...] = ()
    sync_mode: str = "allreduce"
    tp_grad_sync: bool = True
    stats: Optional[CollectiveStats] = dataclasses.field(
        default=None, compare=False)
    backend: CollectiveBackend = dataclasses.field(
        default=AXIS, compare=False)

    def __post_init__(self):
        assert self.sync_mode in ("allreduce", "broadcast"), self.sync_mode

    def _record_data(self, x, kind: str = "reduce") -> None:
        if self.stats is not None:
            self.stats.record(
                x.size, jnp.dtype(x.dtype).itemsize, kind=kind,
                fanout=self.data_size() if kind == "gather" else 1)

    def _record_chunk(self, chunk, kind: str = "reduce") -> None:
        """Record a quantized wire chunk at its honest cost: fractional
        itemsize (0.5 for int4) plus the scale-sidecar overhead bytes."""
        if self.stats is not None:
            self.stats.record(
                chunk.size, chunk.wire_itemsize, kind=kind,
                fanout=self.data_size() if kind == "gather" else 1,
                overhead=chunk.overhead_bytes)

    @property
    def _synced(self) -> bool:
        return self.sync_mode == "broadcast" and bool(self.data_axes)

    def _canonical_reduce(self, x, *, mean: bool):
        """Replica-deterministic data-axis sum/mean (``sync_mode="broadcast"``).

        Gathers all W contributions in rank order and replays the fixed
        pairwise-tree reduction (:func:`_tree_sum`) identically on every
        rank — the result is bit-identical across ranks and across the
        shard_map/SimMesh substrates.  Honors a weighted :class:`SimBackend`
        with exactly :func:`weighted_mean`'s guarded-denominator semantics
        (the zoo conformance contract).
        """
        stacked = self.backend.all_gather(x, self.data_axes,
                                          gather_axis=0, tiled=False)
        weight = getattr(self.backend, "weight", None)
        if weight is None:
            total = _tree_sum(stacked)
            if not mean:
                return total
            return (total / self.data_size()).astype(x.dtype)
        wvec = self.backend.all_gather(jnp.reshape(weight, ()),
                                       self.data_axes,
                                       gather_axis=0, tiled=False)
        wb = wvec.reshape(wvec.shape + (1,) * x.ndim)
        numer = _tree_sum(stacked * wb.astype(x.dtype))
        if not mean:
            return numer
        total = _tree_sum(wvec)
        denom = jnp.maximum(total, jnp.finfo(total.dtype).tiny)
        return (numer.astype(total.dtype) / denom).astype(x.dtype)

    # -- data-parallel collectives (gradient aggregation) ------------------
    def psum_data(self, x, *, sync: Optional[bool] = None):
        self._record_data(x)
        if not self.data_axes:
            return x
        if self._synced:
            if sync is not False:
                self._record_data(x, kind="broadcast")
            return self._canonical_reduce(x, mean=False)
        return self.backend.psum(x, self.data_axes)

    def pmean_data(self, x, *, sync: Optional[bool] = None):
        self._record_data(x)
        if not self.data_axes:
            return x
        if self._synced:
            if sync is not False:
                self._record_data(x, kind="broadcast")
            return self._canonical_reduce(x, mean=True)
        return self.backend.pmean(x, self.data_axes)

    def pmean_flat(self, parts: Sequence[jax.Array], *,
                   wire_dtype: str = "auto",
                   max_chunk_bytes: Optional[int] = None,
                   sync: Optional[bool] = None,
                   interleave: bool = False) -> List[jax.Array]:
        """Fused all-reduce-mean: O(1) collectives for a whole list of arrays.

        Ravels every part, concatenates into contiguous wire buffers (one per
        :class:`~repro.core.matrixize.FlatChunk` — see
        :func:`repro.core.matrixize.plan_flat` for the ``wire_dtype`` /
        ``max_chunk_bytes`` chunking policy), issues one ``pmean`` per chunk
        over the data axes, then splits back into the original shapes/dtypes.
        Because ``pmean`` is elementwise, this is numerically identical to
        per-part ``pmean_data`` calls (bit-identical when no wire cast
        applies) while replacing N latency-bound collectives with one
        bandwidth-bound one per chunk.

        ``wire_dtype="auto"`` keeps each part's own dtype (same-dtype parts
        share a chunk) — a mixed tree no longer silently upcasts a bfloat16
        payload because one float32 straggler rode along.  Each chunk's
        *actual* wire itemsize is recorded in :class:`CollectiveStats`.

        Under ``sync_mode="broadcast"`` each chunk reduces in the canonical
        deterministic order and records the extra ``"broadcast"`` leg;
        ``sync=False`` keeps the canonical order but suppresses that record
        — for multi-phase transports (PowerSGD's P/Q reduces) that issue
        one fused end-of-step :meth:`broadcast_flat` instead.

        ``wire_dtype="int8"``/``"int4"`` quantize each float chunk slot
        symmetrically before the reduce (integer parts keep their own
        chunks): values are snapped to the wire grid locally and the mean is
        taken over the dequantized float32 buffer — a widened accumulator,
        so the collective stays a plain all-reduce and error feedback sees
        the quantization error.  Stats record the honest quantized wire cost
        (1 byte/elem for int8, 0.5 for nibble-packed int4, + one float32
        scale per slot).

        ``interleave=True`` emits the double-buffered schedule instead of
        the serial one: the reduce for chunk b is issued *before* chunk b−1
        is unpacked, so no chunk's decompression sits between consecutive
        collectives in the dataflow graph and the runtime is free to overlap
        chunk b's wire time with chunk b−1's decode.  Chunks, wire bytes,
        reduction order and :class:`CollectiveStats` records (made at issue
        time) are identical to the serial schedule — only the unpack points
        move — so results are bit-identical and budget guards see the same
        trace.
        """
        from repro.core import matrixize  # local: dist must stay import-light

        parts = list(parts)
        if not parts:
            return []
        plan = matrixize.plan_flat(parts, wire_dtype=wire_dtype,
                                   max_chunk_bytes=max_chunk_bytes)

        def issue(chunk):
            if chunk.quant is not None:
                # quantize-before-reduce, widened accumulator: each worker
                # contributes exactly its wire-representable (dequantized)
                # values and the mean is taken in float32, so the transport
                # stays a plain all-reduce.  Recorded at the honest quantized
                # wire cost (fractional itemsize + scale sidecar).
                buf = matrixize.quant_dequant_flat(chunk, parts)
                self._record_chunk(chunk, "reduce")
            else:
                buf = matrixize.pack_flat(chunk, parts)
                self._record_data(buf)
            if self._synced:
                if sync is not False:
                    self._record_data(buf, kind="broadcast")
                return self._canonical_reduce(buf, mean=True)
            if self.data_axes:
                return self.backend.pmean(buf, self.data_axes)
            return buf

        out: dict = {}
        pending = None  # the in-flight (chunk, reduced buffer) pair
        for chunk in plan.chunks:
            buf = issue(chunk)
            if interleave:
                if pending is not None:
                    out.update(matrixize.unpack_flat(*pending))
                pending = (chunk, buf)
            else:
                out.update(matrixize.unpack_flat(chunk, buf))
        if pending is not None:
            out.update(matrixize.unpack_flat(*pending))
        return [out[i] for i in range(len(parts))]

    def broadcast_flat(self, parts: Sequence[jax.Array], *,
                       wire_dtype: str = "auto",
                       max_chunk_bytes: Optional[int] = None) -> List[jax.Array]:
        """Fused rank-0 broadcast: every part replaced by rank 0's copy.

        The end-of-step replica-sync collective of ``sync_mode="broadcast"``:
        parts are packed into wire chunks exactly like :meth:`pmean_flat`
        and each chunk is delivered from rank 0 via the backend's masked
        unweighted psum (:meth:`CollectiveBackend.broadcast0`).  Recorded
        with ``kind="broadcast"``, bytes flat in W.  Outside any data axis
        (and on already replica-identical inputs) this is the identity.

        Quantized wire dtypes remap to ``"auto"`` here: the broadcast is a
        replica *sync* and must deliver rank 0's exact bits — lossy
        requantization of already-synced state would defeat its purpose.
        """
        from repro.core import matrixize

        if wire_dtype in matrixize.QUANT_WIRE_DTYPES:
            wire_dtype = "auto"
        parts = list(parts)
        if not parts:
            return []
        plan = matrixize.plan_flat(parts, wire_dtype=wire_dtype,
                                   max_chunk_bytes=max_chunk_bytes)
        idx = self.data_index()
        out: dict = {}
        for chunk in plan.chunks:
            buf = matrixize.pack_flat(chunk, parts)
            self._record_data(buf, kind="broadcast")
            if self.data_axes:
                buf = self.backend.broadcast0(buf, self.data_axes, idx)
            out.update(matrixize.unpack_flat(chunk, buf))
        return [out[i] for i in range(len(parts))]

    def allgather_flat(self, parts: Sequence[jax.Array], *,
                       wire_dtype: str = "auto",
                       max_chunk_bytes: Optional[int] = None) -> List[jax.Array]:
        """Fused all-gather: O(1) collectives for a whole list of arrays.

        The gather-pattern sibling of :meth:`pmean_flat`, for compressed
        representations that are *not* linear (sign, top-K, sampled SVD
        triplets): the payloads themselves cannot be summed on the wire, so
        every worker must see every other worker's payload and decode all W
        of them.  Parts are fused into wire chunks exactly like
        :meth:`pmean_flat`; each chunk is gathered with ONE ``all_gather``
        over the data axes and each part comes back with a leading
        worker dimension of size ``data_size()`` (size 1 outside any data
        axis — same code path single-device and distributed).

        :class:`CollectiveStats` records these with ``kind="gather"`` and
        ``fanout=data_size()`` so ``bytes_per_collective`` reflects the
        W-scaled traffic — the cost the paper's all-reduce argument avoids.
        """
        from repro.core import matrixize

        parts = list(parts)
        if not parts:
            return []
        plan = matrixize.plan_flat(parts, wire_dtype=wire_dtype,
                                   max_chunk_bytes=max_chunk_bytes)
        w = self.data_size()
        out: dict = {}
        for chunk in plan.chunks:
            if chunk.quant is not None:
                # quantize-before-gather: the real integer payload crosses
                # the wire (nibble-packed for int4) with its per-slot scale
                # sidecar; every worker dequantizes all W payloads after the
                # gather.  One logical collective per chunk — the sidecar
                # rides it, counted as overhead bytes, not a new collective.
                payload, scales = matrixize.quant_pack_flat(chunk, parts)
                self._record_chunk(chunk, "gather")
                if self.data_axes:
                    payload = self.backend.all_gather(
                        payload, self.data_axes, gather_axis=0, tiled=False)
                    scales = self.backend.all_gather(
                        scales, self.data_axes, gather_axis=0, tiled=False)
                else:
                    payload, scales = payload[None], scales[None]
                out.update(matrixize.quant_unpack_flat(
                    chunk, payload, scales, leading=(w,)))
                continue
            buf = matrixize.pack_flat(chunk, parts)
            self._record_data(buf, kind="gather")
            if self.data_axes:
                buf = self.backend.all_gather(buf, self.data_axes,
                                              gather_axis=0, tiled=False)
            else:
                buf = buf[None]
            out.update(matrixize.unpack_flat(chunk, buf, leading=(w,)))
        return [out[i] for i in range(len(parts))]

    def gather_data_weight(self) -> Optional[jax.Array]:
        """All workers' contribution weights as a ``(data_size(),)`` vector,
        or ``None`` when the backend carries no per-worker weight (uniform).

        Gather-pattern aggregation averages *decoded* payloads on the
        receiver, so scenario weights (worker dropout, heterogeneous
        batches — :class:`SimBackend`) must travel with the payloads; the
        transport engine uses this to weight its combine step exactly like
        a weighted ``pmean``.
        """
        weight = getattr(self.backend, "weight", None)
        if weight is None:
            return None
        w = jnp.reshape(weight, ())
        if not self.data_axes:
            return w[None]
        return self.backend.all_gather(w[None], self.data_axes,
                                       gather_axis=0, tiled=True)

    # -- model-parallel collectives (tensor parallelism) --------------------
    def psum_model(self, x):
        if not self.model_axis:
            return x
        if self.tp_grad_sync and self.backend is AXIS:
            # Megatron f: reduce forward, identity backward — paired with the
            # backward psum grad_synced inserts at replicated→sharded entries
            return _psum_identity_bwd(x, self.model_axis)
        return self.backend.psum(x, self.model_axis)

    def pmean_model(self, x):
        return self.backend.pmean(x, self.model_axis) if self.model_axis else x

    def pmax_model(self, x):
        return self.backend.pmax(x, self.model_axis) if self.model_axis else x

    def all_gather_model(self, x, axis: int = -1, tiled: bool = True):
        if self.model_axis is None:
            return x
        return self.backend.all_gather(x, self.model_axis, gather_axis=axis,
                                       tiled=tiled)

    def ppermute_model(self, x, perm):
        if self.model_axis is None:
            return x
        return self.backend.ppermute(x, self.model_axis, perm)

    def all_to_all_model(self, x, split_axis: int, concat_axis: int):
        """Re-distribute: split ``split_axis`` over the model axis, gather
        ``concat_axis`` (e.g. column-sharded → row-sharded activations)."""
        if self.model_axis is None:
            return x
        return self.backend.all_to_all(x, self.model_axis,
                                       split_axis=split_axis,
                                       concat_axis=concat_axis)

    # -- sequence-shard collectives (flash-decode merge) ---------------------
    def psum_seq(self, x):
        return self.backend.psum(x, self.seq_axes) if self.seq_axes else x

    def pmax_seq(self, x):
        return self.backend.pmax(x, self.seq_axes) if self.seq_axes else x

    # -- sizes / indices ----------------------------------------------------
    def data_size(self) -> int:
        return self.backend.axis_size(self.data_axes) if self.data_axes else 1

    def model_size(self) -> int:
        return self.backend.axis_size(self.model_axis) if self.model_axis else 1

    def seq_size(self) -> int:
        return self.backend.axis_size(self.seq_axes) if self.seq_axes else 1

    def model_index(self):
        if self.model_axis is None:
            return 0
        return self.backend.axis_index(self.model_axis)

    def seq_index(self):
        """Linearised index over the seq axes (row-major)."""
        if not self.seq_axes:
            return 0
        idx = 0
        for a in self.seq_axes:
            idx = idx * self.backend.axis_size((a,)) + self.backend.axis_index(a)
        return idx

    def data_index(self):
        """Linearised index over the data axes (row-major)."""
        if not self.data_axes:
            return 0
        idx = 0
        for a in self.data_axes:
            idx = idx * self.backend.axis_size((a,)) + self.backend.axis_index(a)
        return idx


SINGLE = MeshCtx()  # single-device context: all collectives are identities


# ---------------------------------------------------------------------------
# gradlint attribution contract (repro.analysis)
# ---------------------------------------------------------------------------
# Every data-axis collective a traced step emits must reach the wire through
# one of these MeshCtx entry points — the static analyzer attributes each
# collective primitive in a jaxpr to the innermost frame of its traceback
# that names one of them, and flags any data-axis collective whose call
# chain passes through none (a hand-rolled collective escapes both the
# budget and the byte accounting).  Kept here, next to the entry points
# themselves, so adding a transport path and forgetting the ledger is a
# one-file diff review.

#: jaxpr primitive names that move bytes across a named axis
COLLECTIVE_PRIMITIVES = frozenset({
    "psum", "pmax", "pmin", "all_gather", "ppermute", "all_to_all",
    "reduce_scatter", "pbroadcast",
})

#: dist.py function name -> logical collective kind, matching the ``kind``
#: each site records into :class:`CollectiveStats`.  ``issue`` is
#: ``pmean_flat``'s per-chunk closure; ``_canonical_reduce`` is the
#: deterministic gather+tree-sum lowering of a reduce under
#: ``sync_mode="broadcast"`` (one all_gather primitive, kind "reduce").
COLLECTIVE_SITES = {
    "psum_data": "reduce",
    "pmean_data": "reduce",
    "pmean_flat": "reduce",
    "issue": "reduce",
    "_canonical_reduce": "reduce",
    "allgather_flat": "gather",
    "gather_data_weight": "gather",
    "broadcast_flat": "broadcast",
    "broadcast0": "broadcast",
}


def quant_sidecar_line() -> int:
    """Source line of the scale-sidecar ``all_gather`` in
    :meth:`MeshCtx.allgather_flat` (the ``scales = self.backend.all_gather``
    call).  A quantized gather ships its integer payload and its float32
    per-slot scales as two backend all_gathers but ONE logical collective —
    the analyzer folds the primitive at this line into its payload gather.
    Recomputed from the live source so edits to this module cannot stale it.
    """
    import ast as _ast
    import functools
    import inspect

    @functools.lru_cache(maxsize=1)
    def _find() -> int:
        src, base = inspect.getsourcelines(MeshCtx.allgather_flat)
        tree = _ast.parse("".join(
            line[4:] if line.startswith("    ") else line for line in src))
        for node in _ast.walk(tree):
            if (isinstance(node, _ast.Assign)
                    and isinstance(node.targets[0], _ast.Name)
                    and node.targets[0].id == "scales"
                    and isinstance(node.value, _ast.Call)):
                return base + node.lineno - 1
        raise AssertionError(
            "gradlint: scale-sidecar all_gather not found in allgather_flat")

    return _find()
