"""Rank-r PowerSGD compression (paper Algorithm 1).

One warm-started subspace-iteration step per optimization step:

    P  ← M Q                 (local matmul)
    P  ← all-reduce-mean(P)  (data axes)
    P̂  ← orthogonalize(P)
    Q  ← Mᵀ P̂                (local matmul)
    Q  ← all-reduce-mean(Q)  (data axes)
    Δ' ← P̂ Qᵀ                (decompress)

Linearity (Appendix A.3): both matmuls commute with the mean over workers, so
the all-reduces aggregate the *compressed* representation directly — the
whole compressor costs two tall-skinny matmuls, two `psum`s of r·(n+m) floats
and one orthogonalization per matrix.

Under tensor parallelism each model shard compresses its local slice of every
weight matrix independently and all-reduces only over the data axes; the
paper's W-worker linearity argument applies verbatim per shard.

All aggregation goes through ``ctx`` (:class:`repro.core.dist.MeshCtx`), so
the same compressor code runs on a real mesh (shard_map axes) and on the
in-process W-worker simulator (:mod:`repro.core.simmesh`), where the
``pmean``s become exact — optionally *weighted* — means over a stacked
worker axis; ``tests/sim/`` replays Lemma 3 and the collective-count
invariant on that substrate.

Bucketed batched-compression via the transport engine (default,
``bucketing="auto"``)
-------------------------------------------------------------------
The per-leaf schedule above issues two collectives *per weight matrix* —
dozens of tiny latency-bound ``pmean``s per step, exactly the pattern the
paper's all-reduce argument is meant to avoid.  The default path instead
runs the power iteration against :mod:`repro.core.engine`:

1. :class:`~repro.core.engine.MatrixPayloads` groups the tree's matrixized
   leaves into shape buckets (zero-padding within a tolerance; see
   :func:`repro.core.matrixize.plan_buckets`) and stacks each bucket into a
   ``(B, n, m)`` slab,
2. this module runs the *math* — project, orthogonalize, back-project — as
   batched ops over the slabs,
3. :class:`~repro.core.engine.Transport` fuses ALL buckets' P factors (plus
   the uncompressed vector leaves) into one flat wire buffer and issues a
   single ``pmean``; likewise for the Q factors, honoring the configured
   ``wire_dtype`` policy.

One step therefore issues exactly 2 data-axis collectives per power
iteration, independent of the number of weight matrices.  Zero padding is
exact (padded rows/cols contribute exact zeros through both matmuls and the
orthogonalizer), so the engine is numerically identical to the per-leaf path
(``bucketing="off"``) up to float reassociation and any wire-dtype cast.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import engine, matrixize
from repro.core.dist import MeshCtx, SINGLE
from repro.core.orthogonalize import get_orthogonalizer

# canonical homes moved to the transport engine; re-exported for existing
# importers (compressors, tests)
PowerSGDOut = engine.CompressOut
_leaf_key = engine.leaf_key


@dataclasses.dataclass(frozen=True)
class PowerSGDConfig:
    rank: int = 2
    orthogonalizer: str = "gram_schmidt"   # paper default; "cholesky_qr" = TPU opt
    warm_start: bool = True                # §4.2
    num_iters: int = 1                     # >1 ⇒ Appendix G.7 best-approximation
    error_mode: str = "global"             # "global" (reference impl) | "local" (Alg. 2 literal)
    use_pallas: bool = False               # route matmuls through the Pallas kernels
    dtype: Any = jnp.float32
    bucketing: str = "auto"                # "auto"/"on" = batched engine | "off" = per-leaf
    bucket_pad_tolerance: float = 0.25     # max relative padding waste per bucket
    wire_dtype: str = "auto"               # fused-collective wire policy ("auto"|"float32"|"bfloat16")
    max_chunk_bytes: Optional[int] = None  # cap per fused wire buffer


def init_state(cfg: PowerSGDConfig, shapes, specs, key: jax.Array):
    """Q ∈ R^{m×r} per matrix leaf, i.i.d. standard normal (Alg. 1 line 1)."""

    def init_leaf(path, shape_leaf, spec):
        ms = matrixize.matrix_shape(tuple(shape_leaf.shape), spec)
        if ms is None:
            return None
        batch_shape, _, m = ms
        k = _leaf_key(key, path)
        return jax.random.normal(k, batch_shape + (m, cfg.rank), dtype=cfg.dtype)

    return jax.tree_util.tree_map_with_path(
        init_leaf, shapes, specs, is_leaf=lambda x: x is None
    )


def _matmuls(cfg: PowerSGDConfig):
    """Return (project, backproject): P = M Q and Qn = Mᵀ P̂ on (..., n, m)."""
    if cfg.use_pallas:
        from repro.kernels import ops  # lazy: optional dependency direction

        return ops.lowrank_project, ops.lowrank_backproject
    project = lambda m, q: jnp.einsum("...nm,...mr->...nr", m, q)
    backproject = lambda m, p: jnp.einsum("...nm,...nr->...mr", m, p)
    return project, backproject


def compress_aggregate(
    cfg: PowerSGDConfig,
    deltas,                      # tree of update tensors (grad + error)
    state,                       # tree of Q factors (or None per leaf)
    specs,
    ctx: MeshCtx = SINGLE,
    key: Optional[jax.Array] = None,
) -> PowerSGDOut:
    if cfg.bucketing in ("auto", "on"):
        return _compress_aggregate_bucketed(cfg, deltas, state, specs, ctx, key)
    if cfg.bucketing != "off":
        raise ValueError(
            f"unknown bucketing mode {cfg.bucketing!r}; use 'auto', 'on' or 'off'")
    orth = get_orthogonalizer(cfg.orthogonalizer)
    project, backproject = _matmuls(cfg)
    floats_sent = [0]

    def leaf(path, g, q, spec):
        if q is None:  # uncompressed (vector) leaf — paper's bias rule
            agg = ctx.pmean_data(g)
            floats_sent[0] += matrixize.uncompressed_floats(g.shape)
            return agg, g, None

        mat = matrixize.to_matrix(g, spec).astype(cfg.dtype)
        if not cfg.warm_start:
            k = _leaf_key(key, path)
            q = jax.random.normal(k, q.shape, dtype=cfg.dtype)

        n_iter = max(1, cfg.num_iters)
        for it in range(n_iter):
            p = project(mat, q)                    # (..., n, r)
            p = ctx.pmean_data(p)
            p_hat = orth(p)
            q_local = backproject(mat, p_hat)      # (..., m, r)
            q = ctx.pmean_data(q_local)

        agg_mat = jnp.einsum("...nr,...mr->...nm", p_hat, q)
        if cfg.error_mode == "local":
            recon_mat = jnp.einsum("...nr,...mr->...nm", p_hat, q_local)
        else:
            recon_mat = agg_mat
        floats_sent[0] += matrixize.compressed_floats(g.shape, spec, cfg.rank)

        agg = matrixize.from_matrix(agg_mat, g.shape, spec).astype(g.dtype)
        recon = matrixize.from_matrix(recon_mat, g.shape, spec).astype(g.dtype)
        return agg, recon, q

    triples = jax.tree_util.tree_map_with_path(
        leaf, deltas, state, specs, is_leaf=lambda x: x is None
    )
    # tree_map_with_path mapped over `deltas`' structure; unzip the 3-tuples
    agg = jax.tree_util.tree_map(lambda t: t[0], triples, is_leaf=lambda x: isinstance(x, tuple))
    recon = jax.tree_util.tree_map(lambda t: t[1], triples, is_leaf=lambda x: isinstance(x, tuple))
    new_state = jax.tree_util.tree_map(lambda t: t[2], triples, is_leaf=lambda x: isinstance(x, tuple))
    return PowerSGDOut(agg=agg, recon=recon, state=new_state, bits_per_worker=floats_sent[0] * 32)


def _compress_aggregate_bucketed(
    cfg: PowerSGDConfig,
    deltas,
    state,
    specs,
    ctx: MeshCtx = SINGLE,
    key: Optional[jax.Array] = None,
) -> PowerSGDOut:
    """Batched power iteration over shape buckets, 2 collectives per iter.

    Same math as the per-leaf path (see module docstring).  Pack / fuse /
    scatter is the transport engine's job (:class:`engine.MatrixPayloads`
    plans and packs the bucket slabs, :class:`engine.Transport` fuses the
    per-phase all-reduces into one flat wire collective each); this function
    is only the PowerSGD math — project, orthogonalize, back-project —
    scheduled between the two transport phases.  Uncompressed (vector)
    leaves ride along in the first fused collective.  State layout is
    identical to the per-leaf path (per-leaf Q factors), so the two paths
    are freely interchangeable mid-run.
    """
    orth = get_orthogonalizer(cfg.orthogonalizer)
    project, backproject = _matmuls(cfg)
    n_iter = max(1, cfg.num_iters)

    payloads = engine.MatrixPayloads.build(
        deltas, state, specs, rank=cfg.rank, dtype=cfg.dtype,
        tolerance=cfg.bucket_pad_tolerance,
        resample_key=None if cfg.warm_start else key)
    transport = engine.Transport(ctx=ctx, wire_dtype=cfg.wire_dtype,
                                 max_chunk_bytes=cfg.max_chunk_bytes)
    m_bufs, q_bufs = payloads.m_bufs, payloads.q_bufs

    # -- power iteration: 2 fused collectives per round ---------------------
    unc_agg = payloads.unc_values  # identity if no uncompressed leaves
    p_hats = q_locals = []
    for it in range(n_iter):
        p_locals = [project(mb, qb) for mb, qb in zip(m_bufs, q_bufs)]
        extra = unc_agg if it == 0 else []
        reduced = transport.reduce_mean(p_locals + extra)
        p_bufs = reduced[:len(p_locals)]
        if it == 0:
            unc_agg = reduced[len(p_locals):]
        p_hats = [orth(p) for p in p_bufs]
        q_locals = [backproject(mb, ph) for mb, ph in zip(m_bufs, p_hats)]
        q_bufs = transport.reduce_mean(q_locals)

    agg_bufs = [jnp.einsum("bnr,bmr->bnm", ph, qb)
                for ph, qb in zip(p_hats, q_bufs)]
    if cfg.error_mode == "local":
        recon_bufs = [jnp.einsum("bnr,bmr->bnm", ph, ql)
                      for ph, ql in zip(p_hats, q_locals)]
    else:
        recon_bufs = agg_bufs

    agg, recon, new_state = payloads.scatter(agg_bufs, recon_bufs, q_bufs,
                                             unc_agg)
    return PowerSGDOut(agg=agg, recon=recon, state=new_state,
                       bits_per_worker=payloads.bits)


def compressed_floats_total(shapes, specs, rank: int) -> int:
    """Analytic bytes-per-all-reduce accounting (paper Tables 3/10/11)."""
    total = [0]

    def leaf(shape_leaf, spec):
        total[0] += matrixize.compressed_floats(tuple(shape_leaf.shape), spec, rank)

    jax.tree_util.tree_map(leaf, shapes, specs)
    return total[0]
