"""Rank-r PowerSGD compression (paper Algorithm 1).

One warm-started subspace-iteration step per optimization step:

    P  ← M Q                 (local matmul)
    P  ← all-reduce-mean(P)  (data axes)
    P̂  ← orthogonalize(P)
    Q  ← Mᵀ P̂                (local matmul)
    Q  ← all-reduce-mean(Q)  (data axes)
    Δ' ← P̂ Qᵀ                (decompress)

Linearity (Appendix A.3): both matmuls commute with the mean over workers, so
the all-reduces aggregate the *compressed* representation directly — the
whole compressor costs two tall-skinny matmuls, two `psum`s of r·(n+m) floats
and one orthogonalization per matrix.

Under tensor parallelism each model shard compresses its local slice of every
weight matrix independently and all-reduces only over the data axes; the
paper's W-worker linearity argument applies verbatim per shard.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import matrixize
from repro.core.dist import MeshCtx, SINGLE
from repro.core.orthogonalize import get_orthogonalizer


@dataclasses.dataclass(frozen=True)
class PowerSGDConfig:
    rank: int = 2
    orthogonalizer: str = "gram_schmidt"   # paper default; "cholesky_qr" = TPU opt
    warm_start: bool = True                # §4.2
    num_iters: int = 1                     # >1 ⇒ Appendix G.7 best-approximation
    error_mode: str = "global"             # "global" (reference impl) | "local" (Alg. 2 literal)
    use_pallas: bool = False               # route matmuls through the Pallas kernels
    dtype: Any = jnp.float32


@dataclasses.dataclass
class PowerSGDOut:
    agg: Any            # tree: aggregated decompressed update  (= mean_w Δ'_w)
    recon: Any          # tree: reconstruction used for the error update
    state: Any          # tree: new Q factors (warm start)
    bits_per_worker: int  # floats all-reduced per step per model shard


def _leaf_key(key: jax.Array, path) -> jax.Array:
    h = hashlib.sha256(jax.tree_util.keystr(path).encode()).digest()
    return jax.random.fold_in(key, int.from_bytes(h[:4], "little"))


def init_state(cfg: PowerSGDConfig, shapes, specs, key: jax.Array):
    """Q ∈ R^{m×r} per matrix leaf, i.i.d. standard normal (Alg. 1 line 1)."""

    def init_leaf(path, shape_leaf, spec):
        ms = matrixize.matrix_shape(tuple(shape_leaf.shape), spec)
        if ms is None:
            return None
        batch_shape, _, m = ms
        k = _leaf_key(key, path)
        return jax.random.normal(k, batch_shape + (m, cfg.rank), dtype=cfg.dtype)

    return jax.tree_util.tree_map_with_path(
        init_leaf, shapes, specs, is_leaf=lambda x: x is None
    )


def _matmuls(cfg: PowerSGDConfig):
    """Return (project, backproject): P = M Q and Qn = Mᵀ P̂ on (..., n, m)."""
    if cfg.use_pallas:
        from repro.kernels import ops  # lazy: optional dependency direction

        return ops.lowrank_project, ops.lowrank_backproject
    project = lambda m, q: jnp.einsum("...nm,...mr->...nr", m, q)
    backproject = lambda m, p: jnp.einsum("...nm,...nr->...mr", m, p)
    return project, backproject


def compress_aggregate(
    cfg: PowerSGDConfig,
    deltas,                      # tree of update tensors (grad + error)
    state,                       # tree of Q factors (or None per leaf)
    specs,
    ctx: MeshCtx = SINGLE,
    key: Optional[jax.Array] = None,
) -> PowerSGDOut:
    orth = get_orthogonalizer(cfg.orthogonalizer)
    project, backproject = _matmuls(cfg)
    floats_sent = [0]

    def leaf(path, g, q, spec):
        if q is None:  # uncompressed (vector) leaf — paper's bias rule
            agg = ctx.pmean_data(g)
            floats_sent[0] += matrixize.uncompressed_floats(g.shape)
            return agg, g, None

        mat = matrixize.to_matrix(g, spec).astype(cfg.dtype)
        if not cfg.warm_start:
            k = _leaf_key(key, path)
            q = jax.random.normal(k, q.shape, dtype=cfg.dtype)

        n_iter = max(1, cfg.num_iters)
        for it in range(n_iter):
            p = project(mat, q)                    # (..., n, r)
            p = ctx.pmean_data(p)
            p_hat = orth(p)
            q_local = backproject(mat, p_hat)      # (..., m, r)
            q = ctx.pmean_data(q_local)

        agg_mat = jnp.einsum("...nr,...mr->...nm", p_hat, q)
        if cfg.error_mode == "local":
            recon_mat = jnp.einsum("...nr,...mr->...nm", p_hat, q_local)
        else:
            recon_mat = agg_mat
        floats_sent[0] += matrixize.compressed_floats(g.shape, spec, cfg.rank)

        agg = matrixize.from_matrix(agg_mat, g.shape, spec).astype(g.dtype)
        recon = matrixize.from_matrix(recon_mat, g.shape, spec).astype(g.dtype)
        return agg, recon, q

    triples = jax.tree_util.tree_map_with_path(
        leaf, deltas, state, specs, is_leaf=lambda x: x is None
    )
    # tree_map_with_path mapped over `deltas`' structure; unzip the 3-tuples
    agg = jax.tree_util.tree_map(lambda t: t[0], triples, is_leaf=lambda x: isinstance(x, tuple))
    recon = jax.tree_util.tree_map(lambda t: t[1], triples, is_leaf=lambda x: isinstance(x, tuple))
    new_state = jax.tree_util.tree_map(lambda t: t[2], triples, is_leaf=lambda x: isinstance(x, tuple))
    return PowerSGDOut(agg=agg, recon=recon, state=new_state, bits_per_worker=floats_sent[0] * 32)


def compressed_floats_total(shapes, specs, rank: int) -> int:
    """Analytic bytes-per-all-reduce accounting (paper Tables 3/10/11)."""
    total = [0]

    def leaf(shape_leaf, spec):
        total[0] += matrixize.compressed_floats(tuple(shape_leaf.shape), spec, rank)

    jax.tree_util.tree_map(leaf, shapes, specs)
    return total[0]
