"""Rank-r PowerSGD compression (paper Algorithm 1).

One warm-started subspace-iteration step per optimization step:

    P  ← M Q                 (local matmul)
    P  ← all-reduce-mean(P)  (data axes)
    P̂  ← orthogonalize(P)
    Q  ← Mᵀ P̂                (local matmul)
    Q  ← all-reduce-mean(Q)  (data axes)
    Δ' ← P̂ Qᵀ                (decompress)

Linearity (Appendix A.3): both matmuls commute with the mean over workers, so
the all-reduces aggregate the *compressed* representation directly — the
whole compressor costs two tall-skinny matmuls, two `psum`s of r·(n+m) floats
and one orthogonalization per matrix.

Under tensor parallelism each model shard compresses its local slice of every
weight matrix independently and all-reduces only over the data axes; the
paper's W-worker linearity argument applies verbatim per shard.

All aggregation goes through ``ctx`` (:class:`repro.core.dist.MeshCtx`), so
the same compressor code runs on a real mesh (shard_map axes) and on the
in-process W-worker simulator (:mod:`repro.core.simmesh`), where the
``pmean``s become exact — optionally *weighted* — means over a stacked
worker axis; ``tests/sim/`` replays Lemma 3 and the collective-count
invariant on that substrate.

Bucketed batched-compression via the transport engine (default,
``bucketing="auto"``)
-------------------------------------------------------------------
The per-leaf schedule above issues two collectives *per weight matrix* —
dozens of tiny latency-bound ``pmean``s per step, exactly the pattern the
paper's all-reduce argument is meant to avoid.  The default path instead
runs the power iteration against :mod:`repro.core.engine`:

1. :class:`~repro.core.engine.MatrixPayloads` groups the tree's matrixized
   leaves into shape buckets (zero-padding within a tolerance; see
   :func:`repro.core.matrixize.plan_buckets`) and stacks each bucket into a
   ``(B, n, m)`` slab,
2. this module runs the *math* — project, orthogonalize, back-project — as
   batched ops over the slabs,
3. :class:`~repro.core.engine.Transport` fuses ALL buckets' P factors (plus
   the uncompressed vector leaves) into one flat wire buffer and issues a
   single ``pmean``; likewise for the Q factors, honoring the configured
   ``wire_dtype`` policy.

One step therefore issues exactly 2 data-axis collectives per power
iteration, independent of the number of weight matrices.  Zero padding is
exact (padded rows/cols contribute exact zeros through both matmuls and the
orthogonalizer), so the engine is numerically identical to the per-leaf path
(``bucketing="off"``) up to float reassociation and any wire-dtype cast.

Adaptive rank (:class:`RankSchedule`)
-------------------------------------
The rank is *state-carried*, not config-carried: every compress path reads
each leaf's active rank off its warm-start factor (``q.shape[-1]``), so the
payload shapes, the bits accounting and the engine's bucket slabs all
follow whatever rank was last installed into the state.  ``cfg.rank`` only
seeds :func:`init_state`.

Rank changes are *host-level shape transitions* between jitted steps (XLA
shapes are static per trace; a switch simply retraces):

* :class:`RankSchedule` is the policy — :class:`FixedRank`,
  :class:`StaircaseRank` (PowerSGD+-style step staircase) and
  :class:`ResidualEnergyRank` (driven by the measured power-iteration
  residual ‖M − P̂Qᵀ‖_F / ‖M‖_F, tracked per bucket when
  ``cfg.track_residual`` is on).
* :func:`transition_factor` / :func:`transition_state` implement the
  warm-start-preserving switch: a rank *decrease* keeps the leading
  columns of Q bit-exactly (the orthogonalizer's Gram–Schmidt order makes
  those the dominant tracked directions); an *increase* keeps every
  existing column bit-exactly and appends fresh i.i.d. normal columns for
  the power iteration to absorb.  Error-feedback buffers are full-shape
  trees and are not touched at all — preservation across a switch is
  exact by construction (``tests/sim/test_rank_transitions.py``).
* :class:`RankController` is the driver loop's one-liner: feed it the
  step index (and the residual metric, for :class:`ResidualEnergyRank`)
  and it returns the transitioned compressor state when the policy fires.

The α-β autotuner (:mod:`repro.core.autotune`) builds on the same
machinery to assign *per-bucket* ranks under a bits budget.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine, matrixize
from repro.core.dist import MeshCtx, SINGLE
from repro.core.orthogonalize import get_orthogonalizer

# canonical homes moved to the transport engine; re-exported for existing
# importers (compressors, tests)
PowerSGDOut = engine.CompressOut
_leaf_key = engine.leaf_key
StatePartition = engine.StatePartition
MODEL_REPLICATED = engine.MODEL_REPLICATED
MODEL_SHARDED = engine.MODEL_SHARDED
MODEL_LOCAL = engine.MODEL_LOCAL


def _mentions(entry, axis: str) -> bool:
    """Does one PartitionSpec entry carry ``axis`` (entries may be tuples)?"""
    if entry == axis:
        return True
    return isinstance(entry, (tuple, list)) and axis in entry


def factor_partition(param_spec, mspec, model_axis: str = "model"):
    """:class:`~repro.core.engine.StatePartition` of one Q factor.

    Q has shape ``batch_shape + (m, r)``: batch dims keep the parameter's
    entries, the m dim carries the model axis iff any of the parameter's
    trailing (m) dims does.  The subtle case is the *n* dim: ``Q = Mᵀ P̂``
    is computed from each model rank's local n-rows of M, so when the n dim
    is model-sharded (row-parallel weights — embeddings, attention out
    projections, MLP down projections) each rank's Q holds *different*
    content even though no Q dim carries the axis — that leaf is
    :data:`~repro.core.engine.MODEL_LOCAL`, and a checkpoint must gather it
    per model rank instead of trusting the replicated-shaped spec (the
    rank-0-copy corruption this classification exists to prevent).
    Returns None for uncompressed leaves.
    """
    if not mspec.is_compressed():
        return None
    from jax.sharding import PartitionSpec as P

    b = mspec.batch_dims
    entries = tuple(param_spec) + (None,) * 16  # pad
    n_sharded = _mentions(entries[b], model_axis)
    m_sharded = any(_mentions(e, model_axis) for e in entries[b + 1:b + 16])
    assert not (n_sharded and m_sharded), (
        "a weight matrixized with both n and m dims model-sharded has no "
        f"single-axis TP layout: {param_spec} with {mspec}")
    spec = P(*(entries[:b] + (model_axis if m_sharded else None, None)))
    if n_sharded:
        model = MODEL_LOCAL
    elif m_sharded or any(_mentions(e, model_axis) for e in entries[:b]):
        model = MODEL_SHARDED
    else:
        model = MODEL_REPLICATED
    return StatePartition(spec=spec, model=model)


def state_partition(param_pspecs, mspecs, model_axis: str = "model"):
    """Tree of :func:`factor_partition` records, shaped like the state tree
    :func:`init_state` builds (None leaves at uncompressed positions)."""
    from jax.sharding import PartitionSpec as P

    return jax.tree_util.tree_map(
        lambda s, ms: factor_partition(s, ms, model_axis),
        param_pspecs, mspecs, is_leaf=lambda x: isinstance(x, P))


@dataclasses.dataclass(frozen=True)
class PowerSGDConfig:
    rank: int = 2                          # *initial* rank — the live rank is
    #                                        state-carried (q.shape[-1]) and may
    #                                        be moved by a RankSchedule/autotuner
    orthogonalizer: str = "gram_schmidt"   # paper default; "cholesky_qr" = TPU opt
    warm_start: bool = True                # §4.2
    num_iters: int = 1                     # >1 ⇒ Appendix G.7 best-approximation
    error_mode: str = "global"             # "global" (reference impl) | "local" (Alg. 2 literal)
    use_pallas: bool = False               # route matmuls through the Pallas kernels
    dtype: Any = jnp.float32
    bucketing: str = "auto"                # "auto"/"on" = batched engine | "off" = per-leaf
    bucket_pad_tolerance: float = 0.25     # max relative padding waste per bucket
    wire_dtype: str = "auto"               # fused-collective wire policy
    #                                        ("auto"|"float32"|"bfloat16"|"int8"|"int4")
    max_chunk_bytes: Optional[int] = None  # cap per fused wire buffer
    track_residual: bool = False           # emit ‖M − P̂Qᵀ‖/‖M‖ metrics
    #                                        (CompressOut.metrics; required by
    #                                        ResidualEnergyRank)
    pipeline: bool = False                 # engine.PipelinedTransport: issue
    #                                        chunk b's reduce before decoding
    #                                        b−1 (bit-identical; ISSUE 8)


# ---------------------------------------------------------------------------
# Rank schedules: fixed / staircase / residual-energy-driven
# ---------------------------------------------------------------------------


class RankSchedule:
    """Policy deciding the active rank over training.

    Rank is a *shape*, so schedules are evaluated host-side between jitted
    steps (see module docstring): the training driver asks the schedule for
    the rank of the upcoming step and applies :func:`transition_state` when
    it differs from the current one — :class:`RankController` packages that
    loop.  ``next_rank`` must be deterministic given its arguments so every
    worker (and a resumed run) takes the same transition at the same step.
    """

    def initial_rank(self) -> int:
        raise NotImplementedError

    def next_rank(self, step: int, current: int,
                  residual: Optional[float] = None) -> int:
        """Active rank for step ``step``.  ``residual`` is the previous
        step's measured residual-energy ratio (None when not tracked)."""
        raise NotImplementedError

    @property
    def needs_residual(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class FixedRank(RankSchedule):
    """The paper's setting: one static rank for the whole run."""

    rank: int = 2

    def initial_rank(self) -> int:
        return self.rank

    def next_rank(self, step, current, residual=None) -> int:
        return self.rank


@dataclasses.dataclass(frozen=True)
class StaircaseRank(RankSchedule):
    """PowerSGD+-style step staircase: ``milestones`` is a sorted tuple of
    ``(step, rank)`` pairs; the rank of step ``t`` is the one attached to
    the last milestone with ``step <= t``.  The canonical use is
    low-rank-early / high-rank-late (e.g. ``"1@0,2@50,4@100"``): early
    gradients are noisy and the warm-started subspace is still forming, so
    rank 1–2 loses nothing there — spend full rank only once gradient
    structure is worth the bits.  Measured on the synthetic LM
    (``benchmarks adaptive_rank_profile``): the 1→2→4 staircase sends ~42%
    fewer cumulative compressed floats than fixed rank-4 at equal-or-better
    final loss, while the *decay* staircase 4→2→1 loses to every fixed rank
    — a mid-run rank drop injects reconstruction error the remaining steps
    cannot re-absorb (see ``docs/tuning.md``)."""

    milestones: Tuple[Tuple[int, int], ...] = ((0, 2),)

    def __post_init__(self):
        assert self.milestones and self.milestones[0][0] == 0, (
            "first milestone must cover step 0", self.milestones)
        steps = [s for s, _ in self.milestones]
        assert steps == sorted(steps), ("milestones must be sorted",
                                        self.milestones)
        assert all(r >= 1 for _, r in self.milestones), self.milestones

    def initial_rank(self) -> int:
        return self.milestones[0][1]

    def next_rank(self, step, current, residual=None) -> int:
        rank = self.milestones[0][1]
        for s, r in self.milestones:
            if step >= s:
                rank = r
        return rank


@dataclasses.dataclass(frozen=True)
class ResidualEnergyRank(RankSchedule):
    """Rank driven by the measured power-iteration residual.

    The compressor (with ``track_residual=True``) reports
    ρ = ‖M − P̂Qᵀ‖_F / ‖M‖_F each step.  Every ``every`` steps the policy
    compares an exponential moving average of ρ against a hysteresis band:
    ρ̄ > ``grow_above`` means the current rank leaves too much gradient
    energy behind → double toward ``max_rank``; ρ̄ < ``shrink_below`` means
    the subspace over-covers the gradient → halve toward ``min_rank``.
    The EMA lives in :class:`RankController` (the schedule itself stays a
    frozen value object)."""

    min_rank: int = 1
    max_rank: int = 8
    init_rank: int = 4
    shrink_below: float = 0.35
    grow_above: float = 0.7
    every: int = 10
    ema: float = 0.8            # smoothing of the residual signal

    def __post_init__(self):
        assert 1 <= self.min_rank <= self.init_rank <= self.max_rank
        assert 0.0 <= self.shrink_below < self.grow_above

    def initial_rank(self) -> int:
        return self.init_rank

    @property
    def needs_residual(self) -> bool:
        return True

    def next_rank(self, step, current, residual=None) -> int:
        if residual is None or step == 0 or step % self.every:
            return current
        if residual > self.grow_above:
            return min(current * 2, self.max_rank)
        if residual < self.shrink_below:
            return max(current // 2, self.min_rank)
        return current


def parse_schedule(spec) -> RankSchedule:
    """Coerce a user-facing schedule spec into a :class:`RankSchedule`.

    Accepted forms (the string ones are what ``TrainHyper.rank_schedule``
    and the CLIs take):

    * a ``RankSchedule`` — returned as-is,
    * an int (or ``"4"``) — :class:`FixedRank`,
    * ``"4@0,2@60,1@120"`` — :class:`StaircaseRank` (``rank@step`` pairs),
    * ``"residual:min=1,max=8,init=4"`` — :class:`ResidualEnergyRank`
      (keys: min, max, init, shrink, grow, every; all optional).
    """
    if isinstance(spec, RankSchedule):
        return spec
    if isinstance(spec, int):
        return FixedRank(rank=spec)
    if isinstance(spec, (tuple, list)):
        return StaircaseRank(milestones=tuple((int(s), int(r))
                                              for s, r in spec))
    if not isinstance(spec, str):
        raise TypeError(f"cannot parse rank schedule from {spec!r}")
    s = spec.strip()
    if s.startswith("residual"):
        kw = {}
        keymap = {"min": "min_rank", "max": "max_rank", "init": "init_rank",
                  "shrink": "shrink_below", "grow": "grow_above",
                  "every": "every", "ema": "ema"}
        if ":" in s:
            for item in s.split(":", 1)[1].split(","):
                k, v = item.split("=")
                field = keymap[k.strip()]
                kw[field] = (float(v) if field in
                             ("shrink_below", "grow_above", "ema")
                             else int(v))
        return ResidualEnergyRank(**kw)
    if "@" in s:
        pairs = []
        for item in s.split(","):
            r, at = item.split("@")
            pairs.append((int(at), int(r)))
        pairs.sort()
        return StaircaseRank(milestones=tuple(pairs))
    return FixedRank(rank=int(s))


# ---------------------------------------------------------------------------
# Warm-start-preserving rank transitions
# ---------------------------------------------------------------------------


def transition_factor(q: jax.Array, new_rank: int,
                      key: jax.Array) -> jax.Array:
    """Move one warm-start factor ``(..., m, r)`` to ``(..., m, new_rank)``.

    Bit-consistency contract (pinned by ``tests/test_rank_schedule.py``):
    the retained columns are *exactly* the old ones — truncation keeps the
    leading ``new_rank`` columns (Gram–Schmidt orthogonalization processes
    columns in order, so the leading columns carry the dominant tracked
    directions), growth appends fresh i.i.d. N(0, 1) columns.  New columns
    are drawn once with shape ``(m, extra)`` and broadcast over any leading
    batch dims — layer-stack slices start from the same exploration
    directions (one power-iteration step individualizes them), and, more
    importantly, a stacked SimMesh worker dim stays bit-replicated.
    (Host-side drivers should transition the *unreplicated* state anyway —
    see :class:`RankController` — but broadcasting keeps the function safe
    under any leading stacking.)
    """
    r = q.shape[-1]
    if new_rank == r:
        return q
    if new_rank < r:
        return q[..., :new_rank]
    m = q.shape[-2]
    cols = jax.random.normal(key, (m, new_rank - r), dtype=q.dtype)
    cols = jnp.broadcast_to(cols, q.shape[:-2] + cols.shape)
    return jnp.concatenate([q, cols], axis=-1)


def transition_state(state, new_rank, key: jax.Array):
    """Tree version of :func:`transition_factor` (None leaves pass through).

    ``new_rank`` is an int (uniform switch — what a :class:`RankSchedule`
    issues) or a tree of per-leaf ints/None aligned with ``state`` (what
    :func:`repro.core.autotune.apply_plan` issues for per-bucket ranks; a
    None rank leaves that factor untouched).  Per-leaf keys derive from the
    tree path, so every worker computes identical new columns.
    """
    uniform = isinstance(new_rank, int)

    def leaf(path, q, *rest):
        if q is None:
            return None
        r = new_rank if uniform else rest[0]
        if r is None:
            return q
        return transition_factor(q, int(r), _leaf_key(key, path))

    if uniform:
        return jax.tree_util.tree_map_with_path(
            leaf, state, is_leaf=lambda x: x is None)
    return jax.tree_util.tree_map_with_path(
        leaf, state, new_rank, is_leaf=lambda x: x is None)


class RankController:
    """Host-side driver of a :class:`RankSchedule`.

    Call :meth:`update` once per optimization step, *before* the jitted
    step, with the upcoming step index (and the previous step's residual
    metric for residual-driven schedules).  Returns the (possibly
    transitioned) compressor state and whether a switch happened — a switch
    changes factor shapes, so the jitted train step simply retraces.

    Keeps the one piece of mutable policy state (the residual EMA) out of
    the frozen schedule objects.
    """

    def __init__(self, schedule, key: Optional[jax.Array] = None):
        self.schedule = parse_schedule(schedule)
        self.key = jax.random.key(17) if key is None else key
        self.rank = self.schedule.initial_rank()
        self._ema: Optional[float] = None
        self.history: list = [(0, self.rank)]  # (step, rank) switch log

    def observe(self, residual: Optional[float]) -> Optional[float]:
        if residual is None:
            return self._ema
        lam = getattr(self.schedule, "ema", 0.0)
        self._ema = (float(residual) if self._ema is None
                     else lam * self._ema + (1 - lam) * float(residual))
        return self._ema

    def update(self, comp_state, step: int,
               residual: Optional[float] = None):
        """-> (comp_state, changed).  ``comp_state`` must be unreplicated
        (no stacked worker dim) so fresh columns are shared by construction;
        re-replicate afterwards when driving a SimMesh run."""
        ema = self.observe(residual)
        new = int(self.schedule.next_rank(step, self.rank, ema))
        if new == self.rank:
            return comp_state, False
        self.key, sub = jax.random.split(self.key)
        comp_state = transition_state(comp_state, new, sub)
        self.rank = new
        self.history.append((step, new))
        return comp_state, True

    # -- fault-tolerant resume (checkpoint/train_state.py) ------------------
    # The controller is algorithm state: the current rank must agree with
    # the checkpointed factors' shapes, the residual EMA and the transition
    # PRNG key must continue their streams, and the switch history is the
    # audit log benchmarks report.  next_rank() is deterministic given
    # (step, current, ema), so a restored controller replays the remaining
    # schedule bit-exactly — including the N(0,1) columns a future growth
    # transition will draw from `key`.

    def state_dict(self) -> dict:
        """Msgpack-native snapshot for a checkpoint ``meta`` dict."""
        import numpy as np

        if jnp.issubdtype(self.key.dtype, jax.dtypes.prng_key):
            key_data, key_tag = jax.random.key_data(self.key), str(self.key.dtype)
        else:
            key_data, key_tag = self.key, "raw"
        return {
            "rank": int(self.rank),
            "ema": None if self._ema is None else float(self._ema),
            "history": [[int(s), int(r)] for s, r in self.history],
            "key_data": np.asarray(  # gradlint: disable=host-transfer
                key_data).astype(np.uint32).tolist(),
            "key_dtype": key_tag,
        }

    def load_state_dict(self, d: dict) -> "RankController":
        """Restore a :meth:`state_dict` snapshot (schedule comes from the
        constructor — the resuming run must be configured with the same
        schedule spec; drivers should verify that before calling)."""
        self.rank = int(d["rank"])
        self._ema = None if d["ema"] is None else float(d["ema"])
        self.history = [(int(s), int(r)) for s, r in d["history"]]
        key = jnp.asarray(d["key_data"], dtype=jnp.uint32)
        if d.get("key_dtype", "raw") != "raw":
            key = jax.random.wrap_key_data(key)
            if str(key.dtype) != d["key_dtype"]:
                raise ValueError(
                    f"RankController key impl mismatch: checkpoint "
                    f"{d['key_dtype']}, this process {key.dtype}")
        self.key = key
        return self


def init_state(cfg: PowerSGDConfig, shapes, specs, key: jax.Array):
    """Q ∈ R^{m×r} per matrix leaf, i.i.d. standard normal (Alg. 1 line 1)."""

    def init_leaf(path, shape_leaf, spec):
        ms = matrixize.matrix_shape(tuple(shape_leaf.shape), spec)
        if ms is None:
            return None
        batch_shape, _, m = ms
        k = _leaf_key(key, path)
        return jax.random.normal(k, batch_shape + (m, cfg.rank), dtype=cfg.dtype)

    return jax.tree_util.tree_map_with_path(
        init_leaf, shapes, specs, is_leaf=lambda x: x is None
    )


def _matmuls(cfg: PowerSGDConfig):
    """Return (project, backproject): P = M Q and Qn = Mᵀ P̂ on (..., n, m)."""
    if cfg.use_pallas:
        from repro.kernels import ops  # lazy: optional dependency direction

        return ops.lowrank_project, ops.lowrank_backproject
    project = lambda m, q: jnp.einsum("...nm,...mr->...nr", m, q)
    backproject = lambda m, p: jnp.einsum("...nm,...nr->...mr", m, p)
    return project, backproject


def compress_aggregate(
    cfg: PowerSGDConfig,
    deltas,                      # tree of update tensors (grad + error)
    state,                       # tree of Q factors (or None per leaf)
    specs,
    ctx: MeshCtx = SINGLE,
    key: Optional[jax.Array] = None,
    partition=None,              # optional StatePartition tree (see
    #                              state_partition): lets the engine mark
    #                              which buckets hold model-sharded/-local
    #                              factors
) -> PowerSGDOut:
    if cfg.bucketing in ("auto", "on"):
        return _compress_aggregate_bucketed(cfg, deltas, state, specs, ctx,
                                            key, partition=partition)
    if cfg.bucketing != "off":
        raise ValueError(
            f"unknown bucketing mode {cfg.bucketing!r}; use 'auto', 'on' or 'off'")
    orth = get_orthogonalizer(cfg.orthogonalizer)
    project, backproject = _matmuls(cfg)
    floats_sent = [0]
    res_num, res_den = [], []  # per-leaf squared Frobenius norms (traced)

    def leaf(path, g, q, spec):
        if q is None:  # uncompressed (vector) leaf — paper's bias rule
            agg = ctx.pmean_data(g)
            floats_sent[0] += matrixize.uncompressed_floats(g.shape)
            return agg, g, None

        mat = matrixize.to_matrix(g, spec).astype(cfg.dtype)
        if not cfg.warm_start:
            k = _leaf_key(key, path)
            q = jax.random.normal(k, q.shape, dtype=cfg.dtype)

        n_iter = max(1, cfg.num_iters)
        for it in range(n_iter):
            p = project(mat, q)                    # (..., n, r)
            p = ctx.pmean_data(p)
            p_hat = orth(p)
            q_local = backproject(mat, p_hat)      # (..., m, r)
            q = ctx.pmean_data(q_local)

        agg_mat = jnp.einsum("...nr,...mr->...nm", p_hat, q)
        if cfg.error_mode == "local":
            recon_mat = jnp.einsum("...nr,...mr->...nm", p_hat, q_local)
        else:
            recon_mat = agg_mat
        # active rank is state-carried: bits follow this leaf's factor
        floats_sent[0] += matrixize.compressed_floats(g.shape, spec,
                                                      q.shape[-1])
        if cfg.track_residual:
            res_num.append(jnp.sum(jnp.square(mat - agg_mat)))
            res_den.append(jnp.sum(jnp.square(mat)))

        agg = matrixize.from_matrix(agg_mat, g.shape, spec).astype(g.dtype)
        recon = matrixize.from_matrix(recon_mat, g.shape, spec).astype(g.dtype)
        return agg, recon, q

    triples = jax.tree_util.tree_map_with_path(
        leaf, deltas, state, specs, is_leaf=lambda x: x is None
    )
    # tree_map_with_path mapped over `deltas`' structure; unzip the 3-tuples
    agg = jax.tree_util.tree_map(lambda t: t[0], triples, is_leaf=lambda x: isinstance(x, tuple))
    recon = jax.tree_util.tree_map(lambda t: t[1], triples, is_leaf=lambda x: isinstance(x, tuple))
    new_state = jax.tree_util.tree_map(lambda t: t[2], triples, is_leaf=lambda x: isinstance(x, tuple))
    metrics = None
    if cfg.track_residual and res_num:
        metrics = {"residual_ratio": _residual_ratio(sum(res_num),
                                                     sum(res_den))}
    return PowerSGDOut(agg=agg, recon=recon, state=new_state,
                       bits_per_worker=floats_sent[0] * 32, metrics=metrics)


def _residual_ratio(num_sq, den_sq):
    """sqrt(Σ‖M − P̂Qᵀ‖² / Σ‖M‖²) with a guarded denominator."""
    return jnp.sqrt(num_sq / jnp.maximum(den_sq, jnp.finfo(jnp.float32).tiny))


def _compress_aggregate_bucketed(
    cfg: PowerSGDConfig,
    deltas,
    state,
    specs,
    ctx: MeshCtx = SINGLE,
    key: Optional[jax.Array] = None,
    partition=None,
) -> PowerSGDOut:
    """Batched power iteration over shape buckets, 2 collectives per iter.

    Same math as the per-leaf path (see module docstring).  Pack / fuse /
    scatter is the transport engine's job (:class:`engine.MatrixPayloads`
    plans and packs the bucket slabs, :class:`engine.Transport` fuses the
    per-phase all-reduces into one flat wire collective each); this function
    is only the PowerSGD math — project, orthogonalize, back-project —
    scheduled between the two transport phases.  Uncompressed (vector)
    leaves ride along in the first fused collective.  State layout is
    identical to the per-leaf path (per-leaf Q factors), so the two paths
    are freely interchangeable mid-run.
    """
    orth = get_orthogonalizer(cfg.orthogonalizer)
    project, backproject = _matmuls(cfg)
    n_iter = max(1, cfg.num_iters)

    # ranks are read off the state's factors (per bucket, possibly mixed —
    # a RankSchedule or autotune plan moves them between steps)
    payloads = engine.MatrixPayloads.build(
        deltas, state, specs, dtype=cfg.dtype,
        tolerance=cfg.bucket_pad_tolerance,
        resample_key=None if cfg.warm_start else key,
        partition=partition)
    transport_cls = (engine.PipelinedTransport if cfg.pipeline
                     else engine.Transport)
    transport = transport_cls(ctx=ctx, wire_dtype=cfg.wire_dtype,
                              max_chunk_bytes=cfg.max_chunk_bytes)
    m_bufs, q_bufs = payloads.m_bufs, payloads.q_bufs

    # -- power iteration: 2 fused collectives per round ---------------------
    # Under sync_mode="broadcast" the per-phase reduces run in the canonical
    # deterministic order but defer the replica-sync guarantee (sync=False)
    # to ONE fused rank-0 broadcast of everything the cross-step state and
    # the update are computed from — P̂, Q and the uncompressed aggregates —
    # keeping the per-step budget at 2 reduces + 1 broadcast.
    synced = ctx.sync_mode == "broadcast" and bool(ctx.data_axes)
    unc_agg = payloads.unc_values  # identity if no uncompressed leaves
    p_hats = q_locals = []
    for it in range(n_iter):
        p_locals = [project(mb, qb) for mb, qb in zip(m_bufs, q_bufs)]
        extra = unc_agg if it == 0 else []
        reduced = transport.reduce_mean(p_locals + extra, sync=False)
        p_bufs = reduced[:len(p_locals)]
        if it == 0:
            unc_agg = reduced[len(p_locals):]
        p_hats = [orth(p) for p in p_bufs]
        q_locals = [backproject(mb, ph) for mb, ph in zip(m_bufs, p_hats)]
        q_bufs = transport.reduce_mean(q_locals, sync=False)

    if synced:
        flat = transport.broadcast(p_hats + q_bufs + unc_agg)
        p_hats = flat[:len(p_hats)]
        q_bufs = flat[len(p_hats):len(p_hats) + len(q_bufs)]
        unc_agg = flat[len(p_hats) + len(q_bufs):]

    agg_bufs = [jnp.einsum("bnr,bmr->bnm", ph, qb)
                for ph, qb in zip(p_hats, q_bufs)]
    if cfg.error_mode == "local":
        recon_bufs = [jnp.einsum("bnr,bmr->bnm", ph, ql)
                      for ph, ql in zip(p_hats, q_locals)]
    else:
        recon_bufs = agg_bufs

    metrics = None
    if cfg.track_residual and payloads.m_bufs:
        # per-bucket residual energy: the signal ResidualEnergyRank and the
        # autotuner consume (padding contributes exact zeros to both norms)
        nums = [jnp.sum(jnp.square(mb - ab))
                for mb, ab in zip(payloads.m_bufs, agg_bufs)]
        dens = [jnp.sum(jnp.square(mb)) for mb in payloads.m_bufs]
        metrics = {
            "residual_ratio": _residual_ratio(sum(nums), sum(dens)),
            "bucket_residual_ratio": jnp.stack(
                [_residual_ratio(n_, d_) for n_, d_ in zip(nums, dens)]),
        }

    agg, recon, new_state = payloads.scatter(agg_bufs, recon_bufs, q_bufs,
                                             unc_agg)
    return PowerSGDOut(agg=agg, recon=recon, state=new_state,
                       bits_per_worker=payloads.bits, metrics=metrics)


def compressed_floats_total(shapes, specs, rank) -> int:
    """Analytic bytes-per-all-reduce accounting (paper Tables 3/10/11).

    ``rank`` is an int (the paper's static-rank setting) *or* a compressor
    state tree aligned with ``shapes`` (per-leaf Q factors, or None for
    uncompressed leaves): with a state tree each leaf is charged at its own
    active rank — the honest accounting once a :class:`RankSchedule` or the
    autotuner has moved ranks per bucket.
    """
    total = [0]

    if isinstance(rank, int):
        def leaf(shape_leaf, spec):
            total[0] += matrixize.compressed_floats(
                tuple(shape_leaf.shape), spec, rank)

        jax.tree_util.tree_map(leaf, shapes, specs)
        return total[0]

    def leaf_state(shape_leaf, spec, q):
        r = 0 if q is None else q.shape[-1]
        total[0] += matrixize.compressed_floats(
            tuple(shape_leaf.shape), spec, r)

    jax.tree_util.tree_map(leaf_state, shapes, specs, rank,
                           is_leaf=lambda x: x is None)
    return total[0]
