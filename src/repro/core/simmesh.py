"""SimMesh — deterministic in-process W-worker simulation substrate.

The paper's core claims (Algorithm 2's per-worker error feedback, Appendix
A.3 linearity, all-reduce aggregation of the compressed factors) are
W-worker properties.  Exercising them through real multi-device meshes needs
subprocesses with faked XLA device counts — minutes per scenario, and shapes
like "worker 3 dropped this round" or "worker 0 has a bigger batch" are not
expressible at all.  ``SimMesh`` instead runs W *logical* workers in one
process on one device:

* every per-worker value (params copy, gradients, EF error buffer, batch
  shard) carries a stacked leading worker dimension of size W,
* the whole train step runs under ``jax.vmap(..., axis_name=self.axis)``
  over that dimension (:meth:`SimMesh.run`),
* ``MeshCtx`` collectives dispatch through a :class:`~repro.core.dist.
  SimBackend`, so ``pmean_data`` / ``pmean_flat`` lower to exact means/sums
  over the stacked axis — the same compressor code path as production,
  bit-deterministic on a single CPU device, with ``CollectiveStats``
  counting unchanged.

Scenario injection: :meth:`SimMesh.ctx` accepts a per-worker scalar
``weight`` (a traced value inside the step).  Weights model heterogeneous
per-worker batch sizes (weight ∝ local token count), worker dropout and
straggler-skipped rounds (weight 0 for the affected worker/round); see
:class:`repro.core.dist.SimBackend` for the exact semantics.

The conformance suite under ``tests/sim/`` replays the paper's W-worker
invariants on this substrate in seconds.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dist import CollectiveStats, MeshCtx, SimBackend


@dataclasses.dataclass(frozen=True)
class SimMesh:
    """W logical data-parallel workers simulated in one process.

    ``axis`` is the vmap axis name the worker dimension is mapped under; it
    plays the role of the production mesh's ``data`` axis, so a ``SimMesh``
    context has ``data_axes=(axis,)`` and no model/seq axes (tensor
    parallelism is orthogonal to what the simulator isolates: the paper's
    linearity argument applies per model shard).
    """

    workers: int
    axis: str = "simworker"

    def __post_init__(self):
        assert self.workers >= 1, self.workers

    # -- contexts -----------------------------------------------------------
    def ctx(self, weight: Optional[jax.Array] = None,
            stats: Optional[CollectiveStats] = None,
            sync_mode: str = "allreduce") -> MeshCtx:
        """A :class:`MeshCtx` for code running inside :meth:`run`.

        ``weight`` — this worker's scalar contribution weight (traced, one
        per worker under the vmap); ``None`` = uniform (plain means).
        Construct the context *inside* the mapped function so a traced
        weight binds to the right trace.

        ``sync_mode="broadcast"`` selects the canonical deterministic
        reduction order (see :class:`~repro.core.dist.MeshCtx`) — on this
        substrate collectives are already bit-deterministic, but the
        canonical order makes every *collective result* bit-identical to a
        ``shard_map`` run in the same mode.  Whole training steps still
        differ at the ULP level between the two substrates (XLA lowers the
        vmapped compute differently); the cross-substrate equivalence suite
        (``tests/subprocess_scripts/check_drift.py``, ``equiv`` phase) pins
        that envelope at ~5e-7 after 8 steps.
        """
        return MeshCtx(
            data_axes=(self.axis,),
            sync_mode=sync_mode,
            stats=stats,
            backend=SimBackend(axis=self.axis, size=self.workers,
                               weight=weight),
        )

    # -- execution ----------------------------------------------------------
    def run(self, fn, in_axes=0, out_axes=0):
        """``jax.vmap`` over the stacked worker dimension with this mesh's
        axis name.  ``in_axes=None`` marks arguments shared by all workers
        (e.g. the PRNG key — compressors rely on shared seeds)."""
        return jax.vmap(fn, in_axes=in_axes, out_axes=out_axes,
                        axis_name=self.axis)

    # -- data movement ------------------------------------------------------
    def replicate(self, tree):
        """Stack W identical copies of every leaf: shape → (W,) + shape."""
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(
                jnp.asarray(x)[None],
                (self.workers,) + jnp.asarray(x).shape),
            tree)

    def unreplicate(self, tree):
        """Take worker 0's copy of every leaf (inverse of replicate for
        values that are identical across workers, e.g. post-all-reduce)."""
        return jax.tree_util.tree_map(lambda x: x[0], tree)

    def shard(self, tree):
        """Split every leaf's leading (global batch) dim W ways:
        (W·b, ...) → (W, b, ...).  The W-worker analogue of
        :func:`repro.data.synthetic.shard_batch`."""

        def leaf(x):
            x = jnp.asarray(x)
            n = x.shape[0]
            assert n % self.workers == 0, (n, self.workers)
            return x.reshape((self.workers, n // self.workers) + x.shape[1:])

        return jax.tree_util.tree_map(leaf, tree)

    def assert_replicated(self, tree, what: str = "tree"):
        """Host-side check that every leaf is bit-identical across workers —
        the sync invariant of data-parallel SGD (params after an all-reduced
        update must agree on every worker)."""
        import numpy as np

        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            a = np.asarray(leaf)  # gradlint: disable=host-transfer
            if not (a == a[:1]).all():
                raise AssertionError(
                    f"{what}{jax.tree_util.keystr(path)} diverges across "
                    f"workers (max |Δ| = {np.abs(a - a[:1]).max()})")
