"""Full language model: embeddings / modality frontends → block stack →
final norm → vocab-sharded head, plus loss, decode and prefill entry points.

This is the composable model definition every config instantiates; the
launcher wraps these functions in ``shard_map`` and the smoke tests call them
directly with the single-device :data:`repro.core.dist.SINGLE` context.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.dist import MeshCtx, SINGLE
from repro.core.matrixize import MatrixSpec, NONE as SPEC_NONE
from repro.models import attention, blocks, common
from repro.configs.base import ModelConfig


def padded_vocab(cfg: ModelConfig, model_shards: int) -> int:
    v = cfg.vocab_size
    return ((v + model_shards - 1) // model_shards) * model_shards


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------

def init(key, cfg: ModelConfig, model_shards: int = 1):
    dtype = cfg.jnp_dtype()
    ke, kb, kh, kp = jax.random.split(key, 4)
    vp = padded_vocab(cfg, model_shards)
    params: Dict[str, Any] = {
        "embed": common.embed_init(ke, vp, cfg.d_model, dtype),
        "blocks": blocks.init(kb, cfg, model_shards, dtype),
        "final_norm": common.rmsnorm_init(cfg.d_model, dtype),
        "head": common.dense_init(kh, (cfg.d_model, vp), cfg.d_model, dtype),
    }
    if cfg.frontend == "vision":
        params["frontend_proj"] = common.dense_init(
            kp, (cfg.frontend_dim, cfg.d_model), cfg.frontend_dim, dtype)
    return params


def pspecs(cfg: ModelConfig):
    s = {
        "embed": P("model", None),
        "blocks": blocks.pspecs(cfg),
        "final_norm": P(None),
        "head": P(None, "model"),
    }
    if cfg.frontend == "vision":
        s["frontend_proj"] = P(None, None)
    return s


def mspecs(cfg: ModelConfig):
    s = {
        "embed": MatrixSpec("matrix", 0),
        "blocks": blocks.mspecs(cfg),
        "final_norm": SPEC_NONE,
        "head": MatrixSpec("matrix", 0),
    }
    if cfg.frontend == "vision":
        s["frontend_proj"] = MatrixSpec("matrix", 0)
    return s


# ---------------------------------------------------------------------------
# input embedding (tokens and/or frontend-stub embeddings)
# ---------------------------------------------------------------------------

def embed_inputs(params, batch, cfg: ModelConfig, ctx: MeshCtx):
    """batch: {"tokens": (B,S) int32} and, for VLMs,
    {"patches": (B, S_img, frontend_dim)} — patches occupy the sequence
    prefix (anyres tiles), text tokens follow."""
    x = common.embed_lookup(params["embed"], batch["tokens"], ctx)
    if cfg.frontend == "vision" and "patches" in batch:
        proj = batch["patches"].astype(x.dtype) @ params["frontend_proj"]
        x = jnp.concatenate([proj, x], axis=1)
    return x


# ---------------------------------------------------------------------------
# train forward + loss
# ---------------------------------------------------------------------------

def loss_fn(params, batch, cfg: ModelConfig, ctx: MeshCtx = SINGLE, *,
            window: int = 0, q_chunk: int = 512, remat: bool = True,
            unroll: int = 1):
    """batch: tokens (B,S), labels (B,S) [-1 = masked], optional patches.

    Returns (loss, metrics).  The loss is the mean over this worker's local
    tokens — exactly the per-worker stochastic gradient PowerSGD expects."""
    x = embed_inputs(params, batch, cfg, ctx)
    x, moe_aux = blocks.forward(params["blocks"], x, cfg, ctx,
                                window=window, q_chunk=q_chunk, remat=remat,
                                unroll=unroll)
    x = common.rmsnorm(x, params["final_norm"])

    labels = batch["labels"]
    if cfg.frontend == "vision" and "patches" in batch:
        # patches carry no LM loss; score only the text suffix
        n_img = batch["patches"].shape[1]
        x = x[:, n_img:]
    logits_local = common.grad_synced(x, ctx) @ params["head"]
    tok_loss = common.sharded_softmax_xent(logits_local, labels, ctx, cfg.vocab_size)
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(tok_loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + cfg.moe_aux_weight * moe_aux
    return total, {"lm_loss": loss, "moe_aux": moe_aux}


# ---------------------------------------------------------------------------
# decode / prefill
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, model_shards: int, batch_local: int,
               seq_local: int, dtype=jnp.float32):
    return blocks.init_cache(cfg, model_shards, batch_local, seq_local, dtype)


def decode_step(params, cache, tokens, pos, cfg: ModelConfig,
                ctx: MeshCtx = SINGLE, *, window: int = 0, unroll: int = 1):
    """tokens: (B, 1) int32; pos: scalar int32 — position being generated.

    Returns (next_token (B,1) int32, logits (B,1,vocab_pad), new_cache)."""
    x = common.embed_lookup(params["embed"], tokens, ctx)
    x, new_cache = blocks.decode(params["blocks"], cache, x, pos, cfg, ctx,
                                 window=window, unroll=unroll)
    x = common.rmsnorm(x, params["final_norm"])
    logits_local = x @ params["head"]
    logits = ctx.all_gather_model(logits_local, axis=-1)
    nxt = jnp.argmax(logits[..., : cfg.vocab_size], axis=-1).astype(jnp.int32)
    return nxt, logits, new_cache


def prefill_step(params, batch, cfg: ModelConfig, ctx: MeshCtx = SINGLE, *,
                 window: int = 0, q_chunk: int = 512, unroll: int = 1):
    """Run the prompt through the stack, returning (last_logits, cache)."""
    x = embed_inputs(params, batch, cfg, ctx)
    x, cache = blocks.prefill(params["blocks"], x, cfg, ctx,
                              window=window, q_chunk=q_chunk, unroll=unroll)
    x = common.rmsnorm(x[:, -1:, :], params["final_norm"])
    logits_local = x @ params["head"]
    logits = ctx.all_gather_model(logits_local, axis=-1)
    return logits, cache
