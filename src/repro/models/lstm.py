"""Multi-layer LSTM language model (the paper's WikiText-2 benchmark, §5.3).

Paper configuration (Appendix F, Table 11): vocab 28869, embedding 650,
3 layers of hidden 650.  Weight matrices W_ih (4h × in) and W_hh (4h × h)
are the compression targets; biases fall under the bias rule.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.matrixize import MatrixSpec, NONE as SPEC_NONE


@dataclasses.dataclass(frozen=True)
class LSTMConfig:
    vocab: int = 28869
    embed: int = 650
    hidden: int = 650
    layers: int = 3
    init_scale: float = 0.05   # encoder init std (tied decoder scales with it)


def paper_lstm() -> LSTMConfig:
    return LSTMConfig()


def init(key, cfg: LSTMConfig):
    keys = iter(jax.random.split(key, 3 + 2 * cfg.layers))
    params = {"encoder": jax.random.normal(next(keys), (cfg.vocab, cfg.embed)) * cfg.init_scale}
    for l in range(cfg.layers):
        d_in = cfg.embed if l == 0 else cfg.hidden
        params[f"rnn_ih_l{l}"] = jax.random.normal(
            next(keys), (4 * cfg.hidden, d_in)) / math.sqrt(d_in)
        params[f"rnn_hh_l{l}"] = jax.random.normal(
            next(keys), (4 * cfg.hidden, cfg.hidden)) / math.sqrt(cfg.hidden)
        params[f"bias_l{l}"] = jnp.zeros((4 * cfg.hidden,))
    # decoder is weight-tied to the encoder (paper Table 11 lists only the
    # encoder matrix; total 110 MB ⇒ tied embeddings, as in the PyTorch
    # word_language_model recipe the paper builds on)
    params["decoder_b"] = jnp.zeros((cfg.vocab,))
    return params


def mspecs(params):
    def leaf(path, p):
        return MatrixSpec("matrix", 0) if p.ndim >= 2 else SPEC_NONE

    return jax.tree_util.tree_map_with_path(leaf, params)


def _lstm_layer(x, w_ih, w_hh, bias, h0, c0):
    """x: (B, S, d_in) → (B, S, h)."""
    hdim = w_hh.shape[1]

    def step(carry, xt):
        h, c = carry
        gates = xt @ w_ih.T + h @ w_hh.T + bias
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    (_, _), hs = lax.scan(step, (h0, c0), x.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2)


def forward(params, tokens, cfg: LSTMConfig):
    b = tokens.shape[0]
    x = jnp.take(params["encoder"], tokens, axis=0)
    for l in range(cfg.layers):
        h0 = jnp.zeros((b, cfg.hidden))
        x = _lstm_layer(x, params[f"rnn_ih_l{l}"], params[f"rnn_hh_l{l}"],
                        params[f"bias_l{l}"], h0, h0)
    return x @ params["encoder"].T + params["decoder_b"]


def loss_fn(params, batch, cfg: LSTMConfig):
    logits = forward(params, batch["tokens"], cfg)
    logp = jax.nn.log_softmax(logits)
    ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    loss = -jnp.mean(ll)
    return loss, {"loss": loss, "ppl": jnp.exp(loss)}
