"""GQA attention with Megatron-style tensor parallelism, chunked (flash-like)
causal attention for train/prefill, and a sequence-sharded KV cache with
logsumexp merging for decode.

Sharding:
  * Q heads are padded to a multiple of ``model_shards`` and column-split;
    padded heads are masked out of the output (their params receive zero
    gradient and never train).
  * K/V projections are column-split as plain matrices (not head-aligned)
    and all-gathered over the model axis before attention — the standard
    Megatron treatment when ``num_kv_heads < tp`` (uniform path here; the
    kv-head-sharded variant is a hill-climb optimization).
  * The decode KV cache is sharded over ``ctx.seq_axes``; each shard attends
    its local chunk and partial softmaxes merge via pmax/psum (flash-decode).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.dist import MeshCtx
from repro.core.matrixize import MatrixSpec, NONE as SPEC_NONE
from repro.models import common
from repro.configs.base import ModelConfig

NEG_INF = -1e30


def padded_heads(cfg: ModelConfig, model_shards: int) -> int:
    h = cfg.num_heads
    return ((h + model_shards - 1) // model_shards) * model_shards


def kv_map(cfg: ModelConfig, model_shards: int):
    """Static q-head → kv-head index map over the padded head range."""
    group = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
    return [min(i, cfg.num_heads - 1) // group for i in range(padded_heads(cfg, model_shards))]


def init(key, cfg: ModelConfig, model_shards: int, dtype=jnp.float32):
    hd = cfg.resolved_head_dim
    hp = padded_heads(cfg, model_shards)
    d = cfg.d_model
    kq, kk, kv_, ko = jax.random.split(key, 4)
    params = {
        "wq": common.dense_init(kq, (d, hp * hd), d, dtype),
        "wk": common.dense_init(kk, (d, cfg.num_kv_heads * hd), d, dtype),
        "wv": common.dense_init(kv_, (d, cfg.num_kv_heads * hd), d, dtype),
        "wo": common.dense_init(ko, (hp * hd, d), hp * hd, dtype),
    }
    if cfg.qk_norm:
        params["q_norm"] = common.rmsnorm_init(hd, dtype)
        params["k_norm"] = common.rmsnorm_init(hd, dtype)
    return params


def pspecs(cfg: ModelConfig):
    s = {
        "wq": P(None, "model"),
        "wk": P(None, "model"),
        "wv": P(None, "model"),
        "wo": P("model", None),
    }
    if cfg.qk_norm:
        s["q_norm"] = P(None)
        s["k_norm"] = P(None)
    return s


def mspecs(cfg: ModelConfig):
    s = {k: MatrixSpec("matrix", 0) for k in ("wq", "wk", "wv", "wo")}
    if cfg.qk_norm:
        s["q_norm"] = SPEC_NONE
        s["k_norm"] = SPEC_NONE
    return s


# ---------------------------------------------------------------------------
# train / prefill forward
# ---------------------------------------------------------------------------

def forward(params, x, cfg: ModelConfig, ctx: MeshCtx, *, q_chunk: int = 512,
            window: int = 0):
    """Causal self-attention. x: (B, S, d) replicated over the model axis.

    ``window`` > 0 enables sliding-window attention (sub-quadratic)."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    hl = params["wq"].shape[1] // hd          # local (padded) head count
    scale = 1.0 / math.sqrt(hd)

    shards = ctx.model_size()
    group = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
    local_kv = (cfg.tp_local_kv and cfg.num_kv_heads % shards == 0
                and cfg.num_heads % shards == 0)

    # replicated x enters the column-parallel projections here: identity
    # forward, psum(model) on the backward cotangent (see common.grad_synced)
    x = common.grad_synced(x, ctx)

    q = (x @ params["wq"]).reshape(b, s, hl, hd)
    if local_kv:
        # kv heads shard evenly: shard m owns q heads [m·hl, (m+1)·hl) and
        # kv heads [m·kvl, (m+1)·kvl) with hl = group·kvl, so every local q
        # head's kv head is local — no all-gather.
        kvl = cfg.num_kv_heads // shards
        k = (x @ params["wk"]).reshape(b, s, kvl, hd)
        v = (x @ params["wv"]).reshape(b, s, kvl, hd)
    else:
        k = ctx.all_gather_model(x @ params["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
        v = ctx.all_gather_model(x @ params["wv"]).reshape(b, s, cfg.num_kv_heads, hd)

    if cfg.qk_norm:
        q = common.rmsnorm(q, params["q_norm"])
        k = common.rmsnorm(k, params["k_norm"])

    positions = jnp.arange(s)
    q = common.apply_rope(q, positions[None, :], cfg.rope_theta)
    k = common.apply_rope(k, positions[None, :], cfg.rope_theta)

    # map local q heads to kv heads (global head id depends on the shard)
    head0 = ctx.model_index() * hl
    gheads = head0 + jnp.arange(hl)
    if local_kv:
        kv_idx = jnp.arange(hl) // group       # local kv index
    else:
        kv_idx = jnp.minimum(gheads, cfg.num_heads - 1) // group
    k_h = jnp.take(k, kv_idx, axis=2)          # (B, S, hl, hd)
    v_h = jnp.take(v, kv_idx, axis=2)

    qc = min(q_chunk, s)
    n_chunks = (s + qc - 1) // qc
    s_pad = n_chunks * qc
    q_padded = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    q_chunks = q_padded.reshape(b, n_chunks, qc, hl, hd).transpose(1, 0, 2, 3, 4)

    if window and window < s:
        out_chunks = _windowed_chunks(q_chunks, k_h, v_h, qc, window, scale)
    else:
        out_chunks = _full_chunks(q_chunks, k_h, v_h, qc, scale)

    out = out_chunks.transpose(1, 0, 2, 3, 4).reshape(b, s_pad, hl, hd)[:, :s]
    # mask padded heads so they contribute nothing (and get no gradient)
    out = jnp.where((gheads < cfg.num_heads)[None, None, :, None], out, 0.0)
    out = out.reshape(b, s, hl * hd)
    return ctx.psum_model(out @ params["wo"])


def _full_chunks(q_chunks, k, v, qc, scale):
    s = k.shape[1]

    def one(carry, args):
        i, qck = args
        # scores: (B, hl, qc, S)
        scores = jnp.einsum("bqhd,bkhd->bhqk", qck, k) * scale
        qpos = i * qc + jnp.arange(qc)
        kpos = jnp.arange(s)
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
        return carry, out

    _, outs = lax.scan(one, None, (jnp.arange(q_chunks.shape[0]), q_chunks))
    return outs


def _windowed_chunks(q_chunks, k, v, qc, window, scale):
    """Sliding-window: each q chunk attends a static (window+qc)-wide kv slice."""
    s = k.shape[1]
    wpad = ((window + qc - 1) // qc) * qc      # align slice starts
    kv_span = wpad + qc
    # left-pad K/V so every chunk can take a static-size slice
    kp = jnp.pad(k, ((0, 0), (wpad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (wpad, 0), (0, 0), (0, 0)))

    def one(carry, args):
        i, qck = args
        start = i * qc  # in padded coords this is (i*qc + wpad) - wpad
        ks = lax.dynamic_slice_in_dim(kp, start, kv_span, axis=1)
        vs = lax.dynamic_slice_in_dim(vp, start, kv_span, axis=1)
        scores = jnp.einsum("bqhd,bkhd->bhqk", qck, ks) * scale
        qpos = i * qc + jnp.arange(qc)                       # global q positions
        kpos = start + jnp.arange(kv_span) - wpad            # global kv positions
        mask = (qpos[:, None] >= kpos[None, :]) & \
               (qpos[:, None] - kpos[None, :] < window) & (kpos[None, :] >= 0)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vs)
        return carry, out

    _, outs = lax.scan(one, None, (jnp.arange(q_chunks.shape[0]), q_chunks))
    return outs


# ---------------------------------------------------------------------------
# decode with a sequence-sharded KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_local: int, seq_local: int,
               dtype=jnp.float32):
    """Local KV cache slice for one attention layer (unstacked)."""
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch_local, seq_local, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch_local, seq_local, cfg.num_kv_heads, hd), dtype),
    }


def cache_pspecs(batch_axes, seq_axes) -> dict:
    ba = batch_axes if batch_axes else None
    sa = seq_axes if seq_axes else None
    return {"k": P(ba, sa, None, None), "v": P(ba, sa, None, None)}


def decode(params, x, cache, pos, cfg: ModelConfig, ctx: MeshCtx, *,
           window: int = 0):
    """One-token decode. x: (B_local, 1, d) replicated over model & seq axes.

    cache k/v: (B_local, S_local, kv, hd), seq-sharded over ``ctx.seq_axes``.
    ``pos``: scalar int32 — the position of the new token.
    Returns (attn_out (B,1,d), new_cache)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    hl = params["wq"].shape[1] // hd
    hp = hl * ctx.model_size() if ctx.model_axis else hl
    scale = 1.0 / math.sqrt(hd)
    s_local = cache["k"].shape[1]

    # --- project the new token; gather full heads on every shard -----------
    q = ctx.all_gather_model(x @ params["wq"]).reshape(b, 1, hp, hd)
    k_new = ctx.all_gather_model(x @ params["wk"]).reshape(b, 1, cfg.num_kv_heads, hd)
    v_new = ctx.all_gather_model(x @ params["wv"]).reshape(b, 1, cfg.num_kv_heads, hd)

    if cfg.qk_norm:
        q = common.rmsnorm(q, params["q_norm"])
        k_new = common.rmsnorm(k_new, params["k_norm"])

    posv = jnp.full((1, 1), pos)
    q = common.apply_rope(q, posv, cfg.rope_theta)[:, 0]          # (B, hp, hd)
    k_new = common.apply_rope(k_new, posv, cfg.rope_theta)        # roped at abs pos

    # --- write the new kv into the owning shard's slot ---------------------
    cache_len = s_local * max(ctx.seq_size(), 1)
    slot = pos % cache_len if window else pos                     # ring vs linear
    owner = slot // s_local
    offset = slot % s_local
    mine = owner == ctx.seq_index()
    k_upd = lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), offset, axis=1)
    v_upd = lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), offset, axis=1)
    new_cache = {
        "k": jnp.where(mine, k_upd, cache["k"]),
        "v": jnp.where(mine, v_upd, cache["v"]),
    }

    # --- attend over the local chunk, merge partial softmaxes --------------
    kv = cfg.num_kv_heads
    grouped = (cfg.gqa_grouped_decode and hp == cfg.num_heads
               and cfg.num_heads % max(kv, 1) == 0)
    if grouped:
        # GQA-aware: group q heads by kv head in the contraction instead of
        # materializing the cache expanded to every q head (saves
        # group_size× the kv-cache read traffic per token)
        g = cfg.num_heads // kv
        qg = q.reshape(b, kv, g, hd)
        scores = jnp.einsum(
            "bkgd,bskd->bkgs", qg,
            new_cache["k"].astype(q.dtype)) * scale
        scores = scores.reshape(b, hp, s_local)
    else:
        kvm = jnp.asarray(kv_map(cfg, 1 if not ctx.model_axis else ctx.model_size()))
        kvm = kvm[:hp]
        k_loc = jnp.take(new_cache["k"], kvm, axis=2)   # (B, S_local, hp, hd)
        v_loc = jnp.take(new_cache["v"], kvm, axis=2)

        scores = jnp.einsum("bhd,bkhd->bhk", q, k_loc.astype(q.dtype)) * scale

    slots_g = ctx.seq_index() * s_local + jnp.arange(s_local)
    if window:
        stored = pos - ((pos - slots_g) % cache_len)
        valid = stored >= 0
    else:
        valid = slots_g <= pos
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)

    m_loc = jnp.max(scores, axis=-1)                             # (B, hp)
    m_glob = ctx.pmax_seq(m_loc)
    p = jnp.exp(scores - m_glob[..., None])
    l_loc = jnp.sum(p, axis=-1)
    if grouped:
        g = cfg.num_heads // kv
        o_loc = jnp.einsum("bkgs,bskd->bkgd", p.reshape(b, kv, g, s_local),
                           new_cache["v"].astype(p.dtype)).reshape(b, hp, hd)
    else:
        o_loc = jnp.einsum("bhk,bkhd->bhd", p, v_loc.astype(p.dtype))
    l_glob = ctx.psum_seq(l_loc)
    o_glob = ctx.psum_seq(o_loc)
    out = o_glob / jnp.maximum(l_glob[..., None], 1e-30)          # (B, hp, hd)

    out = jnp.where((jnp.arange(hp) < cfg.num_heads)[None, :, None], out, 0.0)
    out = out.reshape(b, 1, hp * hd)

    # row-parallel wo: local rows = this shard's slice of the head dim
    rows = params["wo"].shape[0]
    start = ctx.model_index() * rows
    out_slice = lax.dynamic_slice_in_dim(out, start, rows, axis=-1)
    return ctx.psum_model(out_slice @ params["wo"]), new_cache


# ---------------------------------------------------------------------------
# prefill: run the chunked forward AND emit the cache slice for this shard
# ---------------------------------------------------------------------------

def prefill(params, x, cfg: ModelConfig, ctx: MeshCtx, *, q_chunk: int = 512,
            window: int = 0):
    """Forward over the prompt, returning (out, cache_slice).

    The cache slice holds this shard's s_local = S/seq_shards chunk of the
    roped K/V (full kv heads), matching the decode layout."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim

    seq_shards = max(ctx.seq_size(), 1)
    s_local = s // seq_shards
    start = ctx.seq_index() * s_local

    if cfg.tp_local_kv and ctx.model_axis and seq_shards == ctx.model_size():
        # perf: the cache wants row (sequence) distribution of X·W_kv while
        # TP computes its column (head) distribution — that relayout is one
        # all-to-all whose result is S/seq_shards the size of the naive
        # full-sequence all-gather.  (The naive path's gather is shared with
        # forward() by CSE; under tp_local_kv forward keeps kv heads local
        # and needs no gather at all.)
        k = ctx.all_to_all_model(x @ params["wk"], split_axis=1,
                                 concat_axis=2).reshape(
            b, s_local, cfg.num_kv_heads, hd)
        v = ctx.all_to_all_model(x @ params["wv"], split_axis=1,
                                 concat_axis=2).reshape(
            b, s_local, cfg.num_kv_heads, hd)
        if cfg.qk_norm:
            k = common.rmsnorm(k, params["k_norm"])
        positions = start + jnp.arange(s_local)
        k = common.apply_rope(k, positions[None, :], cfg.rope_theta)
        cache = {"k": k, "v": v}
    else:
        k = ctx.all_gather_model(x @ params["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
        v = ctx.all_gather_model(x @ params["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
        if cfg.qk_norm:
            k = common.rmsnorm(k, params["k_norm"])
        positions = jnp.arange(s)
        k = common.apply_rope(k, positions[None, :], cfg.rope_theta)
        cache = {
            "k": lax.dynamic_slice_in_dim(k, start, s_local, axis=1),
            "v": lax.dynamic_slice_in_dim(v, start, s_local, axis=1),
        }
    out = forward(params, x, cfg, ctx, q_chunk=q_chunk, window=window)
    return out, cache
