"""Block stack: pre-norm residual blocks assembled from the period's layer
slots and scanned over periods (``lax.scan`` keeps the HLO O(1) in depth).

Every architecture is ``num_periods`` repetitions of a static tuple of
:class:`LayerSlot`s — dense models have one slot, Jamba has eight
(7 mamba + 1 attention, MoE on every other FFN).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.dist import MeshCtx
from repro.core.matrixize import NONE as SPEC_NONE
from repro.models import attention, common, mamba2, mlp, moe
from repro.configs.base import ModelConfig


def _slot_init(key, slot, cfg: ModelConfig, model_shards: int, dtype):
    p: Dict[str, Any] = {"norm1": common.rmsnorm_init(cfg.d_model, dtype)}
    km, kf = jax.random.split(key)
    if slot.mixer == "attn":
        p["mixer"] = attention.init(km, cfg, model_shards, dtype)
    elif slot.mixer == "mamba":
        p["mixer"] = mamba2.init(km, cfg, model_shards, dtype)
    else:
        raise ValueError(slot.mixer)
    if slot.ffn != "none":
        p["norm2"] = common.rmsnorm_init(cfg.d_model, dtype)
        p["ffn"] = moe.init(kf, cfg, dtype) if slot.ffn == "moe" else mlp.init(kf, cfg, dtype)
    return p


def init(key, cfg: ModelConfig, model_shards: int, dtype=jnp.float32):
    def one_period(k):
        ks = jax.random.split(k, len(cfg.slots))
        return {f"slot{i}": _slot_init(ks[i], s, cfg, model_shards, dtype)
                for i, s in enumerate(cfg.slots)}

    keys = jax.random.split(key, cfg.num_periods)
    return jax.vmap(one_period)(keys)


def _slot_pspecs(slot, cfg):
    p = {"norm1": P(None)}
    p["mixer"] = attention.pspecs(cfg) if slot.mixer == "attn" else mamba2.pspecs(cfg)
    if slot.ffn != "none":
        p["norm2"] = P(None)
        p["ffn"] = moe.pspecs(cfg) if slot.ffn == "moe" else mlp.pspecs(cfg)
    return p


def pspecs(cfg: ModelConfig):
    per = {f"slot{i}": _slot_pspecs(s, cfg) for i, s in enumerate(cfg.slots)}
    return common.tree_stackspec(per)  # prepend the period dim


def _slot_mspecs(slot, cfg):
    p = {"norm1": SPEC_NONE}
    p["mixer"] = attention.mspecs(cfg) if slot.mixer == "attn" else mamba2.mspecs(cfg)
    if slot.ffn != "none":
        p["norm2"] = SPEC_NONE
        p["ffn"] = moe.mspecs(cfg) if slot.ffn == "moe" else mlp.mspecs(cfg)
    return p


def mspecs(cfg: ModelConfig):
    per = {f"slot{i}": _slot_mspecs(s, cfg) for i, s in enumerate(cfg.slots)}
    return common.tree_stack_mspec(per)  # period dim joins the compressor batch


# ---------------------------------------------------------------------------
# train / scoring forward
# ---------------------------------------------------------------------------

def forward(params, x, cfg: ModelConfig, ctx: MeshCtx, *, window: int = 0,
            q_chunk: int = 512, remat: bool = True, unroll: int = 1):
    """x: (B, S, d) → (B, S, d); returns (out, moe_aux_loss)."""

    def body(carry, pparams):
        h, aux = carry
        for i, slot in enumerate(cfg.slots):
            sp = pparams[f"slot{i}"]
            z = common.rmsnorm(h, sp["norm1"])
            if slot.mixer == "attn":
                h = h + attention.forward(sp["mixer"], z, cfg, ctx,
                                          q_chunk=q_chunk, window=window)
            else:
                h = h + mamba2.forward(sp["mixer"], z, cfg, ctx)
            if slot.ffn != "none":
                z = common.rmsnorm(h, sp["norm2"])
                if slot.ffn == "moe":
                    y, a = moe.forward(sp["ffn"], z, cfg, ctx)
                    h, aux = h + y, aux + a
                else:
                    h = h + mlp.forward(sp["ffn"], z, cfg, ctx)
        return (h, aux), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)), params,
                           unroll=unroll)
    return x, aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, model_shards: int, batch_local: int,
               seq_local: int, dtype=jnp.float32):
    """Stacked (num_periods, ...) cache tree matching the block structure."""
    hl_attn = attention.padded_heads(cfg, model_shards) // model_shards
    hl_ssm = cfg.ssm_heads // model_shards if cfg.ssm_heads else 0

    def one_period():
        c = {}
        for i, slot in enumerate(cfg.slots):
            if slot.mixer == "attn":
                c[f"slot{i}"] = attention.init_cache(cfg, batch_local, seq_local, dtype)
            else:
                c[f"slot{i}"] = mamba2.init_cache(cfg, batch_local, hl_ssm, dtype)
        return c

    per = one_period()
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_periods,) + a.shape), per)


def cache_pspecs(cfg: ModelConfig, batch_axes, seq_axes):
    per = {}
    for i, slot in enumerate(cfg.slots):
        if slot.mixer == "attn":
            per[f"slot{i}"] = attention.cache_pspecs(batch_axes, seq_axes)
        else:
            per[f"slot{i}"] = mamba2.cache_pspecs(batch_axes)
    return common.tree_stackspec(per)


def decode(params, caches, x, pos, cfg: ModelConfig, ctx: MeshCtx, *,
           window: int = 0, unroll: int = 1):
    """One-token decode through the stack. x: (B, 1, d).

    Returns (out, new_caches)."""

    def body(h, inputs):
        pparams, pcache = inputs
        newc = {}
        for i, slot in enumerate(cfg.slots):
            sp = pparams[f"slot{i}"]
            z = common.rmsnorm(h, sp["norm1"])
            if slot.mixer == "attn":
                y, newc[f"slot{i}"] = attention.decode(
                    sp["mixer"], z, pcache[f"slot{i}"], pos, cfg, ctx, window=window)
            else:
                y, newc[f"slot{i}"] = mamba2.decode(sp["mixer"], z, pcache[f"slot{i}"], cfg, ctx)
            h = h + y
            if slot.ffn != "none":
                z = common.rmsnorm(h, sp["norm2"])
                if slot.ffn == "moe":
                    y, _ = moe.forward(sp["ffn"], z, cfg, ctx, dropless=True)
                    h = h + y
                else:
                    h = h + mlp.forward(sp["ffn"], z, cfg, ctx)
        return h, newc

    x, new_caches = lax.scan(body, x, (params, caches), unroll=unroll)
    return x, new_caches


# ---------------------------------------------------------------------------
# prefill: forward + emit cache slices
# ---------------------------------------------------------------------------

def prefill(params, x, cfg: ModelConfig, ctx: MeshCtx, *, window: int = 0,
            q_chunk: int = 512, unroll: int = 1):
    """Returns (out, caches) — the cache holds this shard's seq slice."""
    hl_ssm = 0
    if cfg.ssm_heads:
        msz = ctx.model_size() if ctx.model_axis else 1
        hl_ssm = cfg.ssm_heads // msz

    def body(h, pparams):
        newc = {}
        for i, slot in enumerate(cfg.slots):
            sp = pparams[f"slot{i}"]
            z = common.rmsnorm(h, sp["norm1"])
            if slot.mixer == "attn":
                y, newc[f"slot{i}"] = attention.prefill(
                    sp["mixer"], z, cfg, ctx, q_chunk=q_chunk, window=window)
            else:
                y, state = _mamba_prefill(sp["mixer"], z, cfg, ctx, hl_ssm)
                newc[f"slot{i}"] = state
            h = h + y
            if slot.ffn != "none":
                z = common.rmsnorm(h, sp["norm2"])
                if slot.ffn == "moe":
                    y, _ = moe.forward(sp["ffn"], z, cfg, ctx, dropless=True)
                    h = h + y
                else:
                    h = h + mlp.forward(sp["ffn"], z, cfg, ctx)
        return h, newc

    x, caches = lax.scan(body, x, params, unroll=unroll)
    return x, caches


def _mamba_prefill(p, x, cfg, ctx, hl):
    """Run the SSD forward and capture the final recurrent + conv state."""
    b, s, d = x.shape
    n, pd = cfg.ssm_state, cfg.ssm_head_dim

    z = x @ p["wz"]
    xs_pre = x @ p["wx"]
    xs = jax.nn.silu(mamba2._causal_depthwise_conv(xs_pre, p["conv_x"]))
    bmat = x @ p["wB"]
    cmat = x @ p["wC"]
    dt = jax.nn.softplus((x @ p["wdt"]) + p["dt_bias"])
    a_neg = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xs.reshape(b, s, hl, pd)
    y, h_fin = mamba2._ssd_scan(xh, dt, bmat, cmat, a_neg, 64)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(b, s, hl * pd)
    y = mamba2._sharded_gated_rmsnorm(y, z, p["norm_scale"], ctx, cfg.ssm_d_inner)
    out = ctx.psum_model(y @ p["out_proj"])
    cache = {
        "conv": xs_pre[:, -(cfg.ssm_conv - 1):, :],
        "h": h_fin,
    }
    return out, cache
