"""CIFAR ResNet18 in pure JAX (the paper's main benchmark model, §5).

Conv kernels are stored (O, I, kh, kw) so the compressor's "conv" matrixize
rule reproduces the paper's Table 10 flattening (O × I·kh·kw) exactly.
BatchNorm uses batch statistics in training; running stats are carried in a
separate state tree.  BN scales/biases fall under the paper's bias rule
(aggregated uncompressed, no weight decay).

``width=64, blocks=(2,2,2,2)`` is the paper's exact ResNet18; benchmarks use
scaled-down widths to fit the CPU budget (bytes accounting stays analytic).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.matrixize import MatrixSpec, NONE as SPEC_NONE


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    width: int = 64
    blocks: Tuple[int, ...] = (2, 2, 2, 2)
    num_classes: int = 10
    in_channels: int = 3


def paper_resnet18() -> ResNetConfig:
    return ResNetConfig(width=64, blocks=(2, 2, 2, 2), num_classes=10)


def _conv_init(key, o, i, kh, kw):
    fan_in = i * kh * kw
    return jax.random.normal(key, (o, i, kh, kw)) * math.sqrt(2.0 / fan_in)


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def init(key, cfg: ResNetConfig):
    keys = iter(jax.random.split(key, 64))
    w = cfg.width
    params = {"conv1": _conv_init(next(keys), w, cfg.in_channels, 3, 3),
              "bn1": _bn_init(w)}
    state = {"bn1": _bn_state(w)}
    in_c = w
    for si, n in enumerate(cfg.blocks):
        out_c = w * (2 ** si)
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            name = f"layer{si}_{bi}"
            blk = {
                "conv1": _conv_init(next(keys), out_c, in_c, 3, 3),
                "bn1": _bn_init(out_c),
                "conv2": _conv_init(next(keys), out_c, out_c, 3, 3),
                "bn2": _bn_init(out_c),
            }
            bst = {"bn1": _bn_state(out_c), "bn2": _bn_state(out_c)}
            if stride != 1 or in_c != out_c:
                blk["shortcut"] = _conv_init(next(keys), out_c, in_c, 1, 1)
                blk["bn_s"] = _bn_init(out_c)
                bst["bn_s"] = _bn_state(out_c)
            params[name] = blk
            state[name] = bst
            in_c = out_c
    params["linear"] = {
        "w": jax.random.normal(next(keys), (cfg.num_classes, in_c)) / math.sqrt(in_c),
        "b": jnp.zeros((cfg.num_classes,)),
    }
    return params, state


def _bn_state(c):
    return {"mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def mspecs(params):
    """Matrix specs: convs via the paper's (O, I·kh·kw) rule; BN/bias exempt."""

    def leaf(path, p):
        if p.ndim == 4:
            return MatrixSpec("conv", 0)
        if p.ndim == 2:
            return MatrixSpec("matrix", 0)
        return SPEC_NONE

    return jax.tree_util.tree_map_with_path(leaf, params)


def _conv(x, w, stride):
    return lax.conv_general_dilated(
        x, jnp.transpose(w, (2, 3, 1, 0)),           # OIHW → HWIO
        window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn(x, p, s, train: bool, momentum=0.9, eps=1e-5):
    if train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        new_s = {"mean": momentum * s["mean"] + (1 - momentum) * mean,
                 "var": momentum * s["var"] + (1 - momentum) * var}
    else:
        mean, var = s["mean"], s["var"]
        new_s = s
    y = (x - mean) * lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y, new_s


def forward(params, state, x, cfg: ResNetConfig, train: bool = True):
    """x: (B, H, W, C) → (logits, new_bn_state)."""
    new_state = {}
    h = _conv(x, params["conv1"], 1)
    h, new_state["bn1"] = _bn(h, params["bn1"], state["bn1"], train)
    h = jax.nn.relu(h)
    in_c = cfg.width
    for si, n in enumerate(cfg.blocks):
        out_c = cfg.width * (2 ** si)
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            name = f"layer{si}_{bi}"
            blk, bst = params[name], state[name]
            nst = {}
            y = _conv(h, blk["conv1"], stride)
            y, nst["bn1"] = _bn(y, blk["bn1"], bst["bn1"], train)
            y = jax.nn.relu(y)
            y = _conv(y, blk["conv2"], 1)
            y, nst["bn2"] = _bn(y, blk["bn2"], bst["bn2"], train)
            if "shortcut" in blk:
                sc = _conv(h, blk["shortcut"], stride)
                sc, nst["bn_s"] = _bn(sc, blk["bn_s"], bst["bn_s"], train)
            else:
                sc = h
            h = jax.nn.relu(y + sc)
            new_state[name] = nst
            in_c = out_c
    h = jnp.mean(h, axis=(1, 2))
    logits = h @ params["linear"]["w"].T + params["linear"]["b"]
    return logits, new_state


def loss_fn(params, state, batch, cfg: ResNetConfig, train: bool = True):
    logits, new_state = forward(params, state, batch["images"], cfg, train)
    onehot = jax.nn.one_hot(batch["labels"], cfg.num_classes)
    loss = -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), axis=-1))
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
    return loss, (new_state, {"loss": loss, "acc": acc})
