"""Mixture-of-Experts layer with top-k routing, capacity-based dispatch and
expert parallelism over the ``model`` axis.

Activations are replicated within a model group (Megatron pattern), so each
shard holds E/model_shards experts and processes the tokens routed to *its*
experts — no all-to-all is required; expert outputs combine with one
``psum(model)``.  The router is replicated; ``common.grad_synced`` on the
gate path sums the per-rank partial cotangents so its gradient is the full
value, identical on all model shards.

Dispatch uses the standard capacity-factor scheme: per expert, the first
C = ceil(T·k/E · cf) routed tokens are kept, the rest are dropped (their
residual path passes through).  Aux load-balance loss follows Switch/GShard:
E · Σ_e f_e · p_e.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.dist import MeshCtx
from repro.core.matrixize import MatrixSpec, NONE as SPEC_NONE
from repro.models import common
from repro.configs.base import ModelConfig


def init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe_num_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": common.dense_init(kr, (d, e), d, dtype),
        "w_gate": common.dense_init(kg, (e, d, ff), d, dtype),
        "w_up": common.dense_init(ku, (e, d, ff), d, dtype),
        "w_down": common.dense_init(kd, (e, ff, d), ff, dtype),
    }


def pspecs(cfg: ModelConfig):
    return {
        "router": P(None, None),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }


def mspecs(cfg: ModelConfig):
    return {
        "router": MatrixSpec("matrix", 0),
        "w_gate": MatrixSpec("matrix", 1),   # expert dim is a compressor batch dim
        "w_up": MatrixSpec("matrix", 1),
        "w_down": MatrixSpec("matrix", 1),
    }


def capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(math.ceil(tokens * cfg.moe_top_k / cfg.moe_num_experts
                      * cfg.moe_capacity_factor))
    return max(8, min(c, tokens))


def forward(params, x, cfg: ModelConfig, ctx: MeshCtx, *, dropless: bool = False):
    """x: (B, S, d) replicated over the model axis.  Returns (out, aux_loss).

    ``dropless=True`` sizes every expert's buffer to the full token count so
    no (token, expert) assignment is ever dropped.  Training uses the
    capacity-factor scheme (drops are part of the optimization dynamics);
    inference (prefill/decode) must be dropless — decode routes one token at
    a time and never hits capacity, so a prefill that drops tokens would
    disagree with token-by-token decode on the same prompt.
    """
    b, s, d = x.shape
    t = b * s
    e = cfg.moe_num_experts
    k = cfg.moe_top_k
    e_local = params["w_gate"].shape[0]
    # A token's top-k experts are distinct, so one expert sees ≤ t entries;
    # cap = t keeps the dense per-expert block layout (the einsums below need
    # contiguous expert blocks) at the cost of an (e_local·t, d) dispatch
    # buffer of which ≤ t·k rows are occupied.  Fine for the serve shapes we
    # run; a ragged/sorted dispatch would tighten memory for long-prompt
    # many-expert prefill.
    cap = t if dropless else capacity(cfg, t)

    xt = x.reshape(t, d)
    logits = (xt @ params["router"]).astype(jnp.float32)      # (T, E)
    # The gates are consumed inside the rank-local dispatch/combine below, so
    # every rank's backward produces only its experts' share of the gate
    # cotangent — grad_synced restores the full router gradient (identical on
    # all model shards).  The aux loss is replicated math (its cotangent is
    # already full on every rank) and must read the *unwrapped* logits.
    probs = jax.nn.softmax(common.grad_synced(logits, ctx), axis=-1)
    gates, experts = lax.top_k(probs, k)                      # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (replicated; computed from local tokens) ----
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)    # (E,)
    ce = jnp.zeros((e,)).at[experts.reshape(-1)].add(
        jnp.ones((t * k,)) / (t * k))
    aux = e * jnp.sum(me * ce)

    # ---- capacity positions: rank of each (token, slot) within its expert --
    fe = experts.reshape(-1)                                  # (T·k,) routing order
    onehot = jax.nn.one_hot(fe, e, dtype=jnp.int32)           # (T·k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                 # entries before me
    pos = jnp.sum(pos * onehot, axis=-1)                      # (T·k,)
    keep = pos < cap

    # ---- dispatch to *local* experts -------------------------------------
    lo = ctx.model_index() * e_local
    local = (fe >= lo) & (fe < lo + e_local) & keep
    slot = jnp.where(local, (fe - lo) * cap + pos, e_local * cap)  # dump slot
    token_of = jnp.repeat(jnp.arange(t), k)
    xt_local = common.grad_synced(xt, ctx)    # entering rank-local experts
    buf = jnp.zeros((e_local * cap + 1, d), x.dtype)
    buf = buf.at[slot].add(jnp.where(local[:, None], xt_local[token_of], 0.0))
    h = buf[: e_local * cap].reshape(e_local, cap, d)

    # ---- expert FFNs (SwiGLU) ---------------------------------------------
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, params["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", h, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", g * u, params["w_down"])
    y = y.reshape(e_local * cap, d)
    y = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)], axis=0)

    # ---- combine: gather back, weight by gate, sum over k and shards -------
    contrib = y[slot] * jnp.where(local, gates.reshape(-1), 0.0)[:, None]
    out = jnp.zeros((t, d), x.dtype).at[token_of].add(contrib)
    out = ctx.psum_model(out)
    return out.reshape(b, s, d), aux
