"""SwiGLU feed-forward, column+row tensor-parallel (Megatron pattern)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.dist import MeshCtx
from repro.core.matrixize import MatrixSpec
from repro.models import common
from repro.configs.base import ModelConfig


def init(key, cfg: ModelConfig, dtype=jnp.float32, d_ff=None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "w_gate": common.dense_init(kg, (d, ff), d, dtype),
        "w_up": common.dense_init(ku, (d, ff), d, dtype),
        "w_down": common.dense_init(kd, (ff, d), ff, dtype),
    }


def pspecs(cfg: ModelConfig):
    return {
        "w_gate": P(None, "model"),
        "w_up": P(None, "model"),
        "w_down": P("model", None),
    }


def mspecs(cfg: ModelConfig):
    return {k: MatrixSpec("matrix", 0) for k in ("w_gate", "w_up", "w_down")}


def forward(params, x, cfg: ModelConfig, ctx: MeshCtx):
    """x: (B, S, d) replicated over the model axis; output likewise."""
    x = common.grad_synced(x, ctx)
    gate = jax.nn.silu(x @ params["w_gate"])
    up = x @ params["w_up"]
    return ctx.psum_model((gate * up) @ params["w_down"])
