"""Shared building blocks: TP-aware linears, norms, RoPE, sharded embedding
and the vocab-sharded cross-entropy.

Sharding convention (Megatron-style tensor parallelism over the ``model``
axis, expressed as global shapes + PartitionSpecs; ``shard_map`` hands the
apply functions the *local* slices):

  * column-parallel linear  W (d_in, d_out)        pspec (None, "model")
  * row-parallel linear     W (d_in, d_out)        pspec ("model", None)
    → caller must ``ctx.psum_model`` the output
  * embedding               E (vocab, d)           pspec ("model", None)
  * replicated params                              pspec (None, ...)

All apply code derives local dims from the local array shapes, so the same
functions run unsharded (single CPU device) and sharded (inside shard_map).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.dist import MeshCtx
from repro.core.matrixize import MatrixSpec, NONE as SPEC_NONE


def dense_init(key, shape, in_axis_size, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(in_axis_size)
    return jax.random.normal(key, shape, dtype=dtype) * scale


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d, dtype=jnp.float32):
    return jnp.ones((d,), dtype=dtype)


def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps)).astype(x.dtype) * scale


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# TP gradient synchronisation (the Megatron "g" operator)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _identity_psum_grad(x, axis: str):
    return x


def _identity_psum_grad_fwd(x, axis):
    return x, None


def _identity_psum_grad_bwd(axis, _, ct):
    return (lax.psum(ct, axis),)


_identity_psum_grad.defvjp(_identity_psum_grad_fwd, _identity_psum_grad_bwd)


def grad_synced(x, ctx: MeshCtx):
    """Identity forward; ``psum(model)`` backward (Megatron's *g* operator).

    Wrap a model-replicated activation exactly where it enters rank-local
    sharded compute (column-parallel projections, expert dispatch, the SSD
    scan, the vocab-sharded head).  Each model rank's backward pass produces
    only the cotangent of *its* shard's consumption; ``lax.psum``'s transpose
    is the identity, so without this wrap every cotangent flowing back into
    the replicated residual stream — and every replicated parameter's
    gradient — is a per-rank partial sum: wrong, and different on every
    model rank (replicated state then drifts apart step over step).

    Placement rule: every backward path from the loss to a replicated value
    must cross exactly one ``grad_synced`` — none double-counts by W, two
    double-count too.  Paths that stay in replicated math (identical compute
    on every rank, e.g. the MoE aux loss) already carry the full cotangent
    and must bypass the wrap.

    No-op when there is no model axis (SimMesh, single device) or when
    ``ctx.tp_grad_sync`` is off (a debug switch that reproduces the legacy
    divergence — see tests/sim/test_drift.py).
    """
    if ctx.model_axis is None or not ctx.tp_grad_sync:
        return x
    return _identity_psum_grad(x, ctx.model_axis)


# ---------------------------------------------------------------------------
# Vocab-sharded embedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab, d, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, d), dtype=dtype) * 0.02


def embed_lookup(table, ids, ctx: MeshCtx):
    """table is the local (vocab_local, d) slice; ids are global token ids."""
    vocab_local = table.shape[0]
    offset = ctx.model_index() * vocab_local
    local = ids - offset
    valid = (local >= 0) & (local < vocab_local)
    local = jnp.clip(local, 0, vocab_local - 1)
    out = jnp.take(table, local, axis=0)
    out = jnp.where(valid[..., None], out, 0.0)
    return ctx.psum_model(out)


def sharded_softmax_xent(logits_local, labels, ctx: MeshCtx, vocab: int):
    """Cross-entropy with vocab-sharded logits (..., vocab_local).

    Returns per-token loss (replicated across the model axis)."""
    vocab_local = logits_local.shape[-1]
    offset = ctx.model_index() * vocab_local
    logits32 = logits_local.astype(jnp.float32)

    # mask padding columns (vocab padded up to a multiple of model size)
    col = offset + lax.broadcasted_iota(jnp.int32, logits32.shape, logits32.ndim - 1)
    logits32 = jnp.where(col < vocab, logits32, -jnp.inf)

    # the stabiliser needs no gradient — keeps pmax out of the AD graph
    local_max = lax.stop_gradient(jnp.max(logits32, axis=-1))
    gmax = _pmax_model(local_max, ctx)
    sumexp = jnp.sum(jnp.exp(logits32 - gmax[..., None]), axis=-1)
    sumexp = ctx.psum_model(sumexp)
    lse = gmax + jnp.log(sumexp)

    local_label = labels - offset
    lvalid = (local_label >= 0) & (local_label < vocab_local)
    ll = jnp.clip(local_label, 0, vocab_local - 1)
    picked = jnp.take_along_axis(logits32, ll[..., None], axis=-1)[..., 0]
    label_logit = ctx.psum_model(jnp.where(lvalid, picked, 0.0))
    return lse - label_logit


def _pmax_model(x, ctx: MeshCtx):
    return lax.pmax(x, ctx.model_axis) if ctx.model_axis else x


# ---------------------------------------------------------------------------
# Pytree helpers for specs
# ---------------------------------------------------------------------------

def stackspec(spec: P) -> P:
    """Prepend a None (period/layer-stack) dim to a PartitionSpec."""
    return P(*((None,) + tuple(spec)))


def stack_mspec(ms: MatrixSpec) -> MatrixSpec:
    if not ms.is_compressed():
        return ms
    return MatrixSpec(kind=ms.kind, batch_dims=ms.batch_dims + 1)


def tree_stackspec(tree):
    return jax.tree_util.tree_map(
        stackspec, tree, is_leaf=lambda x: isinstance(x, P))


def tree_stack_mspec(tree):
    return jax.tree_util.tree_map(
        stack_mspec, tree, is_leaf=lambda x: isinstance(x, MatrixSpec))
