from repro.models import attention, blocks, common, lstm, mamba2, mlp, model, moe, resnet
