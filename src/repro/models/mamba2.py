"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) layer.

Chunked SSD algorithm: within a chunk the recurrence is materialised as a
(masked, decay-weighted) attention-like matrix; across chunks a scan carries
the (N × P) state per head.  Decode is the O(1) recurrent update.

Tensor parallelism: d_inner (heads) is column-split over the ``model`` axis;
B/C projections (ngroups=1) are replicated; out_proj is row-parallel.  The
gated RMSNorm before out_proj normalises over the *global* d_inner via a
``psum(model)`` of the local sum of squares.

Per-head vectors (A_log, D, dt_bias) are sharded over the model axis and —
per the paper's bias rule — aggregated uncompressed by PowerSGD.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.dist import MeshCtx
from repro.core.matrixize import MatrixSpec, NONE as SPEC_NONE
from repro.models import common
from repro.configs.base import ModelConfig


def init(key, cfg: ModelConfig, model_shards: int, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.ssm_d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    w = cfg.ssm_conv
    ks = jax.random.split(key, 8)
    return {
        "wz": common.dense_init(ks[0], (d, di), d, dtype),
        "wx": common.dense_init(ks[1], (d, di), d, dtype),
        "wB": common.dense_init(ks[2], (d, n), d, dtype),
        "wC": common.dense_init(ks[3], (d, n), d, dtype),
        "wdt": common.dense_init(ks[4], (d, h), d, dtype),
        "conv_x": jax.random.normal(ks[5], (w, di), dtype) * (1.0 / math.sqrt(w)),
        "dt_bias": jnp.zeros((h,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)).astype(dtype),
        "D": jnp.ones((h,), dtype),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": common.dense_init(ks[6], (di, d), di, dtype),
    }


def pspecs(cfg: ModelConfig):
    return {
        "wz": P(None, "model"),
        "wx": P(None, "model"),
        "wB": P(None, None),
        "wC": P(None, None),
        "wdt": P(None, "model"),
        "conv_x": P(None, "model"),
        "dt_bias": P("model"),
        "A_log": P("model"),
        "D": P("model"),
        "norm_scale": P("model"),
        "out_proj": P("model", None),
    }


def mspecs(cfg: ModelConfig):
    return {
        "wz": MatrixSpec("matrix", 0),
        "wx": MatrixSpec("matrix", 0),
        "wB": MatrixSpec("matrix", 0),
        "wC": MatrixSpec("matrix", 0),
        "wdt": MatrixSpec("matrix", 0),
        "conv_x": SPEC_NONE,      # tiny depthwise filter — bias rule
        "dt_bias": SPEC_NONE,
        "A_log": SPEC_NONE,
        "D": SPEC_NONE,
        "norm_scale": SPEC_NONE,
        "out_proj": MatrixSpec("matrix", 0),
    }


def _sharded_gated_rmsnorm(y, z, scale, ctx: MeshCtx, d_inner_global, eps=1e-6):
    y = y * jax.nn.silu(z)
    ss = jnp.sum(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    # the replicated mean-square is consumed by every rank's local y path:
    # its true cotangent is the sum of the per-rank partials
    ss = common.grad_synced(ctx.psum_model(ss) / d_inner_global, ctx)
    return (y * lax.rsqrt(ss + eps)).astype(y.dtype) * scale


def _causal_depthwise_conv(x, kernel):
    """x: (B, S, C); kernel: (w, C) — causal depthwise conv along S."""
    w = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(w):
        out = out + xp[:, i : i + x.shape[1]] * kernel[i]
    return out


def forward(params, x, cfg: ModelConfig, ctx: MeshCtx, *, chunk: int = 64):
    """x: (B, S, d) replicated over the model axis → (B, S, d)."""
    b, s, d = x.shape
    n = cfg.ssm_state
    p = cfg.ssm_head_dim
    hl = params["wdt"].shape[1]              # local head count
    di_local = hl * p

    # x enters the column-parallel projections (wz/wx/wdt) here; the
    # replicated B/C projections feed the rank-local SSD scan, so each gets
    # its own backward psum *after* the matmul — computed from the raw x so
    # the cotangent reaching x through wB/wC is not summed twice.
    x_loc = common.grad_synced(x, ctx)
    z = x_loc @ params["wz"]                                 # (B, S, di_l)
    xs = x_loc @ params["wx"]
    xs = jax.nn.silu(_causal_depthwise_conv(xs, params["conv_x"]))
    bmat = common.grad_synced(x @ params["wB"], ctx)         # (B, S, N) replicated
    cmat = common.grad_synced(x @ params["wC"], ctx)
    dt = jax.nn.softplus((x_loc @ params["wdt"]) + params["dt_bias"])  # (B, S, hl)
    a_neg = -jnp.exp(params["A_log"].astype(jnp.float32))     # (hl,)

    xh = xs.reshape(b, s, hl, p)
    y, _ = _ssd_scan(xh, dt, bmat, cmat, a_neg, chunk)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(b, s, di_local)

    y = _sharded_gated_rmsnorm(y, z, params["norm_scale"], ctx, cfg.ssm_d_inner)
    return ctx.psum_model(y @ params["out_proj"])


def _ssd_scan(xh, dt, bmat, cmat, a_neg, chunk):
    """Chunked SSD.  xh: (B,S,H,P), dt: (B,S,H), bmat/cmat: (B,S,N).

    Returns (y: (B,S,H,P), final state h: (B,H,N,P))."""
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    lc = min(chunk, s)
    assert s % lc == 0, (s, lc)
    nc = s // lc

    def split(t):
        return t.reshape((b, nc, lc) + t.shape[2:]).transpose((1, 0, 2) + tuple(range(3, t.ndim + 1)))

    xc, dtc = split(xh), split(dt)
    bc, cc = split(bmat), split(cmat)

    h0 = jnp.zeros((b, h, n, p), jnp.float32)

    def body(hst, args):
        xk, dtk, bk, ck = args                     # (B,Lc,H,P) (B,Lc,H) (B,Lc,N)
        a = dtk.astype(jnp.float32) * a_neg        # (B,Lc,H)
        cum = jnp.cumsum(a, axis=1)                # inclusive
        # intra-chunk: scores_ij = C_i·B_j · exp(cum_i − cum_j) · dt_j  (i ≥ j)
        cb = jnp.einsum("bin,bjn->bij", ck, bk)    # (B,Lc,Lc)
        decay = cum[:, :, None, :] - cum[:, None, :, :]          # (B,Lc,Lc,H)
        causal = jnp.tril(jnp.ones((lc, lc), bool))[None, :, :, None]
        # double-where: exp(decay) overflows in the masked upper triangle
        # (decay > 0 there), and where(mask, inf, 0) has NaN gradient.
        decay = jnp.where(causal, decay, 0.0)
        lmat = jnp.where(causal, jnp.exp(decay), 0.0)
        scores = cb[..., None] * lmat * dtk[:, None, :, :]       # (B,i,j,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores, xk.astype(jnp.float32))
        # inter-chunk: y_i += C_i · (exp(cum_i) · h_in)
        hin_term = jnp.einsum("bin,bhnp->bihp", ck, hst)
        y_inter = hin_term * jnp.exp(cum)[..., None]
        # state update: h' = exp(cum_last) h + Σ_j exp(cum_last − cum_j) dt_j B_j ⊗ x_j
        cl = cum[:, -1, :]                                       # (B,H)
        w = jnp.exp(cl[:, None, :] - cum) * dtk                  # (B,Lc,H)
        upd = jnp.einsum("bjh,bjn,bjhp->bhnp", w, bk, xk.astype(jnp.float32))
        h_new = jnp.exp(cl)[:, :, None, None] * hst + upd
        return h_new, (y_intra + y_inter)

    h_fin, ys = lax.scan(body, h0, (xc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p).astype(xh.dtype)
    return y, h_fin


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_local: int, heads_local: int,
               dtype=jnp.float32):
    n, p, w, di_l = cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_conv, heads_local * cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch_local, w - 1, di_l), dtype),
        "h": jnp.zeros((batch_local, heads_local, n, p), jnp.float32),
    }


def cache_pspecs(batch_axes) -> dict:
    ba = batch_axes if batch_axes else None
    return {"conv": P(ba, None, "model"), "h": P(ba, "model", None, None)}


def decode(params, x, cache, cfg: ModelConfig, ctx: MeshCtx):
    """One-token recurrent update.  x: (B, 1, d).  Returns (y, new_cache)."""
    b = x.shape[0]
    n, p = cfg.ssm_state, cfg.ssm_head_dim
    hl = params["wdt"].shape[1]

    z = x[:, 0] @ params["wz"]                                # (B, di_l)
    xs = x[:, 0] @ params["wx"]
    # causal conv over the cached window + current input
    w = cfg.ssm_conv
    window = jnp.concatenate([cache["conv"], xs[:, None, :]], axis=1)  # (B, w, di_l)
    xs = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, params["conv_x"]))
    new_conv = window[:, 1:]

    bvec = x[:, 0] @ params["wB"]                              # (B, N)
    cvec = x[:, 0] @ params["wC"]
    dt = jax.nn.softplus(x[:, 0] @ params["wdt"] + params["dt_bias"])  # (B, hl)
    a_neg = -jnp.exp(params["A_log"].astype(jnp.float32))

    xh = xs.reshape(b, hl, p).astype(jnp.float32)
    decay = jnp.exp(dt.astype(jnp.float32) * a_neg)            # (B, hl)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt.astype(jnp.float32), bvec.astype(jnp.float32), xh)
    h_new = decay[:, :, None, None] * cache["h"] + upd
    y = jnp.einsum("bn,bhnp->bhp", cvec.astype(jnp.float32), h_new)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(b, hl * p).astype(x.dtype)

    y = _sharded_gated_rmsnorm(y, z, params["norm_scale"], ctx, cfg.ssm_d_inner)
    out = ctx.psum_model(y @ params["out_proj"])[:, None, :]
    return out, {"conv": new_conv, "h": h_new}
