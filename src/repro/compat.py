"""Compatibility shims for the pinned jax (0.4.x in this container).

The launcher code targets the modern jax surface (``jax.shard_map``,
``jax.set_mesh``); older releases ship the same functionality under
different names.  Importing this module installs forward-compatible
aliases onto ``jax`` when they are missing — a no-op on new jax:

* ``jax.shard_map``  → ``jax.experimental.shard_map.shard_map`` with the
  ``check_vma`` kwarg mapped to its old name ``check_rep``.
* ``jax.set_mesh``   → the ``jax.sharding.Mesh`` context manager itself
  (``with jax.set_mesh(mesh):`` ≡ ``with mesh:`` on 0.4.x).
* ``jax.lax.axis_size`` → ``jax.core.axis_frame`` (which returns the static
  axis size on 0.4.x), folded over tuples of axis names.

Imported for its side effect by ``repro.core`` so every entry point
(tests, examples, benchmarks, launchers) sees a uniform API.
"""

from __future__ import annotations

import jax


def _install() -> None:
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)

        jax.shard_map = shard_map

    if not hasattr(jax, "set_mesh"):
        def set_mesh(mesh):
            return mesh  # Mesh is a context manager on 0.4.x

        jax.set_mesh = set_mesh

    if not hasattr(jax.lax, "axis_size"):
        from jax import core as _core

        def axis_size(axis_name):
            if isinstance(axis_name, (tuple, list)):
                size = 1
                for a in axis_name:
                    size *= _core.axis_frame(a)
                return size
            return _core.axis_frame(axis_name)

        jax.lax.axis_size = axis_size


_install()
