"""End-to-end distributed training driver (deliverable b).

Trains a ~100M-parameter GQA transformer LM on the synthetic Markov stream
with EF-PowerSGD (Algorithm 1+2), data×model-parallel over the host devices,
and compares against full-precision SGD (IdentityCompressor) on loss and
bytes all-reduced per step.  Checkpoints via repro.checkpoint.

    # full run (~100M params, a few hundred steps — takes a while on CPU):
    PYTHONPATH=src python examples/train_end_to_end.py --steps 300

    # quick smoke (~7M params, 2 minutes):
    PYTHONPATH=src python examples/train_end_to_end.py --preset small --steps 40
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.configs.base import LayerSlot, ModelConfig
from repro.core.compressors import IdentityCompressor, PowerSGDCompressor
from repro.data.synthetic import MarkovLM
from repro.launch.train import TrainHyper, make_train_step


PRESETS = {
    # ~101M params: 2*V*d + L*(4*d*hd*H... ) — dominated by embed+head
    "100m": ModelConfig(
        name="demo-100m", arch_type="dense", num_layers=8, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32768,
        slots=(LayerSlot("attn", "dense"),)),
    "small": ModelConfig(
        name="demo-7m", arch_type="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=512, vocab_size=8192,
        slots=(LayerSlot("attn", "dense"),)),
}


def run(name, compressor, cfg, mesh, args, log):
    hyper = TrainHyper(lr=args.lr, rank=args.rank, q_chunk=64,
                       warmup_steps=min(20, args.steps // 4), remat=False)
    step_fn, _, init_state = make_train_step(cfg, mesh, hyper,
                                             compressor=compressor)
    key = jax.random.key(args.seed)
    with jax.set_mesh(mesh):
        params, ef = init_state(key)
    data = MarkovLM(vocab=cfg.vocab_size, seed=0)
    it = data.batches(args.batch, args.seq)

    losses, t0 = [], time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        with jax.set_mesh(mesh):
            params, ef, metrics = step_fn(params, ef, batch, key)
        loss = float(metrics["lm_loss"])
        losses.append(loss)
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            print(f"  [{name}] step {i:4d} loss={loss:.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    if args.ckpt_dir:
        path = save_checkpoint(os.path.join(args.ckpt_dir, name),
                               args.steps, {"params": params})
        print(f"  [{name}] checkpoint: {path}")
    log[name] = {"final_loss": losses[-1],
                 "loss_curve": losses[:: max(1, args.steps // 50)],
                 "wall_s": round(time.time() - t0, 1)}
    return losses[-1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="100m", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-sgd-baseline", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out", default="experiments/train_end_to_end.json")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((max(1, n_dev // 2), min(2, n_dev)),
                         ("data", "model"))
    print(f"model: {cfg.name}  params≈{cfg.param_count()/1e6:.1f}M  "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    # bytes all-reduced per step: PowerSGD vs raw gradient
    from repro.core import powersgd as ps_lib
    from repro.models import model as model_lib
    shapes = jax.eval_shape(lambda: model_lib.init(jax.random.key(0), cfg, 1))
    specs = model_lib.mspecs(cfg)
    total = sum(x.size for x in jax.tree_util.tree_leaves(shapes))
    sent = ps_lib.compressed_floats_total(shapes, specs, args.rank)
    print(f"gradient floats {total:,} -> all-reduced {sent:,} "
          f"({total/sent:.0f}x compression at rank {args.rank})\n")

    log = {"config": {k: v for k, v in vars(args).items()},
           "params_m": cfg.param_count() / 1e6,
           "compression_ratio": total / sent}
    run("powersgd", PowerSGDCompressor(rank=args.rank), cfg, mesh, args, log)
    if not args.skip_sgd_baseline:
        run("sgd", IdentityCompressor(), cfg, mesh, args, log)
        d = log["powersgd"]["final_loss"] - log["sgd"]["final_loss"]
        print(f"\nfinal loss: powersgd={log['powersgd']['final_loss']:.4f} "
              f"sgd={log['sgd']['final_loss']:.4f} (gap {d:+.4f}) — "
              f"with {total/sent:.0f}x less gradient traffic")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(log, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
