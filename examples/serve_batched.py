"""Batched serving example: prefill + greedy decode with a KV cache.

Serves a small GQA transformer (the reduced llama3-8b family config) over a
batch of variable-length requests:

  1. right-pads the prompt batch and prefills it in q_chunk'd flash blocks,
  2. greedily decodes continuation tokens with the O(1)-per-token KV-cache
     decode path (the same code the decode_32k / long_500k dry-run lowers),
  3. reports per-phase latency and tokens/s.

    PYTHONPATH=src python examples/serve_batched.py [--arch mamba2-1.3b]

Works for any assigned architecture id (--arch); SSM archs serve with their
recurrent state instead of a KV cache.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.dist import SINGLE
from repro.models import model as model_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--gen-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    print(f"serving {cfg.name} (reduced: {cfg.num_layers}L d={cfg.d_model}, "
          f"params≈{cfg.param_count()/1e6:.1f}M)")

    key = jax.random.key(0)
    params = model_lib.init(key, cfg, model_shards=1)

    # --- a batch of 4 variable-length requests (token ids) ---------------
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (9, 17, 5, 23)]
    b = len(prompts)
    plen = max(len(p) for p in prompts)
    toks = np.zeros((b, plen), np.int32)
    for i, p in enumerate(prompts):          # right-align so decode continues
        toks[i, plen - len(p):] = p          # from a common position
    toks = jnp.asarray(toks)

    batch = {"tokens": toks}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(key, (b, 16, cfg.frontend_dim))

    # --- prefill ----------------------------------------------------------
    prefill = jax.jit(lambda p, bt: model_lib.prefill_step(
        p, bt, cfg, SINGLE, q_chunk=32))
    t0 = time.time()
    logits, _ = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    first_tok = jnp.argmax(logits[..., :cfg.vocab_size], axis=-1)

    # --- decode loop (fresh cache; prompt replayed via teacher forcing) ---
    cache = model_lib.init_cache(cfg, 1, b, args.max_len)
    decode = jax.jit(lambda p, c, t, pos: model_lib.decode_step(
        p, c, t, pos, cfg, SINGLE))

    # replay prompt through the decode path to fill the cache
    t0 = time.time()
    for pos in range(plen):
        nxt, _, cache = decode(params, cache, toks[:, pos:pos + 1],
                               jnp.int32(pos))
    jax.block_until_ready(nxt)
    t_replay = time.time() - t0

    # verify the decode path agrees with prefill on the next token
    assert bool(jnp.all(nxt[:, 0] == first_tok[:, 0])), \
        "decode path disagrees with prefill"

    # greedy generation
    out = [nxt]
    t0 = time.time()
    for k in range(args.gen_tokens - 1):
        nxt, logits, cache = decode(params, cache, nxt,
                                    jnp.int32(plen + k))
        out.append(nxt)
    jax.block_until_ready(nxt)
    t_gen = time.time() - t0
    gen = jnp.concatenate(out, axis=1)

    assert gen.shape == (b, args.gen_tokens)
    assert bool(jnp.all(jnp.isfinite(logits)))

    print(f"\nbatch={b}  prompt_len≤{plen}  gen={args.gen_tokens} tokens")
    print(f"prefill: {t_prefill*1e3:7.1f} ms "
          f"({b*plen/t_prefill:7.0f} tok/s)")
    print(f"replay : {t_replay*1e3:7.1f} ms")
    print(f"decode : {t_gen*1e3:7.1f} ms "
          f"({b*(args.gen_tokens-1)/t_gen:7.0f} tok/s, "
          f"{t_gen/(args.gen_tokens-1)*1e3:.1f} ms/step)")
    print("\ncontinuations (token ids):")
    for i in range(b):
        print(f"  req{i} ({len(prompts[i])} prompt toks): "
              f"{np.asarray(gen[i][:10]).tolist()} ...")
    print("\nprefill/decode consistency check passed.")


if __name__ == "__main__":
    main()
