"""Quickstart: the PowerSGD compressor in isolation, then one EF-SGD loop.

Runs on CPU in <1 minute:

    PYTHONPATH=src python examples/quickstart.py

Demonstrates, step by step:
  1. rank-r compress+aggregate of a single gradient matrix (Algorithm 1),
  2. the warm-start effect (approximation error falls across steps),
  3. the linearity property (W workers ≡ 1 worker with the mean gradient),
  4. a full Error-Feedback SGD loop (Algorithm 2) on a least-squares problem,
     converging to the same solution as uncompressed SGD,
  5. the bucketed batched-compression engine: one step of a multi-layer
     model issues exactly 2 data-axis collectives instead of 2 per matrix,
  6. the unified transport engine across the zoo: linear schemes ride one
     fused all-reduce, non-linear schemes a genuine W-scaled all-gather,
  7. adaptive rank: a staircase schedule moving the rank mid-run with
     bit-exact warm-start hand-off, and the α-β autotuner picking
     per-bucket ranks + the wire policy under a bits budget.
"""

import jax
import jax.numpy as jnp

from repro.core import error_feedback, matrixize
from repro.core.compressors import PowerSGDCompressor, make_compressor
from repro.core.dist import CollectiveStats, MeshCtx
from repro.core.powersgd import (PowerSGDConfig, compress_aggregate,
                                 init_state)

KEY = jax.random.key(0)


def section(title):
    print(f"\n=== {title} " + "=" * max(0, 60 - len(title)))


# ---------------------------------------------------------------------------
section("1. Rank-2 compression of one gradient matrix")

n, m, r = 256, 512, 2
cfg = PowerSGDConfig(rank=r)
# a synthetic gradient with decaying spectrum (like real gradients, §2)
u = jax.random.normal(jax.random.key(1), (n, 16))
v = jax.random.normal(jax.random.key(2), (16, m))
scales = jnp.exp(-jnp.arange(16.0))
M = (u * scales) @ v

specs = {"w": matrixize.MatrixSpec("matrix", 0)}
shapes = {"w": jax.ShapeDtypeStruct((n, m), jnp.float32)}
state = init_state(cfg, shapes, specs, KEY)

out = compress_aggregate(cfg, {"w": M}, state, specs)
err = jnp.linalg.norm(M - out.agg["w"]) / jnp.linalg.norm(M)
sent_floats = out.bits_per_worker // 32            # r*(n+m)
print(f"matrix {n}x{m} = {n*m} floats -> sent {sent_floats} floats "
      f"({n*m/sent_floats:.0f}x compression), rel. error {err:.3f}")

# ---------------------------------------------------------------------------
section("2. Warm start: error falls across steps on a fixed matrix")

for step in range(4):
    out = compress_aggregate(cfg, {"w": M}, state, specs)
    state = out.state
    err = jnp.linalg.norm(M - out.agg["w"]) / jnp.linalg.norm(M)
    print(f"  step {step}: rel. error {err:.5f}")
print("  (Theorem I: iterating on a fixed matrix converges to the best "
      "rank-r approximation)")

# ---------------------------------------------------------------------------
section("3. Linearity: mean-of-gradients == multi-worker aggregate")

W = 4
Ms = [M + 0.1 * jax.random.normal(jax.random.key(i), (n, m))
      for i in range(W)]
mean_M = sum(Ms) / W
# single "worker" on the mean gradient
out1 = compress_aggregate(cfg, {"w": mean_M}, state, specs)
# W workers: because both matmuls are linear in M, compressing the mean
# equals all-reduce-averaging the per-worker P and Q (Appendix A.3).  On a
# real mesh ctx.pmean does this; here we average manually.
from repro.core.orthogonalize import get_orthogonalizer
orth = get_orthogonalizer(cfg.orthogonalizer)
q0 = state["w"]
P = sum(Mi @ q0 for Mi in Ms) / W          # == all-reduce-mean of M_i Q
Phat = orth(P)
Q = sum(Mi.T @ Phat for Mi in Ms) / W      # == all-reduce-mean of M_i^T P̂
recon_multi = Phat @ Q.T
diff = jnp.abs(recon_multi - out1.agg["w"]).max()
print(f"  max |multi-worker - single-worker| = {diff:.2e}  (exact linearity)")

# ---------------------------------------------------------------------------
section("4. EF-SGD (Algorithm 2) on least squares vs uncompressed SGD")

dim_in, dim_out, n_data = 64, 32, 512
A = jax.random.normal(jax.random.key(3), (n_data, dim_in))
w_true = jax.random.normal(jax.random.key(4), (dim_in, dim_out))
y = A @ w_true


def grad_fn(w, k):
    idx = jax.random.randint(k, (64,), 0, n_data)
    a, t = A[idx], y[idx]
    return a.T @ (a @ w - t) / 64


# NOTE on the learning rate: this quadratic's gradient is *full rank* —
# the hardest case for a rank-2 compressor — so EF needs a smaller step
# than uncompressed SGD here.  Real DL gradients have decaying spectra
# (§2), which is why the paper can reuse SGD's learning rate there.
comp = PowerSGDCompressor(rank=2)
params = {"w": jnp.zeros((dim_in, dim_out))}
specs = {"w": matrixize.MatrixSpec("matrix", 0)}
ef = error_feedback.init_state(comp, params, specs, KEY)

lr, lam = 0.01, 0.9


@jax.jit
def ps_step(params, ef, k):
    g = grad_fn(params["w"], k)
    p, e, _ = error_feedback.apply_updates(
        comp, params, {"w": g}, ef, specs,
        lr=lr, momentum=lam, weight_decay=0.0)
    return p, e


@jax.jit
def sgd_step(w, mom, k):
    g = grad_fn(w, k)
    mom = lam * mom + g
    return w - lr * (g + mom), mom


params_sgd = jnp.zeros((dim_in, dim_out))
mom_sgd = jnp.zeros_like(params_sgd)

for step in range(400):
    k = jax.random.fold_in(KEY, step)
    params, ef = ps_step(params, ef, k)
    params_sgd, mom_sgd = sgd_step(params_sgd, mom_sgd, k)
    if step % 100 == 0 or step == 399:
        l_ps = jnp.linalg.norm(params["w"] - w_true)
        l_sgd = jnp.linalg.norm(params_sgd - w_true)
        print(f"  step {step:3d}  |w-w*|  PowerSGD={l_ps:.4f}  SGD={l_sgd:.4f}")

# ---------------------------------------------------------------------------
section("5. Bucketed engine: 2 collectives per step, however many matrices")

# a small multi-layer "model": 5 weight matrices + 5 bias vectors
# (mirrored by tests/test_bucketing.py::test_bucketed_step_issues_exactly_two_collectives)
mkey = jax.random.key(7)
dims = [(64, 32), (32, 32), (32, 16), (30, 16), (16, 4)]
mgrads, mspecs = {}, {}
for i, (n_i, m_i) in enumerate(dims):
    w = jax.random.normal(jax.random.fold_in(mkey, i), (n_i, m_i))
    mgrads[f"layer{i}/w"], mspecs[f"layer{i}/w"] = w, matrixize.default_spec(w)
    b = jax.random.normal(jax.random.fold_in(mkey, 100 + i), (m_i,))
    mgrads[f"layer{i}/b"], mspecs[f"layer{i}/b"] = b, matrixize.default_spec(b)
mshapes = jax.tree_util.tree_map(
    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), mgrads)

for mode in ("off", "auto"):
    stats = CollectiveStats()
    comp5 = PowerSGDCompressor(rank=2, bucketing=mode)
    out5 = comp5.step(mgrads, comp5.init(mshapes, mspecs, KEY), mspecs,
                      ctx=MeshCtx(stats=stats), key=KEY)
    label = "per-leaf" if mode == "off" else "bucketed"
    print(f"  {label:9s}: {stats.data_collectives:2d} collectives/step, "
          f"bytes each: {stats.bytes_per_collective()}")
    if mode == "off":
        agg_ref = out5.agg
diff5 = max(float(jnp.abs(out5.agg[k] - agg_ref[k]).max()) for k in mgrads)
print(f"  max |bucketed - per-leaf| over the update = {diff5:.2e}")
print("  (same math, fused into one flat all-reduce per phase — the bucketed"
      "\n   engine is the default; pass bucketing='off' for the per-leaf path)")

# ---------------------------------------------------------------------------
section("6. The whole zoo through the transport engine")

# every compressor declares its payloads; the engine fuses them into O(1)
# collectives — all-reduce for linear schemes, W-scaled all-gather otherwise
for name in ("identity", "powersgd", "random_k", "sign_norm", "top_k"):
    stats = CollectiveStats()
    comp6 = make_compressor(name, rank=2)
    comp6.step(mgrads, comp6.init(mshapes, mspecs, KEY), mspecs,
               ctx=MeshCtx(stats=stats), key=KEY)
    print(f"  {name:10s}: {stats.data_collectives} collectives/step "
          f"({stats.reduce_collectives} reduce, "
          f"{stats.gather_collectives} gather)")
print("  (gather bytes scale with W on the wire — CollectiveStats records"
      "\n   the fanout; see benchmarks/run.py --only zoo_transport_profile)")

# ---------------------------------------------------------------------------
section("7. Adaptive rank: schedules + the α-β autotuner")

# (mirrors the README "Adaptive rank" snippet)
# A. scheduled rank: low rank early, full rank late (PowerSGD+-style).
#    The live rank is carried by the state (Q.shape[-1]); the controller
#    transitions it between steps and the retained columns survive
#    bit-exactly.
from repro.core import autotune

comp7 = PowerSGDCompressor(rank_schedule="1@0,2@2,4@4")
ctl = comp7.controller()
state7 = comp7.init(mshapes, mspecs, KEY)
for step in range(6):
    state7, changed = ctl.update(state7, step)   # retraces on a switch
    out7 = comp7.step(mgrads, state7, mspecs, key=KEY)
    state7 = out7.state
    if step in (0, 2, 4):
        r = state7["layer0/w"].shape[-1]
        print(f"  step {step}: rank {r}, payload "
              f"{out7.bits_per_worker // 32} floats")
print(f"  rank history: {ctl.history}")

# B. autotuned: per-bucket ranks + wire policy under a bits budget,
#    priced with an α-β hardware model
from repro.core.powersgd import compressed_floats_total

budget_bits = compressed_floats_total(mshapes, mspecs, 4) * 32 // 2
plan = autotune.autotune(
    mshapes, mspecs, bits_budget=budget_bits, workers=16,
    hw=autotune.HardwareModel.from_backend("nccl_10gbit"))
comp_t = autotune.make_tuned_compressor(plan)            # wire policy applied
state_t = autotune.apply_plan(plan, comp_t.init(mshapes, mspecs, KEY),
                              mshapes, mspecs, KEY)      # per-bucket ranks
print(f"  autotuned under {budget_bits} payload bits (50% of fixed rank-4):")
for d in plan.decisions:
    print(f"    bucket {d.n}x{d.m} (x{d.count}): rank {d.rank}")
print(f"    wire_dtype={plan.wire_dtype}, predicted comm "
      f"{plan.predicted_comm_s*1e3:.3f} ms/step @ W=16")
stats7 = CollectiveStats()
comp_t.step(mgrads, state_t, mspecs, ctx=MeshCtx(stats=stats7), key=KEY)
print(f"    still {stats7.data_collectives} fused collectives/step "
      "(mixed per-bucket ranks ride the same 2 flat reduces)")

print("\nDone. PowerSGD tracks uncompressed SGD while sending "
      f"{(dim_in*dim_out)/(2*(dim_in+dim_out)):.0f}x fewer floats per step.")
